//! Regenerates **Table 8** (dense-delta ring buffer budget) with
//! measured compression ratios and revert latencies (G3), including the
//! XOR-vs-arithmetic ablation (sparse top-k is deliberately absent: the
//! paper uses it only as a non-exact ablation).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::checkpoint::TrainState;
use unlearn::deltas::{DeltaRing, PatchMode};
use unlearn::util::rng::SplitMix64;

/// Simulated AdamW-style update trajectory (small deltas, realistic
/// exponent structure — what the ring compresses in production).
fn walk(n: usize, steps: usize, seed: u64) -> Vec<TrainState> {
    let mut r = SplitMix64::new(seed);
    let mut s = TrainState::zeros_like(
        (0..n).map(|_| r.normal() as f32 * 0.02).collect(),
    );
    s.m = vec![0.0; n];
    s.v = vec![1e-8; n];
    let mut out = vec![s.clone()];
    for t in 0..steps {
        for i in 0..n {
            let g = r.normal() as f32 * 0.1;
            s.m[i] = 0.9 * s.m[i] + 0.1 * g;
            s.v[i] = 0.999 * s.v[i] + 0.001 * g * g;
            s.params[i] -= 1e-3 * s.m[i] / (s.v[i].sqrt() + 1e-8);
        }
        s.applied_updates += 1;
        s.logical_step = t as u32 + 1;
        out.push(s.clone());
    }
    out
}

fn main() {
    let window = 16;
    header(
        "Table 8 — dense-delta ring budget (window N=16)",
        &[
            "Params", "Per-step raw", "Pre-compress total", "Ratio",
            "Stored",
        ],
    );
    for n in [101_614usize, 120_064, 1_000_000] {
        // 101,614 f32 ≈ the paper's 406,456 B per-step delta
        let states = walk(n, window, 42);
        let mut ring = DeltaRing::new(n, window, PatchMode::Xor, false);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]);
        }
        let b = ring.budget();
        println!(
            "{n} | {} | {} | {:.2} | {}",
            fmt_bytes(b.per_step_bytes_raw as u64),
            fmt_bytes(b.pre_compress_total as u64),
            b.compress_ratio,
            fmt_bytes(b.stored_bytes as u64)
        );
    }
    println!("(paper toy: 406,456 B/step, N=16, ratio 0.70, ~4.55 MB stored)");

    header(
        "Revert latency (G3) — measured",
        &["Mode", "Params", "Revert u=16 steps", "Exact?"],
    );
    for (mode, name) in [
        (PatchMode::Xor, "XOR (bitwise)"),
        (PatchMode::Arithmetic, "arithmetic"),
    ] {
        let n = 120_064;
        let states = walk(n, window, 7);
        let st = time_it(1, 5, || {
            let mut ring = DeltaRing::new(n, window, mode, true);
            for w in states.windows(2) {
                ring.record(&w[0], &w[1]);
            }
            let mut cur = states.last().unwrap().clone();
            ring.revert(&mut cur, window).unwrap();
            cur
        });
        // verify exactness claim
        let mut ring = DeltaRing::new(n, window, mode, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]);
        }
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, window).unwrap();
        let exact = cur.bits_equal(&states[0]);
        println!(
            "{name} | {n} | {} (incl. record) | {}",
            fmt_secs(st.mean),
            if exact { "bitwise" } else { "up to rounding" }
        );
    }

    header(
        "Record throughput — measured",
        &["Params", "record() per step", "Bytes stored/step"],
    );
    let n = 120_064;
    let states = walk(n, 2, 9);
    let st = time_it(1, 10, || {
        let mut ring = DeltaRing::new(n, window, PatchMode::Xor, true);
        ring.record(&states[0], &states[1]);
        ring
    });
    let mut ring = DeltaRing::new(n, window, PatchMode::Xor, true);
    ring.record(&states[0], &states[1]);
    println!(
        "{n} | {} | {}",
        fmt_secs(st.mean),
        fmt_bytes(ring.budget().stored_bytes as u64)
    );
}
