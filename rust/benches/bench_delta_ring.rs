//! Regenerates **Table 8** (dense-delta ring buffer budget) with
//! measured compression ratios and revert latencies (G3), including the
//! XOR-vs-arithmetic ablation (sparse top-k is deliberately absent: the
//! paper uses it only as a non-exact ablation) and the scalar-vs-
//! word-wise hot-path comparison that justifies the `util::simd` layer.
//!
//! `-- --json` emits `BENCH_delta_ring.json` (ns/op, bytes/step,
//! compress ratio, scalar-baseline speedup).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::io::Write as _;

use unlearn::checkpoint::TrainState;
use unlearn::deltas::{DeltaRing, PatchMode};
use unlearn::util::json::Json;
use unlearn::util::rng::SplitMix64;
use unlearn::util::simd;

/// Simulated AdamW-style update trajectory (small deltas, realistic
/// exponent structure — what the ring compresses in production).
fn walk(n: usize, steps: usize, seed: u64) -> Vec<TrainState> {
    let mut r = SplitMix64::new(seed);
    let mut s = TrainState::zeros_like(
        (0..n).map(|_| r.normal() as f32 * 0.02).collect(),
    );
    s.m = vec![0.0; n];
    s.v = vec![1e-8; n];
    let mut out = vec![s.clone()];
    for t in 0..steps {
        for i in 0..n {
            let g = r.normal() as f32 * 0.1;
            s.m[i] = 0.9 * s.m[i] + 0.1 * g;
            s.v[i] = 0.999 * s.v[i] + 0.001 * g * g;
            s.params[i] -= 1e-3 * s.m[i] / (s.v[i].sqrt() + 1e-8);
        }
        s.applied_updates += 1;
        s.logical_step = t as u32 + 1;
        out.push(s.clone());
    }
    out
}

/// The seed's scalar record pipeline: serialize both tensors, XOR one
/// byte at a time, transpose, single-stream DEFLATE.  Kept as the
/// measured before/after baseline for the word-wise zero-copy path.
fn scalar_record_patch(before: &[f32], after: &[f32]) -> Vec<u8> {
    let mut b = simd::scalar::f32s_to_bytes(after);
    let before_b = simd::scalar::f32s_to_bytes(before);
    simd::scalar::xor_in_place(&mut b, &before_b);
    let planes = unlearn::util::compress::plane_split(&b).unwrap();
    let mut enc = flate2::write::ZlibEncoder::new(
        Vec::new(),
        flate2::Compression::fast(),
    );
    enc.write_all(&planes).unwrap();
    enc.finish().unwrap()
}

fn measure(n: usize, window: usize) -> (Stats, Stats, f64, usize, f64) {
    let states = walk(n, window, 7);
    let record = time_it(1, 5, || {
        let mut ring = DeltaRing::new(n, window, PatchMode::Xor, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        ring
    });
    let mut ring = DeltaRing::new(n, window, PatchMode::Xor, true);
    for w in states.windows(2) {
        ring.record(&w[0], &w[1]).unwrap();
    }
    let budget = ring.budget();
    let bytes_per_step = budget.stored_bytes / window;
    let ratio = budget.compress_ratio;
    let revert = time_it(1, 5, || {
        let mut ring = DeltaRing::new(n, window, PatchMode::Xor, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, window).unwrap();
        cur
    });
    let scalar = time_it(1, 3, || {
        let mut patches = Vec::new();
        for w in states.windows(2) {
            patches.push(scalar_record_patch(&w[0].params, &w[1].params));
            patches.push(scalar_record_patch(&w[0].m, &w[1].m));
            patches.push(scalar_record_patch(&w[0].v, &w[1].v));
        }
        patches
    });
    (record, revert, ratio, bytes_per_step, scalar.mean)
}

fn json_main() {
    let (n, window) = (120_064usize, 4usize);
    let (record, revert, ratio, bytes_per_step, scalar_mean) =
        measure(n, window);
    let record_step = record.mean / window as f64;
    let scalar_step = scalar_mean / window as f64;
    let mut j = Json::obj();
    j.set("bench", "delta_ring")
        .set("params", n)
        .set("window", window)
        .set("record_ns_per_step", ns(record_step))
        .set("record_plus_revert_ns_per_step", ns(revert.mean / window as f64))
        .set("scalar_baseline_record_ns_per_step", ns(scalar_step))
        .set("speedup_vs_scalar", scalar_step / record_step)
        .set("bytes_per_step", bytes_per_step)
        .set("compress_ratio", ratio)
        .set("schema", 1);
    emit_json("delta_ring", &j);
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let window = 16;
    header(
        "Table 8 — dense-delta ring budget (window N=16)",
        &[
            "Params", "Per-step raw", "Pre-compress total", "Ratio",
            "Stored",
        ],
    );
    for n in [101_614usize, 120_064, 1_000_000] {
        // 101,614 f32 ≈ the paper's 406,456 B per-step delta
        let states = walk(n, window, 42);
        let mut ring = DeltaRing::new(n, window, PatchMode::Xor, false);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let b = ring.budget();
        println!(
            "{n} | {} | {} | {:.2} | {}",
            fmt_bytes(b.per_step_bytes_raw as u64),
            fmt_bytes(b.pre_compress_total as u64),
            b.compress_ratio,
            fmt_bytes(b.stored_bytes as u64)
        );
    }
    println!("(paper toy: 406,456 B/step, N=16, ratio 0.70, ~4.55 MB stored)");

    header(
        "Revert latency (G3) — measured",
        &["Mode", "Params", "Revert u=16 steps", "Exact?"],
    );
    for (mode, name) in [
        (PatchMode::Xor, "XOR (bitwise)"),
        (PatchMode::Arithmetic, "arithmetic"),
    ] {
        let n = 120_064;
        let states = walk(n, window, 7);
        let st = time_it(1, 5, || {
            let mut ring = DeltaRing::new(n, window, mode, true);
            for w in states.windows(2) {
                ring.record(&w[0], &w[1]).unwrap();
            }
            let mut cur = states.last().unwrap().clone();
            ring.revert(&mut cur, window).unwrap();
            cur
        });
        // verify exactness claim
        let mut ring = DeltaRing::new(n, window, mode, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, window).unwrap();
        let exact = cur.bits_equal(&states[0]);
        println!(
            "{name} | {n} | {} (incl. record) | {}",
            fmt_secs(st.mean),
            if exact { "bitwise" } else { "up to rounding" }
        );
    }

    header(
        "Record throughput — measured (word-wise fused vs scalar seed path)",
        &["Params", "record()/step", "scalar baseline/step", "Speedup",
          "Bytes stored/step"],
    );
    let (n, w4) = (120_064usize, 4usize);
    let (record, _revert, _ratio, bytes_per_step, scalar_mean) =
        measure(n, w4);
    let record_step = record.mean / w4 as f64;
    let scalar_step = scalar_mean / w4 as f64;
    println!(
        "{n} | {} | {} | {:.2}x | {}",
        fmt_secs(record_step),
        fmt_secs(scalar_step),
        scalar_step / record_step,
        fmt_bytes(bytes_per_step as u64)
    );
    // wall-time accounting now lives in the budget too
    let states = walk(n, 2, 9);
    let mut ring = DeltaRing::new(n, w4, PatchMode::Xor, true);
    ring.record(&states[0], &states[1]).unwrap();
    let b = ring.budget();
    println!(
        "ring-reported record wall time: {} (last {})",
        fmt_secs(b.record_secs_mean),
        fmt_secs(b.record_secs_last)
    );
}
