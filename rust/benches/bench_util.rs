//! Minimal bench harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + repeated timing with mean/p50/p95 reporting and a
//! table-row printer so each bench binary regenerates its paper table
//! with measured numbers.  Used via `cargo bench` with `harness = false`
//! targets.

use std::time::Instant;

/// Time `f` `iters` times after `warmup` runs.
pub fn time_it<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from(samples)
}

/// Summary statistics over timing samples (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub n: usize,
}

impl Stats {
    pub fn from(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        Stats {
            mean: samples.iter().sum::<f64>() / n as f64,
            p50: samples[n / 2],
            p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
            min: samples[0],
            n,
        }
    }
}

/// Pretty time formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Pretty byte formatting.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

/// Print a table header + separator.
pub fn header(title: &str, cols: &[&str]) {
    println!("\n=== {title} ===");
    println!("{}", cols.join(" | "));
    println!("{}", vec!["---"; cols.len()].join(" | "));
}

/// True when the bench was invoked as `cargo bench --bench X -- --json`:
/// run the reduced smoke config and emit a `BENCH_<name>.json` summary
/// instead of the full human-readable tables.
#[allow(dead_code)]
pub fn json_mode() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Where `BENCH_<name>.json` lands: `$BENCH_JSON_DIR` or the crate root
/// (the committed baselines live in `rust/`).
#[allow(dead_code)]
pub fn bench_json_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::var("BENCH_JSON_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    std::path::PathBuf::from(dir).join(format!("BENCH_{name}.json"))
}

/// Write a bench summary JSON (and echo it) — the per-PR perf record.
#[allow(dead_code)]
pub fn emit_json(name: &str, j: &unlearn::util::json::Json) {
    let path = bench_json_path(name);
    std::fs::write(&path, j.pretty()).expect("write bench json");
    println!("{}", j.pretty());
    eprintln!("wrote {}", path.display());
}

/// Seconds -> nanoseconds (bench JSON unit).
#[allow(dead_code)]
pub fn ns(secs: f64) -> f64 {
    secs * 1e9
}
