//! Online-ingest benchmark: **forget latency under a moving tail**.
//!
//! Trains a small system, then runs several online-ingest rounds
//! through the scheduler (durable doc-segment append + bounded
//! train-increment, both committed through the interleave log) and
//! measures wall time for one forget request issued AFTER the tail has
//! moved — the number the online data plane adds to the paper's story:
//! erasure latency must not grow with how much the corpus has been
//! extended since training "finished".  The run double-checks itself
//! the same way the acceptance test does: the post-forget serving
//! state must be bit-identical to the retain-only oracle over the
//! final corpus.  Ingest throughput (docs/sec through append + index
//! insert + increment) is reported ungated.
//!
//! `-- --json` gates `ingest_forget_ms` against the committed
//! `BENCH_ingest.json` through the same >20% cigate rule as the other
//! benches, with first-measured-run promotion over the null
//! placeholder.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::collections::HashSet;
use std::time::Instant;

use unlearn::cigate::perf;
use unlearn::config::RunConfig;
use unlearn::controller::{execute_batch, ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::ingest::{self, IngestDoc, IngestLog, IngestScheduler};
use unlearn::runtime::Runtime;
use unlearn::util::json::Json;

const STEPS: u32 = 8;
const INC_STEPS: u32 = 2;
const ROUNDS: usize = 3;
const DOCS_PER_ROUND: usize = 4;
const FORGET_USER: u32 = 2;

struct Probe {
    /// Mean wall ms for one full ingest round (append + increment).
    ingest_round_ms: f64,
    /// Docs committed per second across all rounds.
    ingest_docs_per_sec: f64,
    /// Forget submit → committed, under the moved tail (the gated SLA).
    forget_ms: f64,
    /// Final corpus size (base + everything ingested).
    corpus_len: usize,
}

fn run_probe(rt: &Runtime, tag: &str) -> Probe {
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir(tag),
        steps: STEPS,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 2,
        warmup: 2,
        ..Default::default()
    };
    let trained =
        harness::build_system(rt, cfg.clone(), corpus, false).expect("train");
    let mut sys = trained.system;
    let mut log = IngestLog::attach(&cfg.run_dir, sys.corpus.len())
        .expect("attach log");

    let sched = IngestScheduler::new(INC_STEPS);
    let mut ingest_secs = 0.0;
    for r in 0..ROUNDS {
        let docs: Vec<IngestDoc> = (0..DOCS_PER_ROUND)
            .map(|d| IngestDoc {
                user: 200 + (r * DOCS_PER_ROUND + d) as u32,
                text: format!(
                    "round {r} doc {d}: a new user files a short note \
                     about the weather on day {}",
                    r * DOCS_PER_ROUND + d
                ),
            })
            .collect();
        let t0 = Instant::now();
        let out = sched
            .run_round(
                &mut sys,
                &mut log,
                ingest::round_of(&format!("{tag}-round-{r}")),
                &docs,
            )
            .expect("ingest round");
        ingest_secs += t0.elapsed().as_secs_f64();
        assert!(out.executed, "a fresh round must execute");
        assert_eq!(sys.tail_lag_steps(), 0, "increment covers the tail");
    }

    let req = ForgetRequest {
        id: "bench-ingest".to_string(),
        user: Some(FORGET_USER),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    let t0 = Instant::now();
    let out = execute_batch(&mut sys, &[req]).expect("forget");
    assert!(
        out.outcomes[0].as_ref().unwrap().executed,
        "forget must commit"
    );
    log.record_forget("bench-ingest", sys.forgotten.len())
        .expect("interleave forget record");
    let forget_ms = t0.elapsed().as_secs_f64() * 1e3;

    // the bench proves what it times: serving state after the forget
    // must equal the retain-only oracle over the FINAL corpus
    let mut union: HashSet<u64> = sys.forgotten.clone();
    union.extend(sys.laundered.iter().copied());
    let oracle = ingest::oracle_state(&sys, &union).expect("oracle replay");
    assert!(
        sys.state.bits_equal(&oracle),
        "forget under a moving tail must stay bit-exact"
    );

    let n_docs = (ROUNDS * DOCS_PER_ROUND) as f64;
    Probe {
        ingest_round_ms: ingest_secs * 1e3 / ROUNDS as f64,
        ingest_docs_per_sec: if ingest_secs > 0.0 {
            n_docs / ingest_secs
        } else {
            0.0
        },
        forget_ms,
        corpus_len: sys.corpus.len(),
    }
}

fn json_main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let p = run_probe(&rt, "bench-ingest-json");

    // fail-closed gate against the committed baseline
    let baseline = bench_json_path("ingest");
    match perf::check_ingest(
        &baseline,
        p.forget_ms,
        perf::DEFAULT_MAX_REGRESSION,
    ) {
        Ok(v) => println!("ingest perf gate: {v:?}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }

    let mut j = Json::obj();
    j.set("bench", "ingest")
        .set(perf::INGEST_METRIC, p.forget_ms)
        .set("ingest_round_ms", p.ingest_round_ms)
        .set("ingest_docs_per_sec", p.ingest_docs_per_sec)
        .set("rounds", ROUNDS)
        .set("docs_per_round", DOCS_PER_ROUND)
        .set("corpus_len", p.corpus_len)
        .set("schema", 1);
    match perf::record_first_baseline_for(&baseline, perf::INGEST_METRIC, &j)
        .expect("write baseline")
    {
        perf::BaselineDisposition::Recorded => {
            println!(
                "ingest baseline: first measured run RECORDED at {} — the \
                 >{:.0}% regression gate bites from the next run",
                baseline.display(),
                perf::DEFAULT_MAX_REGRESSION * 100.0
            );
            println!("{}", j.pretty());
        }
        perf::BaselineDisposition::AlreadyMeasured => emit_json("ingest", &j),
    }
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let p = run_probe(&rt, "bench-ingest");
    header(
        "Online ingest (forget under a moving tail)",
        &["metric", "value"],
    );
    println!("forget under moving tail | {}", fmt_secs(p.forget_ms / 1e3));
    println!("ingest round | {}", fmt_secs(p.ingest_round_ms / 1e3));
    println!("ingest throughput | {:.1} docs/s", p.ingest_docs_per_sec);
    println!(
        "final corpus | {} docs after {} rounds × {}",
        p.corpus_len, ROUNDS, DOCS_PER_ROUND
    );
}
