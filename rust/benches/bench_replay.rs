//! End-to-end replay benchmark (Tables 3-5 latency side): measures
//! t_step, full-run training throughput, and ReplayFilter latency as a
//! function of checkpoint distance — the paper's "worst-case replay
//! latency ≤ K·t_step" claim, measured — plus the nearest-checkpoint
//! auto-start path the controller uses.
//!
//! `-- --json` runs the smoke config, compares the measured per-step
//! replay latency against the committed `BENCH_replay.json` baseline
//! through the cigate perf gate (refusing a >20% regression with a
//! non-zero exit), then records the new baseline.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::collections::HashSet;

use unlearn::checkpoint::CheckpointStore;
use unlearn::cigate::perf;
use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::replay::{
    load_run, replay_filter, replay_filter_nearest, ReplayOptions,
};
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

struct Fixture {
    rt: Runtime,
    corpus: unlearn::data::corpus::Corpus,
    cfg: RunConfig,
    steps: u32,
}

fn fixture(tag: &str, steps: u32) -> Fixture {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir(tag),
        steps,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        warmup: 4,
        ..Default::default()
    };
    Fixture {
        rt,
        corpus,
        cfg,
        steps,
    }
}

fn json_main() {
    let f = fixture("bench-replay-json", 12);
    let t0 = std::time::Instant::now();
    Trainer::new(&f.rt, f.cfg.clone(), f.corpus.clone())
        .train(|_| false)
        .unwrap();
    let t_step = t0.elapsed().as_secs_f64() / f.steps as f64;

    let (records, idmap, pins) = load_run(&f.cfg.run_dir, None).unwrap();
    let store = CheckpointStore::open(&f.cfg.run_dir.join("ckpt"), 64).unwrap();
    // first seen after checkpoint 4 (the small corpus is fully covered
    // within ~7 steps, so later-first-seen candidates don't exist)
    let closure: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&records, &idmap, 5)
            .into_iter()
            .take(4)
            .collect();
    // nearest-checkpoint auto-start (the controller's replay path),
    // A/B: default segment-parallel dispatch vs forced sequential
    let par_opts = ReplayOptions::default();
    let seq_opts = ReplayOptions {
        sequential: true,
        ..ReplayOptions::default()
    };
    let (k, outcome) = replay_filter_nearest(
        &f.rt, &f.corpus, &store, &records, &idmap, &closure, Some(&pins),
        &par_opts,
    )
    .unwrap();
    let replayed = (f.steps - k).max(1);
    let st = time_it(0, 3, || {
        replay_filter_nearest(
            &f.rt, &f.corpus, &store, &records, &idmap, &closure,
            Some(&pins), &par_opts,
        )
        .unwrap()
    });
    let st_seq = time_it(0, 3, || {
        replay_filter_nearest(
            &f.rt, &f.corpus, &store, &records, &idmap, &closure,
            Some(&pins), &seq_opts,
        )
        .unwrap()
    });
    let ns_per_step = ns(st.mean) / replayed as f64;
    let ns_per_step_seq = ns(st_seq.mean) / replayed as f64;
    drop(outcome);

    // fail-closed perf gate against the committed baseline
    let baseline = bench_json_path("replay");
    match perf::check_replay(&baseline, ns_per_step, perf::DEFAULT_MAX_REGRESSION)
    {
        Ok(v) => println!("perf gate: {v:?}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }
    let mut j = perf::replay_json(ns_per_step, ns(t_step), f.steps);
    perf::set_replay_ab(&mut j, ns_per_step_seq, ns_per_step);
    j.set("from_checkpoint", k).set("replayed_steps", replayed);
    println!(
        "replay ns/step: sequential {ns_per_step_seq:.0} vs parallel \
         {ns_per_step:.0} ({:.2}x)",
        ns_per_step_seq / ns_per_step.max(1.0)
    );
    // a committed null placeholder (toolchain-less host) is promoted to
    // a real baseline by the first measured run — loudly, so the gate's
    // record-only phase is visible in CI logs
    match perf::record_first_baseline(&baseline, &j).expect("write baseline")
    {
        perf::BaselineDisposition::Recorded => {
            println!(
                "perf baseline: first measured run RECORDED at {} — the \
                 >{:.0}% regression gate bites from the next run",
                baseline.display(),
                perf::DEFAULT_MAX_REGRESSION * 100.0
            );
            println!("{}", j.pretty());
        }
        perf::BaselineDisposition::AlreadyMeasured => emit_json("replay", &j),
    }
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let f = fixture("bench-replay", 12);
    let steps = f.steps;

    header("Training throughput (measured)", &["Steps", "Total", "t_step"]);
    let t0 = std::time::Instant::now();
    Trainer::new(&f.rt, f.cfg.clone(), f.corpus.clone())
        .train(|_| false)
        .unwrap();
    let total = t0.elapsed().as_secs_f64();
    let t_step = total / steps as f64;
    println!("{steps} | {} | {}", fmt_secs(total), fmt_secs(t_step));

    let (records, idmap, pins) = load_run(&f.cfg.run_dir, None).unwrap();
    let store = CheckpointStore::open(&f.cfg.run_dir.join("ckpt"), 64).unwrap();
    let closure: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&records, &idmap, 5)
            .into_iter()
            .take(4)
            .collect();

    header(
        "ReplayFilter latency vs checkpoint distance (≤ K·t_step bound)",
        &["From ckpt", "Steps replayed", "Latency", "Bound K·t_step"],
    );
    for k in [0u32, 4, 8] {
        let ck = store.load_full(k).unwrap();
        let st = time_it(0, 2, || {
            replay_filter(
                &f.rt,
                &f.corpus,
                &ck,
                &records,
                &idmap,
                &closure,
                Some(&pins),
                &ReplayOptions::default(),
            )
            .unwrap()
        });
        let replayed = steps - k;
        println!(
            "C_{k} | {replayed} | {} | {}",
            fmt_secs(st.mean),
            fmt_secs(replayed as f64 * t_step)
        );
    }

    header(
        "Segment-parallel vs sequential replay (pinned reduce, bit-identical)",
        &["Mode", "From ckpt", "Latency", "Speedup"],
    );
    let ck0 = store.load_full(0).unwrap();
    let st_seq = time_it(0, 2, || {
        replay_filter(
            &f.rt, &f.corpus, &ck0, &records, &idmap, &closure, Some(&pins),
            &ReplayOptions { sequential: true, ..ReplayOptions::default() },
        )
        .unwrap()
    });
    let st_par = time_it(0, 2, || {
        replay_filter(
            &f.rt, &f.corpus, &ck0, &records, &idmap, &closure, Some(&pins),
            &ReplayOptions::default(),
        )
        .unwrap()
    });
    println!("sequential | C_0 | {} | 1.00x", fmt_secs(st_seq.mean));
    println!(
        "parallel | C_0 | {} | {:.2}x",
        fmt_secs(st_par.mean),
        st_seq.mean / st_par.mean.max(1e-12)
    );

    header(
        "Nearest-checkpoint auto-start (controller path)",
        &["Chosen ckpt", "Steps replayed", "Latency"],
    );
    let st = time_it(0, 2, || {
        replay_filter_nearest(
            &f.rt, &f.corpus, &store, &records, &idmap, &closure,
            Some(&pins), &ReplayOptions::default(),
        )
        .unwrap()
    });
    let (k, _) = replay_filter_nearest(
        &f.rt, &f.corpus, &store, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions::default(),
    )
    .unwrap();
    println!("C_{k} | {} | {}", steps - k, fmt_secs(st.mean));

    header(
        "Per-graph execution time (runtime metrics)",
        &["Graph", "Calls", "Mean"],
    );
    for (name, n, tot) in f.rt.metrics.timers() {
        if let Some(g) = name.strip_prefix("exec.") {
            if n > 0 {
                println!("{g} | {n} | {}", fmt_secs(tot / n as f64));
            }
        }
    }
}
