//! End-to-end replay benchmark (Tables 3-5 latency side): measures
//! t_step, full-run training throughput, and ReplayFilter latency as a
//! function of checkpoint distance — the paper's "worst-case replay
//! latency ≤ K·t_step" claim, measured.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::collections::HashSet;

use unlearn::checkpoint::CheckpointStore;
use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::replay::{load_run, replay_filter, ReplayOptions};
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

fn main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let steps = 12u32;
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("bench-replay"),
        steps,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        warmup: 4,
        ..Default::default()
    };

    header("Training throughput (measured)", &["Steps", "Total", "t_step"]);
    let t0 = std::time::Instant::now();
    Trainer::new(&rt, cfg.clone(), corpus.clone())
        .train(|_| false)
        .unwrap();
    let total = t0.elapsed().as_secs_f64();
    let t_step = total / steps as f64;
    println!("{steps} | {} | {}", fmt_secs(total), fmt_secs(t_step));

    let (records, idmap, pins) = load_run(&cfg.run_dir, None).unwrap();
    let store = CheckpointStore::open(&cfg.run_dir.join("ckpt"), 64).unwrap();
    let closure: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&records, &idmap, 9)
            .into_iter()
            .take(4)
            .collect();

    header(
        "ReplayFilter latency vs checkpoint distance (≤ K·t_step bound)",
        &["From ckpt", "Steps replayed", "Latency", "Bound K·t_step"],
    );
    for k in [0u32, 4, 8] {
        let ck = store.load_full(k).unwrap();
        let st = time_it(0, 2, || {
            replay_filter(
                &rt,
                &corpus,
                &ck,
                &records,
                &idmap,
                &closure,
                Some(&pins),
                &ReplayOptions::default(),
            )
            .unwrap()
        });
        let replayed = steps - k;
        println!(
            "C_{k} | {replayed} | {} | {}",
            fmt_secs(st.mean),
            fmt_secs(replayed as f64 * t_step)
        );
    }

    header(
        "Per-graph execution time (runtime metrics)",
        &["Graph", "Calls", "Mean"],
    );
    for g in ["train_step", "adamw_update"] {
        if let Some((n, _tot, mean)) = rt.metrics.timer(&format!("exec.{g}")) {
            println!("{g} | {n} | {}", fmt_secs(mean));
        }
    }
}
