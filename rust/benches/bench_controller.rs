//! **Figure 1** as a benchmark: route the four request archetypes
//! through the controller and report path taken + end-to-end latency —
//! the cost ordering (adapter ≪ revert ≪ hot path < replay) is the
//! figure's operational story.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::util::json::Json;

fn json_main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let mk = |tag: &str| RunConfig {
        run_dir: unlearn::util::tempdir(tag),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 2,
        ..Default::default()
    };
    let trained =
        harness::build_system(&rt, mk("bench-controller-json"), corpus.clone(),
                              false)
            .unwrap();
    let mut system = trained.system;
    let t0 = std::time::Instant::now();
    let outcome = system
        .handle(&ForgetRequest {
            id: "bench-json-replay".into(),
            user: Some(2),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        })
        .unwrap();
    let handle_ns = ns(t0.elapsed().as_secs_f64());

    // ---- coalesced vs sequential forget throughput --------------------
    // K replay-bound requests: once sequentially (K tail replays), once
    // through execute_batch (ONE union-filtered tail replay).  Tracks
    // the amortization win in the perf trajectory.
    const K: usize = 4;
    let mut seq =
        harness::build_system(&rt, mk("bench-ctl-seq"), corpus.clone(), false)
            .unwrap()
            .system;
    let mut coal =
        harness::build_system(&rt, mk("bench-ctl-coal"), corpus.clone(), false)
            .unwrap()
            .system;
    // pick users whose earliest influence predates the ring window so
    // BOTH routes measure the replay path (apples to apples)
    let earliest_ring = seq.ring.earliest_step().unwrap_or(u32::MAX);
    let reqs: Vec<ForgetRequest> = (0..24u32)
        .filter_map(|u| {
            let req = ForgetRequest {
                id: format!("bench-batch-{u}"),
                user: Some(u),
                sample_ids: vec![],
                urgency: Urgency::Normal,
            };
            let plan = seq.plan(&req).ok()?;
            let first = *plan.offending.first()?;
            (first < earliest_ring).then_some(req)
        })
        .take(K)
        .collect();
    let kn = reqs.len().max(1) as f64;
    let t0 = std::time::Instant::now();
    let mut seq_replay_steps = 0u64;
    for r in &reqs {
        let o = seq.handle(r).unwrap();
        seq_replay_steps += o
            .details
            .get("applied_steps")
            .or_else(|| o.details.get("resumed_applied_steps"))
            .and_then(|v| v.as_u64())
            .unwrap_or(0);
    }
    let seq_secs = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let batch = unlearn::controller::execute_batch(&mut coal, &reqs).unwrap();
    let coal_secs = t0.elapsed().as_secs_f64();

    // ---- checkpoint laundering: wall time + plan-cost reduction -------
    // `coal` now carries the batch's cumulative forgotten set.  ONE
    // probe user is pinned before laundering and re-planned after, so
    // pre/post compare the same request: its rebuild must start before
    // ALL forgotten influence pre-launder and only before its own
    // influence post-launder.
    let probe_cost = |sys: &unlearn::controller::UnlearnSystem<'_>,
                      tag: &str,
                      u: u32| {
        let p = sys
            .plan(&ForgetRequest {
                id: format!("launder-probe-{tag}-{u}"),
                user: Some(u),
                sample_ids: vec![],
                urgency: Urgency::Normal,
            })
            .ok()?;
        p.steps
            .iter()
            .find(|s| s.step.kind() == "exact_replay")
            .map(|s| s.cost.replay_steps)
    };
    let probe_user =
        (0..24u32).find(|&u| probe_cost(&coal, "pin", u).is_some());
    let plan_steps_pre =
        probe_user.and_then(|u| probe_cost(&coal, "pre", u));
    let policy = unlearn::controller::LaunderPolicy {
        min_extra_replay_records: 0,
    };
    let t0 = std::time::Instant::now();
    let laundered = coal
        .launder("bench-launder", &policy, true)
        .map(|o| o.executed)
        .unwrap_or(false);
    let launder_secs = t0.elapsed().as_secs_f64();
    let plan_steps_post =
        probe_user.and_then(|u| probe_cost(&coal, "post", u));
    let cas = coal.cas_stats().ok();

    let mut j = unlearn::util::json::Json::obj();
    j.set("bench", "controller")
        .set("action", outcome.action.as_str())
        .set("closure_size", outcome.closure_size)
        .set("handle_ns", handle_ns)
        .set("coalesce_requests", reqs.len())
        .set("seq_forget_ns_total", ns(seq_secs))
        .set("coalesced_forget_ns_total", ns(coal_secs))
        .set("seq_requests_per_s", kn / seq_secs.max(1e-12))
        .set("coalesced_requests_per_s", kn / coal_secs.max(1e-12))
        .set(
            "seq_replay_steps_per_request",
            seq_replay_steps as f64 / kn,
        )
        .set(
            "coalesced_replay_steps_per_request",
            batch.applied_steps as f64 / kn,
        )
        .set("coalesced_replays_run", batch.replays_run)
        .set("launder_executed", laundered)
        .set("launder_ns", ns(launder_secs))
        .set(
            "plan_replay_steps_pre_launder",
            plan_steps_pre.map(Json::from).unwrap_or(Json::Null),
        )
        .set(
            "plan_replay_steps_post_launder",
            plan_steps_post.map(Json::from).unwrap_or(Json::Null),
        )
        .set(
            // null when either probe failed — never a fabricated win
            "launder_plan_cost_reduction",
            match (plan_steps_pre, plan_steps_post) {
                (Some(pre), Some(post)) if pre > 0 => {
                    Json::from(1.0 - post as f64 / pre as f64)
                }
                _ => Json::Null,
            },
        )
        .set(
            "cas_dedup_ratio",
            cas.as_ref().map(|c| c.dedup_ratio).unwrap_or(1.0),
        )
        .set("schema", 3);
    emit_json("controller", &j);
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let mut corpus = harness::toy_corpus(rt.manifest.seq_len);
    corpus.tag_cohort(&[150, 151], 9);
    let cohort_ids: Vec<u64> = [150u32, 151]
        .iter()
        .flat_map(|&u| corpus.user_samples(u))
        .collect();
    let cohort_set: std::collections::HashSet<u64> =
        cohort_ids.iter().copied().collect();

    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("bench-controller"),
        steps: 12,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    // base training excludes the cohort (it is firewalled into an adapter)
    let trainer =
        unlearn::trainer::Trainer::new(&rt, cfg.clone(), corpus.clone());
    let out = trainer.train_excluding(&cohort_set).unwrap();
    let trained =
        harness::system_from_run(&rt, cfg, corpus.clone(), out, true).unwrap();
    let mut system = trained.system;
    system
        .adapters
        .train_cohort(&rt, &corpus, &system.state.params, 9, &cohort_ids, 6,
                      5e-3, 1)
        .unwrap();

    header(
        "Figure 1 — controller path selection (measured)",
        &["Request archetype", "Path taken", "Latency", "Audit pass"],
    );
    fn run(
        system: &mut unlearn::controller::UnlearnSystem<'_>,
        label: &str,
        req: ForgetRequest,
    ) {
        let t0 = std::time::Instant::now();
        let outcome = system.handle(&req).unwrap();
        println!(
            "{label} | {} | {} | {:?}",
            outcome.action.as_str(),
            fmt_secs(t0.elapsed().as_secs_f64()),
            outcome.audit.map(|a| a.pass())
        );
    }
    // 1. cohort-confined -> adapter deletion
    run(
        &mut system,
        "cohort-confined (user 150)",
        ForgetRequest {
            id: "fig1-adapter".into(),
            user: Some(150),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        },
    );
    // 2. recent influence -> ring revert: candidates first seen inside
    // the ring window whose closure also stays inside it
    let recent_set: std::collections::HashSet<u64> =
        harness::ids_first_seen_at_or_after(&system.records, &system.idmap, 10)
            .into_iter()
            .collect();
    let mut recent: Vec<u64> = recent_set
        .iter()
        .copied()
        .filter(|&id| {
            let (cl, _) = system.closure_of(&ForgetRequest {
                id: "probe".into(),
                user: None,
                sample_ids: vec![id],
                urgency: Urgency::Normal,
            });
            cl.iter().all(|c| recent_set.contains(c))
        })
        .collect();
    recent.sort_unstable();
    recent.truncate(3);
    run(
        &mut system,
        "recent steps only",
        ForgetRequest {
            id: "fig1-revert".into(),
            user: None,
            sample_ids: recent,
            urgency: Urgency::Normal,
        },
    );
    // 3. urgent + old influence -> hot path (or escalation)
    run(
        &mut system,
        "urgent, old influence (user 1)",
        ForgetRequest {
            id: "fig1-hotpath".into(),
            user: Some(1),
            sample_ids: vec![],
            urgency: Urgency::High,
        },
    );
    // 4. normal urgency, old influence -> exact replay
    run(
        &mut system,
        "normal, old influence (user 2)",
        ForgetRequest {
            id: "fig1-replay".into(),
            user: Some(2),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        },
    );

    println!(
        "\nmanifest: {} entries, chain valid: {}",
        system.manifest.len(),
        system
            .manifest
            .verify_chain()
            .map(|c| c.iter().all(|(_, s)| *s))
            .unwrap_or(false)
    );
}
