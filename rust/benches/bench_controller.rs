//! **Figure 1** as a benchmark: route the four request archetypes
//! through the controller and report path taken + end-to-end latency —
//! the cost ordering (adapter ≪ revert ≪ hot path < replay) is the
//! figure's operational story.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::runtime::Runtime;

fn json_main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("bench-controller-json"),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 2,
        ..Default::default()
    };
    let trained =
        harness::build_system(&rt, cfg, corpus.clone(), false).unwrap();
    let mut system = trained.system;
    let t0 = std::time::Instant::now();
    let outcome = system
        .handle(&ForgetRequest {
            id: "bench-json-replay".into(),
            user: Some(2),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        })
        .unwrap();
    let mut j = unlearn::util::json::Json::obj();
    j.set("bench", "controller")
        .set("action", outcome.action.as_str())
        .set("closure_size", outcome.closure_size)
        .set("handle_ns", ns(t0.elapsed().as_secs_f64()))
        .set("schema", 1);
    emit_json("controller", &j);
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let mut corpus = harness::toy_corpus(rt.manifest.seq_len);
    corpus.tag_cohort(&[150, 151], 9);
    let cohort_ids: Vec<u64> = [150u32, 151]
        .iter()
        .flat_map(|&u| corpus.user_samples(u))
        .collect();
    let cohort_set: std::collections::HashSet<u64> =
        cohort_ids.iter().copied().collect();

    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("bench-controller"),
        steps: 12,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    // base training excludes the cohort (it is firewalled into an adapter)
    let trainer =
        unlearn::trainer::Trainer::new(&rt, cfg.clone(), corpus.clone());
    let out = trainer.train_excluding(&cohort_set).unwrap();
    let trained =
        harness::system_from_run(&rt, cfg, corpus.clone(), out, true).unwrap();
    let mut system = trained.system;
    system
        .adapters
        .train_cohort(&rt, &corpus, &system.state.params, 9, &cohort_ids, 6,
                      5e-3, 1)
        .unwrap();

    header(
        "Figure 1 — controller path selection (measured)",
        &["Request archetype", "Path taken", "Latency", "Audit pass"],
    );
    fn run(
        system: &mut unlearn::controller::UnlearnSystem<'_>,
        label: &str,
        req: ForgetRequest,
    ) {
        let t0 = std::time::Instant::now();
        let outcome = system.handle(&req).unwrap();
        println!(
            "{label} | {} | {} | {:?}",
            outcome.action.as_str(),
            fmt_secs(t0.elapsed().as_secs_f64()),
            outcome.audit.map(|a| a.pass())
        );
    }
    // 1. cohort-confined -> adapter deletion
    run(
        &mut system,
        "cohort-confined (user 150)",
        ForgetRequest {
            id: "fig1-adapter".into(),
            user: Some(150),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        },
    );
    // 2. recent influence -> ring revert: candidates first seen inside
    // the ring window whose closure also stays inside it
    let recent_set: std::collections::HashSet<u64> =
        harness::ids_first_seen_at_or_after(&system.records, &system.idmap, 10)
            .into_iter()
            .collect();
    let mut recent: Vec<u64> = recent_set
        .iter()
        .copied()
        .filter(|&id| {
            let (cl, _) = system.closure_of(&ForgetRequest {
                id: "probe".into(),
                user: None,
                sample_ids: vec![id],
                urgency: Urgency::Normal,
            });
            cl.iter().all(|c| recent_set.contains(c))
        })
        .collect();
    recent.sort_unstable();
    recent.truncate(3);
    run(
        &mut system,
        "recent steps only",
        ForgetRequest {
            id: "fig1-revert".into(),
            user: None,
            sample_ids: recent,
            urgency: Urgency::Normal,
        },
    );
    // 3. urgent + old influence -> hot path (or escalation)
    run(
        &mut system,
        "urgent, old influence (user 1)",
        ForgetRequest {
            id: "fig1-hotpath".into(),
            user: Some(1),
            sample_ids: vec![],
            urgency: Urgency::High,
        },
    );
    // 4. normal urgency, old influence -> exact replay
    run(
        &mut system,
        "normal, old influence (user 2)",
        ForgetRequest {
            id: "fig1-replay".into(),
            user: Some(2),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        },
    );

    println!(
        "\nmanifest: {} entries, chain valid: {}",
        system.manifest.len(),
        system
            .manifest
            .verify_chain()
            .map(|c| c.iter().all(|(_, s)| *s))
            .unwrap_or(false)
    );
}
