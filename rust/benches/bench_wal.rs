//! Regenerates **Table 7** (WAL overhead) with measured numbers, plus
//! append/scan throughput (the "negligible overhead" claim of §6.4).

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::cigate::perf;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::server::{JobQueue, JobRequest};
use unlearn::util::tempdir;
use unlearn::wal::{integrity, WalRecord, WalWriter};

fn rec(i: u32) -> WalRecord {
    WalRecord {
        hash64: 0xABCD_0000 + i as u64,
        seed64: i as u64 * 17,
        lr_bits: (1e-3f32).to_bits(),
        opt_step: i / 2,
        accum_end: i % 2 == 1,
        mb_len: 8,
    }
}

fn json_main() {
    let n = 10_000u32;
    let dir = tempdir("bench-wal-json");
    let append = time_it(0, 1, || {
        let mut w = WalWriter::create(&dir.join("a"), 4096, None).unwrap();
        for i in 0..n {
            w.append(&rec(i)).unwrap();
        }
        w.finish().unwrap();
    });
    let scan = time_it(1, 3, || integrity::scan(&dir.join("a"), None).unwrap());

    // ---- jobs-WAL recovery replay (schema 2) --------------------------
    // Restart-to-serving latency of the durable admin queue: reopen a
    // jobs WAL with a fixed pending backlog — parse, re-queue under
    // original ids, compact.  The warmup run compacts the freshly
    // written file, so the measured runs see the steady state every
    // real restart after the first sees.
    const PENDING: usize = 256;
    let jobs_wal = dir.join("jobs.wal");
    {
        let q = JobQueue::<JobRequest>::with_wal(&jobs_wal).unwrap();
        for i in 0..PENDING {
            q.submit(JobRequest::Forget(ForgetRequest {
                id: format!("req-{i}"),
                user: Some(i as u32),
                sample_ids: vec![],
                urgency: Urgency::Normal,
            }))
            .unwrap();
        }
    }
    let recovery = time_it(1, 3, || {
        let q = JobQueue::<JobRequest>::with_wal(&jobs_wal).unwrap();
        assert_eq!(q.queued_len(), PENDING);
    });
    let recovery_ns = ns(recovery.mean);

    // fail-closed gate against the committed baseline (record-only
    // while the committed file is a placeholder without the metric)
    let baseline = bench_json_path("wal");
    match perf::check_wal_recovery(
        &baseline,
        recovery_ns,
        perf::DEFAULT_MAX_REGRESSION,
    ) {
        Ok(v) => println!("wal recovery perf gate: {v:?}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }

    let mut j = unlearn::util::json::Json::obj();
    j.set("bench", "wal")
        .set("records", n)
        .set("append_ns_per_record", ns(append.mean) / n as f64)
        .set("scan_ns_per_record", ns(scan.mean) / n as f64)
        .set(perf::WAL_RECOVERY_METRIC, recovery_ns)
        .set("recovery_pending_jobs", PENDING)
        // replay now lexes each event line with the zero-alloc scanner
        // (util::json_scan) instead of building a Json tree per line;
        // the gated metric above is where the improvement shows up.
        .set("recovery_parser", "json_scan")
        .set("bytes_per_record", 32)
        .set("schema", 3);
    match perf::record_first_baseline_for(
        &baseline,
        perf::WAL_RECOVERY_METRIC,
        &j,
    )
    .expect("write baseline")
    {
        perf::BaselineDisposition::Recorded => {
            println!(
                "wal recovery baseline: first measured run RECORDED at {} \
                 — the >{:.0}% regression gate bites from the next run",
                baseline.display(),
                perf::DEFAULT_MAX_REGRESSION * 100.0
            );
            println!("{}", j.pretty());
        }
        perf::BaselineDisposition::AlreadyMeasured => emit_json("wal", &j),
    }
}

fn main() {
    if json_mode() {
        return json_main();
    }
    // ---- Table 7: footprint at the paper's record counts --------------
    header(
        "Table 7 — WAL overhead",
        &["Records", "Bytes/record", "Total bytes"],
    );
    for records in [400u64, 800_000] {
        println!(
            "{records} | 32 | {} ({})",
            records * 32,
            fmt_bytes(records * 32)
        );
    }
    println!("(paper: 400 records -> 12,800 B; 8e5 -> ~25.6 MB)");

    // ---- measured append/scan performance -----------------------------
    header(
        "WAL throughput (measured)",
        &["Operation", "Records", "Time", "Per record"],
    );
    let n = 10_000u32;
    let dir = tempdir("bench-wal");
    let st = time_it(0, 1, || {
        let mut w = WalWriter::create(&dir.join("a"), 4096, None).unwrap();
        for i in 0..n {
            w.append(&rec(i)).unwrap();
        }
        w.finish().unwrap();
    });
    println!(
        "append (toy hash) | {n} | {} | {}",
        fmt_secs(st.mean),
        fmt_secs(st.mean / n as f64)
    );
    let st = time_it(0, 1, || {
        let mut w = WalWriter::create(
            &dir.join("b"),
            4096,
            Some(b"production-key".to_vec()),
        )
        .unwrap();
        for i in 0..n {
            w.append(&rec(i)).unwrap();
        }
        w.finish().unwrap();
    });
    println!(
        "append (HMAC mode) | {n} | {} | {}",
        fmt_secs(st.mean),
        fmt_secs(st.mean / n as f64)
    );
    let st = time_it(1, 3, || integrity::scan(&dir.join("a"), None).unwrap());
    println!(
        "integrity scan | {n} | {} | {}",
        fmt_secs(st.mean),
        fmt_secs(st.mean / n as f64)
    );
    let rep = integrity::scan(&dir.join("a"), None).unwrap();
    assert!(rep.ok());
    println!("\nscan result ok={} records={}", rep.ok(), rep.records);
}
