//! **Figure 2** as a benchmark: the determinism/replay CI gate, with
//! per-phase latencies (train-train equality, checkpoint-replay
//! equality, WAL scan) — what a deployment pays before enabling
//! forgetting.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::runtime::Runtime;

fn main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("bench-cigate").join("run"),
        accum: 2,
        checkpoint_every: 4,
        warmup: 2,
        ..Default::default()
    };

    if json_mode() {
        let gate_steps = 6u32;
        let t0 = std::time::Instant::now();
        let report =
            unlearn::cigate::run_gate(&rt, &cfg, &corpus, gate_steps).unwrap();
        let mut j = unlearn::util::json::Json::obj();
        j.set("bench", "cigate")
            .set("gate_steps", gate_steps)
            .set("total_ns", bench_util::ns(t0.elapsed().as_secs_f64()))
            .set("pass", report.pass())
            .set("schema", 1);
        emit_json("cigate", &j);
        assert!(report.pass(), "CI gate must pass on this pinned stack");
        return;
    }

    header("Figure 2 — CI gate (measured)", &["Gate steps", "Total", "Pass"]);
    for gate_steps in [6u32, 10] {
        let t0 = std::time::Instant::now();
        let report =
            unlearn::cigate::run_gate(&rt, &cfg, &corpus, gate_steps).unwrap();
        println!(
            "{gate_steps} | {} | {}",
            fmt_secs(t0.elapsed().as_secs_f64()),
            report.pass()
        );
        assert!(report.pass(), "CI gate must pass on this pinned stack");
    }
    println!("\n(gate = 2x train + 1x replay + WAL scan; Alg. 5.1)");
}
