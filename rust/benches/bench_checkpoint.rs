//! Regenerates **Table 3** (storage/latency budgets): checkpoint sizes
//! by formula at the paper's scales + measured sizes and save/load
//! latency at toy scale, and the worst-case replay bound K·t_step.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::checkpoint::{CheckpointStore, TrainState};
use unlearn::util::rng::SplitMix64;
use unlearn::util::tempdir;

fn state(n: usize, seed: u64) -> TrainState {
    let mut r = SplitMix64::new(seed);
    let mut s =
        TrainState::zeros_like((0..n).map(|_| r.normal() as f32).collect());
    s.m = (0..n).map(|_| r.normal() as f32 * 0.01).collect();
    s.v = (0..n).map(|_| (r.normal() as f32).powi(2)).collect();
    s
}

fn json_main() {
    let n = 120_064usize;
    let dir = tempdir("bench-ckpt-json");
    let store = CheckpointStore::open(&dir, 4).unwrap();
    let mut s = state(n, 1);
    let save = time_it(1, 3, || {
        s.logical_step += 1;
        store.save_full(&s).unwrap()
    });
    let step = s.logical_step;
    let load = time_it(1, 3, || store.load_full(step).unwrap());
    let bytes = store.full_checkpoint_bytes(step).unwrap();

    // CAS dedup: consecutive checkpoints where only the weights moved
    // (micro-checkpoint cadence, adapter-only phases, laundering with a
    // short contaminated tail) share their optimizer blobs.  Also time
    // the fully-redundant save — the dedup fast path writes zero
    // tensor bytes.
    let ddir = tempdir("bench-ckpt-dedup");
    let dstore = CheckpointStore::open(&ddir, 16).unwrap();
    let mut d = state(n, 7);
    d.logical_step = 1;
    dstore.save_full(&d).unwrap();
    d.logical_step = 2;
    d.params = state(n, 8).params; // optimizer tensors unchanged
    dstore.save_full(&d).unwrap();
    let resave = time_it(1, 3, || {
        // identical content: manifest rewrite only, zero blob writes
        dstore.save_full(&d).unwrap()
    });
    let stats = dstore.stats().unwrap();

    let mut j = unlearn::util::json::Json::obj();
    j.set("bench", "checkpoint")
        .set("params", n)
        .set("save_full_ns", ns(save.mean))
        .set("load_full_verified_ns", ns(load.mean))
        .set("save_full_dedup_hit_ns", ns(resave.mean))
        .set("bytes_on_disk", bytes)
        .set("cas_objects", stats.objects)
        .set("cas_object_bytes", stats.object_bytes)
        .set("cas_referenced_bytes", stats.referenced_bytes)
        .set("dedup_ratio", stats.dedup_ratio)
        .set("schema", 2);
    emit_json("checkpoint", &j);
}

fn main() {
    if json_mode() {
        return json_main();
    }
    header(
        "Table 3 — storage budgets (formula; FP32 here, paper uses FP16 \
         weights + FP32 moments)",
        &["Artifact", "Formula", "1.3B params", "13B params"],
    );
    let gb = |x: f64| format!("{:.1} GB", x / 1e9);
    for (name, bytes_per_param) in [
        ("Full checkpoint (w+opt)", 4.0 + 8.0),
        ("Micro-checkpoint (w only)", 4.0),
        ("Dense delta per-step", 4.0),
    ] {
        println!(
            "{name} | ≈{bytes_per_param}P B | {} | {}",
            gb(1.3e9 * bytes_per_param),
            gb(13e9 * bytes_per_param)
        );
    }
    println!("WAL | 32 B × #microbatches | {} (8e5 rec) | proportional",
             fmt_bytes(800_000 * 32));

    header(
        "Checkpoint store — measured (toy scale)",
        &["Params", "On-disk", "save_full", "load_full (verified)"],
    );
    let dir = tempdir("bench-ckpt");
    for n in [120_064usize, 1_000_000] {
        let store = CheckpointStore::open(&dir.join(format!("{n}")), 4).unwrap();
        let mut s = state(n, n as u64);
        let save = time_it(1, 3, || {
            s.logical_step += 1; // fresh dir each time
            store.save_full(&s).unwrap()
        });
        let step = s.logical_step;
        let load = time_it(1, 3, || store.load_full(step).unwrap());
        let bytes = store.full_checkpoint_bytes(step).unwrap();
        println!(
            "{n} | {} | {} | {}",
            fmt_bytes(bytes),
            fmt_secs(save.mean),
            fmt_secs(load.mean)
        );
    }

    header(
        "CAS dedup (two checkpoints, optimizer tensors unchanged)",
        &["Objects", "Stored", "Referenced", "Dedup ratio"],
    );
    let ddir = tempdir("bench-ckpt-dedup-h");
    let dstore = CheckpointStore::open(&ddir, 16).unwrap();
    let mut d = state(120_064, 7);
    d.logical_step = 1;
    dstore.save_full(&d).unwrap();
    d.logical_step = 2;
    d.params = state(120_064, 8).params;
    dstore.save_full(&d).unwrap();
    let st = dstore.stats().unwrap();
    println!(
        "{} | {} | {} | {:.3}",
        st.objects,
        fmt_bytes(st.object_bytes),
        fmt_bytes(st.referenced_bytes),
        st.dedup_ratio
    );

    header(
        "Worst-case replay bound (Table 3 last row)",
        &["K (ckpt cadence)", "t_step (measured proxy)", "bound K·t_step"],
    );
    // t_step proxy: measured from the e2e run's metrics when present;
    // here we use a representative 0.25 s/step for the tiny model on
    // this host (see bench_replay for the measured value).
    for k in [25u32, 50, 100] {
        let t_step = 0.25;
        println!("{k} | {} | {}", fmt_secs(t_step), fmt_secs(k as f64 * t_step));
    }
}
