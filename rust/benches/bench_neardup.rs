//! Near-duplicate substrate benchmark (the FAISS/SimHash role of
//! Alg. A.6): index build, banded vs exact query, closure expansion at
//! the paper's toy corpus scale.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::data::corpus::{Corpus, CorpusConfig};
use unlearn::neardup::closure::build_index;
use unlearn::neardup::{expand_closure, simhash_tokens, ClosureParams};

fn main() {
    let corpus = Corpus::generate(CorpusConfig::default());

    if json_mode() {
        let build = time_it(1, 3, || build_index(&corpus));
        let idx = build_index(&corpus);
        let sig = simhash_tokens(&corpus.by_id(0).unwrap().tokens);
        let query = time_it(5, 50, || idx.query(sig, 3));
        let req = corpus.user_samples(0);
        let expand = time_it(1, 5, || {
            expand_closure(&corpus, &idx, &req, ClosureParams::default())
        });
        let mut j = unlearn::util::json::Json::obj();
        j.set("bench", "neardup")
            .set("docs", corpus.len())
            .set("index_build_ns", ns(build.mean))
            .set("banded_query_ns", ns(query.mean))
            .set("closure_expand_ns", ns(expand.mean))
            .set("schema", 1);
        emit_json("neardup", &j);
        return;
    }
    println!("corpus: {} samples", corpus.len());

    header("SimHash index — measured", &["Operation", "Latency"]);
    let st = time_it(1, 3, || build_index(&corpus));
    println!("build index ({} docs) | {}", corpus.len(), fmt_secs(st.mean));
    let idx = build_index(&corpus);

    let sig = simhash_tokens(&corpus.by_id(0).unwrap().tokens);
    let st = time_it(5, 50, || idx.query(sig, 3));
    println!("banded query (radius 3) | {}", fmt_secs(st.mean));
    let st = time_it(5, 50, || idx.query(sig, 20));
    println!("verified scan (radius 20) | {}", fmt_secs(st.mean));
    let st = time_it(5, 50, || idx.query_exact(sig, 3));
    println!("brute force (radius 3) | {}", fmt_secs(st.mean));

    // banded recall vs brute force at the guaranteed radius
    let mut agree = 0;
    let mut total = 0;
    for id in (0..corpus.len() as u64).step_by(97) {
        let s = simhash_tokens(&corpus.by_id(id).unwrap().tokens);
        let a = idx.query(s, 3);
        let b = idx.query_exact(s, 3);
        agree += (a == b) as usize;
        total += 1;
    }
    println!("banded==exact at radius 3: {agree}/{total}");

    header(
        "Closure expansion (Alg. A.6) — measured",
        &["Request", "Closure size", "Expanded", "Latency"],
    );
    for user in [0u32, 5, 50] {
        let req = corpus.user_samples(user);
        let st = time_it(1, 5, || {
            expand_closure(&corpus, &idx, &req, ClosureParams::default())
        });
        let cl = expand_closure(&corpus, &idx, &req, ClosureParams::default());
        println!(
            "user {user} ({} docs) | {} | {} | {}",
            req.len(),
            cl.ids.len(),
            cl.expanded.len(),
            fmt_secs(st.mean)
        );
    }
}
