//! Fleet scaling benchmark: the SISA-style `1/N` claim, measured.
//!
//! Trains fleets at N ∈ {1, 4, 16} over the SAME corpus (per-shard step
//! budgets scaled to the shard's corpus share — constant epochs), then
//! forgets one fixed user on each and records forget wall-time plus
//! **replay-steps/request** (microbatch updates applied fleet-wide per
//! forget).  N = 1 is the monolithic baseline; the per-request replay
//! work must shrink monotonically as N grows, because a forget touches
//! only `shard(u)` and that shard's tail is `~1/N` of the run.
//!
//! `-- --json` gates `fleet_replay_steps_per_request` (a deterministic
//! count, machine-independent) against the committed `BENCH_fleet.json`
//! through the same >20% cigate rule as the replay bench, with
//! first-measured-run promotion over the null placeholder.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use unlearn::cigate::perf;
use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::fleet::{Fleet, FleetConfig};
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::shard::ShardSpec;
use unlearn::util::json::Json;

/// Shard count whose replay-steps/request is the gated metric (the
/// middle of the sweep: sharded, but not so fine that per-shard tails
/// hit the minimum step clamp).
const GATE_N: u32 = 4;

struct Probe {
    n_shards: u32,
    forget_secs: f64,
    replay_steps: u64,
    shards_touched: usize,
}

fn run_probe(rt: &Runtime, n_shards: u32, tag: &str) -> Probe {
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let cfg = FleetConfig {
        root: unlearn::util::tempdir(&format!("{tag}-{n_shards}")),
        spec: ShardSpec {
            n_shards,
            salt: 0xF1EE7,
        },
        base: RunConfig {
            steps: 12,
            accum: 2,
            checkpoint_every: 4,
            checkpoint_keep: 16,
            // a small ring forces the replay path — the metric under
            // the gate is replay work, not ring luck
            ring_window: 2,
            warmup: 4,
            ..Default::default()
        },
        scale_steps: true,
        launder_policy: Default::default(),
        auto_launder: false,
    };
    let mut fleet = Fleet::train(rt, cfg, corpus).expect("fleet train");
    // the same user on every topology: apples-to-apples forget work
    let req = ForgetRequest {
        id: format!("bench-fleet-{n_shards}"),
        user: Some(2),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    let t0 = std::time::Instant::now();
    let out = fleet.forget(&req).expect("fleet forget");
    let forget_secs = t0.elapsed().as_secs_f64();
    assert!(out.outcomes[0].executed(), "forget must commit");
    Probe {
        n_shards,
        forget_secs,
        replay_steps: out.applied_steps_total,
        shards_touched: out.shards_touched,
    }
}

fn json_main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let probes: Vec<Probe> = [1u32, 4, 16]
        .iter()
        .map(|&n| run_probe(&rt, n, "bench-fleet-json"))
        .collect();
    let gated = probes
        .iter()
        .find(|p| p.n_shards == GATE_N)
        .map(|p| p.replay_steps as f64)
        .expect("gate point measured");
    let monotone = probes
        .windows(2)
        .all(|w| w[1].replay_steps <= w[0].replay_steps);

    // fail-closed gate against the committed baseline
    let baseline = bench_json_path("fleet");
    match perf::check_fleet(&baseline, gated, perf::DEFAULT_MAX_REGRESSION) {
        Ok(v) => println!("fleet perf gate: {v:?}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }

    let mut j = Json::obj();
    j.set("bench", "fleet")
        .set(perf::FLEET_METRIC, gated)
        .set("gate_n_shards", GATE_N)
        .set("monotone_reduction", monotone)
        .set("schema", 1);
    for p in &probes {
        j.set(&format!("n{}_forget_ns", p.n_shards), ns(p.forget_secs))
            .set(
                &format!("n{}_replay_steps_per_request", p.n_shards),
                p.replay_steps,
            )
            .set(
                &format!("n{}_shards_touched", p.n_shards),
                p.shards_touched,
            );
    }
    for p in &probes {
        println!(
            "N={}: forget {} | replay steps/request {} | shards touched {}",
            p.n_shards,
            fmt_secs(p.forget_secs),
            p.replay_steps,
            p.shards_touched
        );
    }
    if !monotone {
        eprintln!(
            "WARNING: replay steps/request did not reduce monotonically \
             with N — recorded for the trajectory, not fabricated away"
        );
    }
    match perf::record_first_baseline_for(&baseline, perf::FLEET_METRIC, &j)
        .expect("write baseline")
    {
        perf::BaselineDisposition::Recorded => {
            println!(
                "fleet perf baseline: first measured run RECORDED at {} — \
                 the >{:.0}% regression gate bites from the next run",
                baseline.display(),
                perf::DEFAULT_MAX_REGRESSION * 100.0
            );
            println!("{}", j.pretty());
        }
        perf::BaselineDisposition::AlreadyMeasured => emit_json("fleet", &j),
    }
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    header(
        "Fleet scaling — forget cost vs shard count (measured)",
        &[
            "N shards",
            "Forget wall",
            "Replay steps/request",
            "Shards touched",
        ],
    );
    for &n in &[1u32, 4, 16] {
        let p = run_probe(&rt, n, "bench-fleet");
        println!(
            "{} | {} | {} | {}",
            p.n_shards,
            fmt_secs(p.forget_secs),
            p.replay_steps,
            p.shards_touched
        );
    }
}
