//! Replica serving-plane benchmark: the **erasure-propagation SLA**.
//!
//! Trains a small fleet, attaches read replicas to the forgotten
//! user's shard, then measures wall time from forget submission until
//! EVERY replica serves the laundered (clean) lineage — the number a
//! regulator actually cares about, covering the forget commit, the
//! launder replay + atomic lineage swap, and the replicas' CAS
//! re-sync.  Also records the transfer accounting that makes the SLA
//! cheap: content addressing means a launder re-sync ships only the
//! rewritten tensors (asserted strictly below the cold-mirror bill).
//!
//! `-- --json` gates `erasure_propagation_ms` against the committed
//! `BENCH_replica.json` through the same >20% cigate rule as the
//! other benches, with first-measured-run promotion over the null
//! placeholder.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::time::Instant;

use unlearn::cigate::perf;
use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, LaunderPolicy, Urgency};
use unlearn::fleet::{Fleet, FleetConfig};
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::shard::ShardSpec;
use unlearn::util::json::Json;

/// Replicas attached to the forgotten user's shard — the SLA is the
/// max over them, so more than one makes the fan-out real.
const N_REPLICAS: usize = 2;

const FORGET_USER: u32 = 2;

struct Probe {
    cold_bytes: u64,
    cold_objects: usize,
    resync_bytes: u64,
    resync_objects: usize,
    reused_objects: usize,
    /// Forget submit → every replica clean (the gated SLA).
    propagation_ms: f64,
    /// Launder trigger → every replica clean (the `fleet_status` view).
    launder_to_clean_ms: Option<f64>,
}

fn run_probe(rt: &Runtime, tag: &str) -> Probe {
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let cfg = FleetConfig {
        root: unlearn::util::tempdir(tag),
        spec: ShardSpec {
            n_shards: 2,
            salt: 0xF1EE7,
        },
        base: RunConfig {
            steps: 8,
            accum: 2,
            checkpoint_every: 4,
            checkpoint_keep: 16,
            ring_window: 2,
            warmup: 2,
            ..Default::default()
        },
        scale_steps: false,
        // any pending forgotten set makes laundering due immediately:
        // the bench measures propagation, not the trigger policy
        launder_policy: LaunderPolicy {
            min_extra_replay_records: 0,
        },
        auto_launder: false,
    };
    let mut fleet = Fleet::train(rt, cfg, corpus).expect("fleet train");
    let shard = fleet.spec.assign(FORGET_USER);
    let (mut cold_bytes, mut cold_objects) = (0u64, 0usize);
    for r in 0..N_REPLICAS {
        let dir = unlearn::util::tempdir(&format!("{tag}-replica-{r}"));
        let (_, stats) = fleet.attach_replica(shard, &dir).expect("attach");
        cold_bytes += stats.bytes_pulled;
        cold_objects += stats.objects_pulled;
    }
    let req = ForgetRequest {
        id: "bench-replica".to_string(),
        user: Some(FORGET_USER),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    let t0 = Instant::now();
    let out = fleet.forget(&req).expect("fleet forget");
    assert!(out.outcomes[0].executed(), "forget must commit");
    let laundered = fleet.launder_due("bench-replica");
    assert!(
        laundered
            .iter()
            .any(|(s, r)| *s == shard && matches!(r, Ok(o) if o.executed)),
        "the forgotten user's shard must launder"
    );
    let propagation_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (mut resync_bytes, mut resync_objects, mut reused_objects) =
        (0u64, 0usize, 0usize);
    for att in fleet.replicas() {
        assert_eq!(
            att.replica.lag().expect("source generation"),
            0,
            "every replica must serve the laundered lineage"
        );
        let s = att.replica.last_sync().expect("synced during launder");
        resync_bytes += s.bytes_pulled;
        resync_objects += s.objects_pulled;
        reused_objects += s.objects_reused;
    }
    assert!(
        resync_bytes < cold_bytes,
        "dedup bound: launder re-sync ({resync_bytes} B) must ship \
         strictly fewer bytes than the cold mirrors ({cold_bytes} B)"
    );
    Probe {
        cold_bytes,
        cold_objects,
        resync_bytes,
        resync_objects,
        reused_objects,
        propagation_ms,
        launder_to_clean_ms: fleet.last_propagation_ms,
    }
}

fn json_main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let p = run_probe(&rt, "bench-replica-json");

    // fail-closed gate against the committed baseline
    let baseline = bench_json_path("replica");
    match perf::check_replica(
        &baseline,
        p.propagation_ms,
        perf::DEFAULT_MAX_REGRESSION,
    ) {
        Ok(v) => println!("replica perf gate: {v:?}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }

    let mut j = Json::obj();
    j.set("bench", "replica")
        .set(perf::REPLICA_METRIC, p.propagation_ms)
        .set(
            "launder_to_clean_ms",
            p.launder_to_clean_ms.map(Json::from).unwrap_or(Json::Null),
        )
        .set("replicas", N_REPLICAS)
        .set("cold_sync_bytes", p.cold_bytes)
        .set("cold_sync_objects", p.cold_objects)
        .set("launder_resync_bytes", p.resync_bytes)
        .set("launder_resync_objects", p.resync_objects)
        .set("launder_reused_objects", p.reused_objects)
        .set("schema", 1);
    match perf::record_first_baseline_for(&baseline, perf::REPLICA_METRIC, &j)
        .expect("write baseline")
    {
        perf::BaselineDisposition::Recorded => {
            println!(
                "replica baseline: first measured run RECORDED at {} — the \
                 >{:.0}% regression gate bites from the next run",
                baseline.display(),
                perf::DEFAULT_MAX_REGRESSION * 100.0
            );
            println!("{}", j.pretty());
        }
        perf::BaselineDisposition::AlreadyMeasured => emit_json("replica", &j),
    }
}

fn main() {
    if json_mode() {
        return json_main();
    }
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let p = run_probe(&rt, "bench-replica");
    header(
        "Erasure propagation (forget submit → every replica clean)",
        &["metric", "value"],
    );
    println!(
        "propagation | {}",
        fmt_secs(p.propagation_ms / 1e3)
    );
    if let Some(ms) = p.launder_to_clean_ms {
        println!("launder→clean | {}", fmt_secs(ms / 1e3));
    }
    println!(
        "cold sync | {} in {} objects",
        fmt_bytes(p.cold_bytes),
        p.cold_objects
    );
    println!(
        "launder re-sync | {} in {} objects ({} reused)",
        fmt_bytes(p.resync_bytes),
        p.resync_objects,
        p.reused_objects
    );
}
