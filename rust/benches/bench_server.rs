//! Admin-plane transport throughput: thread-per-connection (the
//! pre-event-loop architecture, reproduced inline with blocking reads)
//! vs the shared nonblocking poll loop, at 1/32/256 concurrent
//! connections.  The dispatch closure is stateless and mirrors the
//! servers' lazy hot path over the public scanner API, so the A/B
//! isolates the connection layer + zero-alloc JSON parse — no WAL
//! fsyncs or job execution in the measured path.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};

use unlearn::cigate::perf;
use unlearn::server::serve_event_loop;
use unlearn::util::json::Json;
use unlearn::util::json_scan;
use unlearn::util::rng::philox_u64;

/// Philox key for the request mix — same counter stream in both modes,
/// so the two transports see byte-identical workloads.
const SEED: u64 = 0xBE9C_5E4E_AD41_0007;

/// Lazily-scanned hot dispatch (submit/poll/status), shaped like the
/// real servers' hot path: extract fields with the zero-alloc scanner,
/// answer from them, never build a tree.
fn dispatch_bench(line: &str) -> Json {
    let b = line.as_bytes();
    let mut out = Json::obj();
    let op = match json_scan::scan_str(b, "op") {
        Ok(Some(op)) => op,
        _ => {
            out.set("ok", false).set("error", "bad json");
            return out;
        }
    };
    match op.as_ref() {
        "submit" => {
            let id = json_scan::scan_str(b, "id")
                .ok()
                .flatten()
                .map(|s| s.into_owned())
                .unwrap_or_default();
            let user =
                json_scan::scan_u64(b, "user").ok().flatten().unwrap_or(0);
            let samples = json_scan::scan_u64s(b, "sample_ids")
                .ok()
                .flatten()
                .unwrap_or_default();
            out.set("ok", true)
                .set("job", format!("job-{id}"))
                .set("user", user)
                .set("samples", samples.len() as u64);
        }
        "poll" => {
            let job = json_scan::scan_str(b, "job")
                .ok()
                .flatten()
                .map(|s| s.into_owned())
                .unwrap_or_default();
            out.set("ok", true).set("job", job).set("state", "queued");
        }
        "status" => {
            out.set("ok", true).set("queued_jobs", 0u64);
        }
        _ => {
            out.set("ok", false).set("error", "unknown op");
        }
    }
    out
}

/// Deterministic request line for global request counter `ctr`.
fn request_line(ctr: u64) -> String {
    match philox_u64(SEED, ctr) % 4 {
        0 => format!(
            r#"{{"op":"submit","id":"req-{ctr}","user":{},"sample_ids":[{},{}]}}"#,
            philox_u64(SEED, ctr ^ 0x1000) % 1000,
            philox_u64(SEED, ctr ^ 0x2000) % 4096,
            philox_u64(SEED, ctr ^ 0x3000) % 4096,
        ),
        1 => format!(r#"{{"op":"poll","job":"job-req-{}"}}"#, ctr / 2),
        _ => r#"{"op":"status"}"#.to_string(),
    }
}

/// Synchronous request/response clients: `conns` connections, each
/// issuing `per_conn` round-trips, then closing (EOF to the server).
fn run_clients(addr: SocketAddr, conns: usize, per_conn: usize) {
    std::thread::scope(|s| {
        for c in 0..conns {
            s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader =
                    BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut resp = String::new();
                for i in 0..per_conn {
                    let ctr = (c * per_conn + i) as u64;
                    let line = request_line(ctr);
                    writer.write_all(line.as_bytes()).unwrap();
                    writer.write_all(b"\n").unwrap();
                    resp.clear();
                    reader.read_line(&mut resp).unwrap();
                    assert!(
                        resp.contains("\"ok\":true"),
                        "bad response to {line}: {resp}"
                    );
                }
            });
        }
    });
}

/// The old architecture's per-connection handler: blocking buffered
/// reads, one thread per accepted socket.
fn serve_blocking_conn(stream: TcpStream) {
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut buf = String::new();
    loop {
        buf.clear();
        match reader.read_line(&mut buf) {
            Ok(0) | Err(_) => return,
            Ok(_) => {
                let resp = dispatch_bench(buf.trim());
                if writeln!(writer, "{}", resp.encode()).is_err() {
                    return;
                }
            }
        }
    }
}

/// Measured request phase under thread-per-connection.  Returns secs.
fn run_threaded(conns: usize, per_conn: usize) -> f64 {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let local = listener.local_addr().unwrap();
    let shutdown = AtomicBool::new(false);
    let mut elapsed = 0.0;
    std::thread::scope(|s| {
        let shutdown = &shutdown;
        let acceptor = s.spawn(move || {
            std::thread::scope(|cs| {
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { break };
                    cs.spawn(move || serve_blocking_conn(stream));
                }
            });
        });
        let st = time_it(0, 1, || run_clients(local, conns, per_conn));
        elapsed = st.mean;
        shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(local); // poke the blocking acceptor
        let _ = acceptor.join();
    });
    elapsed
}

/// Measured request phase under the shared event loop.  Returns secs.
fn run_event_loop(conns: usize, per_conn: usize) -> f64 {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let local = listener.local_addr().unwrap();
    let shutdown = AtomicBool::new(false);
    let mut elapsed = 0.0;
    std::thread::scope(|s| {
        let shutdown = &shutdown;
        let looper = s.spawn(move || {
            serve_event_loop(listener, shutdown, dispatch_bench).unwrap();
        });
        let st = time_it(0, 1, || run_clients(local, conns, per_conn));
        elapsed = st.mean;
        shutdown.store(true, Ordering::SeqCst);
        let _ = looper.join();
    });
    elapsed
}

/// Sweep both modes across the concurrency ladder; returns rows of
/// (conns, total_requests, threaded_secs, event_loop_secs).
fn sweep(total_target: usize) -> Vec<(usize, usize, f64, f64)> {
    let mut rows = Vec::new();
    for &conns in &[1usize, 32, 256] {
        let per_conn = (total_target / conns).max(1);
        let total = conns * per_conn;
        let thr = run_threaded(conns, per_conn);
        let evt = run_event_loop(conns, per_conn);
        rows.push((conns, total, thr, evt));
    }
    rows
}

fn json_main() {
    const TOTAL_TARGET: usize = 2048;
    let rows = sweep(TOTAL_TARGET);

    let mut j = Json::obj();
    j.set("bench", "server")
        .set("total_requests_per_config", TOTAL_TARGET as u64)
        .set("schema", 1);
    let mut gate_ns = f64::NAN;
    for &(conns, total, thr, evt) in &rows {
        j.set(
            &format!("threaded_c{conns}_ns_per_request"),
            ns(thr) / total as f64,
        )
        .set(
            &format!("threaded_c{conns}_requests_per_s"),
            total as f64 / thr,
        )
        .set(
            &format!("event_loop_c{conns}_ns_per_request"),
            ns(evt) / total as f64,
        )
        .set(
            &format!("event_loop_c{conns}_requests_per_s"),
            total as f64 / evt,
        );
        if conns == 32 {
            gate_ns = ns(evt) / total as f64;
        }
    }
    j.set(perf::SERVER_METRIC, gate_ns);

    // fail-closed gate against the committed baseline (record-only
    // while the committed file is a placeholder without the metric)
    let baseline = bench_json_path("server");
    match perf::check_server(&baseline, gate_ns, perf::DEFAULT_MAX_REGRESSION)
    {
        Ok(v) => println!("server perf gate: {v:?}"),
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }
    match perf::record_first_baseline_for(&baseline, perf::SERVER_METRIC, &j)
        .expect("write baseline")
    {
        perf::BaselineDisposition::Recorded => {
            println!(
                "server baseline: first measured run RECORDED at {} — the \
                 >{:.0}% regression gate bites from the next run",
                baseline.display(),
                perf::DEFAULT_MAX_REGRESSION * 100.0
            );
            println!("{}", j.pretty());
        }
        perf::BaselineDisposition::AlreadyMeasured => emit_json("server", &j),
    }
}

fn main() {
    if json_mode() {
        return json_main();
    }
    header(
        "Admin-plane transport (thread-per-conn vs event loop)",
        &["Conns", "Requests", "Threaded", "Event loop", "Evt ns/req"],
    );
    let rows = sweep(2048);
    for (conns, total, thr, evt) in rows {
        println!(
            "{conns} | {total} | {} | {} | {:.0}",
            fmt_secs(thr),
            fmt_secs(evt),
            ns(evt) / total as f64
        );
    }
    println!(
        "\n(both modes run the same lazily-scanned dispatch; the delta is \
         the connection layer)"
    );
}
