//! Regenerates the **Table 6** audit pipeline with measured latencies:
//! MIA AUC (+bootstrap CI), canary exposure, targeted extraction, fuzzy
//! recall and retain PPL over a freshly trained toy model.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::*;

use std::collections::HashSet;

use unlearn::audit::{self, AuditContext, ModelView};
use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

fn main() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("bench-audits"),
        steps: 10,
        accum: 2,
        checkpoint_every: 5,
        warmup: 3,
        ..Default::default()
    };
    let out = Trainer::new(&rt, cfg, corpus.clone()).train(|_| false).unwrap();

    if json_mode() {
        let forget: Vec<u64> = corpus.user_samples(0);
        let fset: HashSet<u64> = forget.iter().copied().collect();
        let (retain_ids, eval_ids) = harness::audit_splits(&corpus, &fset, 5);
        let ctx = AuditContext {
            rt: &rt,
            corpus: &corpus,
            forget_ids: &forget,
            retain_ids: &retain_ids,
            eval_ids: &eval_ids,
            baseline_ppl: None,
            thresholds: Default::default(),
            seed: 5,
        };
        let view = ModelView::Base(&out.state.params);
        let t0 = std::time::Instant::now();
        let rep = audit::run_audits(&ctx, view).unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let mut j = unlearn::util::json::Json::obj();
        j.set("bench", "audits")
            .set("full_suite_ns", ns(elapsed))
            .set("mia_auc", rep.mia_auc)
            .set("retain_ppl", rep.retain_ppl)
            .set("schema", 1);
        emit_json("audits", &j);
        return;
    }

    let forget: Vec<u64> = corpus.user_samples(0);
    let fset: HashSet<u64> = forget.iter().copied().collect();
    let (retain_ids, eval_ids) = harness::audit_splits(&corpus, &fset, 5);
    let ctx = AuditContext {
        rt: &rt,
        corpus: &corpus,
        forget_ids: &forget,
        retain_ids: &retain_ids,
        eval_ids: &eval_ids,
        baseline_ppl: None,
        thresholds: Default::default(),
        seed: 5,
    };
    let view = ModelView::Base(&out.state.params);

    header(
        "Table 6 pipeline — per-audit latency (measured)",
        &["Audit", "Latency", "Value"],
    );
    let st = time_it(0, 2, || audit::mia::mia_auc(&ctx, view).unwrap());
    let mia = audit::mia::mia_auc(&ctx, view).unwrap();
    println!(
        "MIA AUC + bootstrap CI | {} | {:.3} (CI {:.3}-{:.3})",
        fmt_secs(st.mean),
        mia.auc,
        mia.ci95.0,
        mia.ci95.1
    );
    let st = time_it(0, 2, || audit::canary::exposure(&ctx, view).unwrap());
    let (mu, sigma) = audit::canary::exposure(&ctx, view).unwrap();
    println!(
        "Canary exposure (64 cands) | {} | mu {:+.3} sigma {:.3} bits",
        fmt_secs(st.mean),
        mu,
        sigma
    );
    let st =
        time_it(0, 2, || audit::extraction::extraction_rate(&ctx, view).unwrap());
    let ex = audit::extraction::extraction_rate(&ctx, view).unwrap();
    println!(
        "Targeted extraction (greedy) | {} | {:.1}%",
        fmt_secs(st.mean),
        ex * 100.0
    );
    let st = time_it(0, 2, || audit::fuzzy::fuzzy_recall(&ctx, view).unwrap());
    let fz = audit::fuzzy::fuzzy_recall(&ctx, view).unwrap();
    println!("Fuzzy recall AUC | {} | {:.3}", fmt_secs(st.mean), fz);
    let st = time_it(0, 2, || audit::utility::retain_ppl(&ctx, view).unwrap());
    let ppl = audit::utility::retain_ppl(&ctx, view).unwrap();
    println!("Retain PPL | {} | {:.2}", fmt_secs(st.mean), ppl);

    let st = time_it(0, 1, || audit::run_audits(&ctx, view).unwrap());
    println!("\nfull audit suite: {}", fmt_secs(st.mean));
}
