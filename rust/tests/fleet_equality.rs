//! The sharded-fleet acceptance proof: forgetting user `u` on the
//! fleet is **bit-identical to retraining `shard(u)` on its retain
//! set** (params + optimizer state — the per-shard G1 guarantee), the
//! cross-shard scatter erases near-duplicates from THEIR owning shards,
//! and every non-owning shard is provably untouched — serving state
//! bit-equal AND its entire run directory (WAL, IdMap, pins, CAS
//! objects, lineage manifests, signed manifest) byte-for-byte
//! unchanged.

use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::data::corpus::SampleKind;
use unlearn::fleet::server::{dispatch_fleet, drain_fleet_once, FleetCtx};
use unlearn::fleet::{Fleet, FleetConfig};
use unlearn::harness;
use unlearn::replay::replay_filter;
use unlearn::runtime::Runtime;
use unlearn::shard::ShardSpec;

const STEPS: u32 = 8;
const CKPT_EVERY: u32 = 4;

fn base_cfg() -> RunConfig {
    RunConfig {
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 2,
        ..Default::default()
    }
}

/// Every file under `root`, relative path → bytes (the byte-identity
/// witness for untouched shards).
fn dir_bytes(root: &Path) -> BTreeMap<PathBuf, Vec<u8>> {
    fn walk(dir: &Path, root: &Path, out: &mut BTreeMap<PathBuf, Vec<u8>>) {
        for e in std::fs::read_dir(dir).unwrap() {
            let e = e.unwrap();
            let path = e.path();
            if e.file_type().unwrap().is_dir() {
                walk(&path, root, out);
            } else {
                out.insert(
                    path.strip_prefix(root).unwrap().to_path_buf(),
                    std::fs::read(&path).unwrap(),
                );
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

#[test]
fn fleet_forget_is_shard_scoped_and_bit_identical_to_shard_retrain() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let mut corpus = harness::small_corpus(rt.manifest.seq_len);
    let spec = ShardSpec {
        n_shards: 4,
        salt: 0x51AB,
    };

    // Re-own one near-duplicate to a user on a DIFFERENT shard than its
    // original: forgetting the original's owner must scatter to the
    // dup's shard too (ownership-routed closure), proving the fleet
    // does not silently drop cross-shard paraphrases.
    let (dup_idx, of) = corpus
        .samples
        .iter()
        .enumerate()
        .find_map(|(i, s)| match s.kind {
            SampleKind::NearDup { of } => Some((i, of)),
            _ => None,
        })
        .expect("corpus has near-dups");
    let forget_user = corpus.by_id(of).unwrap().user;
    let dup_owner = (0..24u32)
        .find(|&u| u != forget_user && spec.assign(u) != spec.assign(forget_user))
        .expect("a user on another shard exists");
    corpus.samples[dup_idx].user = dup_owner;
    let dup_gid = corpus.samples[dup_idx].id;

    let root = unlearn::util::tempdir("fleet-eq");
    let mut fleet = Fleet::train(
        &rt,
        FleetConfig {
            root: root.clone(),
            spec,
            base: base_cfg(),
            // fixed per-shard step budget: predictable checkpoints
            scale_steps: false,
            launder_policy: Default::default(),
            auto_launder: false,
        },
        corpus.clone(),
    )
    .expect("fleet train");

    let req = ForgetRequest {
        id: "fleet-eq-1".into(),
        user: Some(forget_user),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };

    // ---- routing: owner shard + the scattered dup's shard -------------
    let routed = fleet.route(&req).unwrap();
    let touched: HashSet<u32> = routed.iter().map(|&(s, _)| s).collect();
    let owner_shard = spec.assign(forget_user);
    let dup_shard = spec.assign(dup_owner);
    assert!(touched.contains(&owner_shard), "owner shard routed");
    assert!(
        touched.contains(&dup_shard),
        "cross-shard near-dup scattered to ITS owner's shard"
    );
    assert!(touched.len() >= 2);
    // the scattered part addresses exactly the dup (by local id)
    let (_, dup_part) =
        routed.iter().find(|&&(s, _)| s == dup_shard).unwrap();
    let dup_local = fleet.split().local_of(dup_gid).unwrap().1;
    assert!(dup_part.sample_ids.contains(&dup_local));

    // ---- pre-state snapshots ------------------------------------------
    let n = fleet.n_shards();
    let pre_state: Vec<Option<unlearn::checkpoint::TrainState>> = (0..n)
        .map(|i| fleet.shard(i).map(|s| s.state.clone()))
        .collect();
    let pre_bytes: Vec<Option<BTreeMap<PathBuf, Vec<u8>>>> = (0..n)
        .map(|i| {
            fleet
                .shard(i)
                .map(|s| dir_bytes(&s.cfg.run_dir))
        })
        .collect();

    // ---- fleet plan: rolled-up cost before executing ------------------
    let plan = fleet.plan(&req).unwrap();
    assert_eq!(plan.shard_plans.len(), touched.len());
    assert!(plan.total_replay_steps > 0, "replay-bound request");
    assert!(plan.max_est_wall_secs <= plan.sum_est_wall_secs + 1e-12);

    // ---- execute ------------------------------------------------------
    let out = fleet.forget(&req).unwrap();
    assert_eq!(out.outcomes.len(), 1);
    let fo = &out.outcomes[0];
    assert!(fo.executed(), "every routed shard committed");
    assert_eq!(fo.shards.len(), touched.len());
    assert_eq!(out.shards_touched, touched.len());
    assert!(out.applied_steps_total > 0);

    // ---- touched shards: bit-identical to the shard retrain oracle ----
    // RETAINTRAIN(shard) = preserved-graph replay of the shard's own WAL
    // from θ0, filtering its local closure (Def. A.12 / Thm. A.1 — the
    // same oracle the monolithic G1 test uses, now per shard).
    for (shard, sreq) in &routed {
        let sys = fleet.shard(*shard).unwrap();
        let (cl, _) = sys.closure_of(sreq);
        let closure: HashSet<u64> = cl.into_iter().collect();
        assert!(!closure.is_empty());
        let theta0 = sys.store().load_full(0).unwrap();
        let oracle = replay_filter(
            sys.rt,
            &sys.corpus,
            &theta0,
            &sys.records,
            &sys.idmap,
            &closure,
            Some(&sys.pins),
            &sys.replay_options(),
        )
        .expect("shard retrain oracle");
        assert!(
            sys.state.bits_equal(&oracle.state),
            "shard {shard}: fleet-forget must be bit-identical to \
             retraining the shard on its retain set (model {} vs {}, \
             optimizer {} vs {})",
            sys.state.model_hash(),
            oracle.state.model_hash(),
            sys.state.optimizer_hash(),
            oracle.state.optimizer_hash()
        );
        // and it actually changed something (the shard forgot)
        assert!(
            !sys.state.bits_equal(pre_state[*shard as usize].as_ref().unwrap()),
            "shard {shard} state must have changed"
        );
        // one signed manifest entry per touched shard
        let chain = sys.manifest.verify_chain().unwrap();
        assert_eq!(chain.len(), 1);
        assert!(chain.iter().all(|(_, sig)| *sig));
    }

    // ---- untouched shards: serving state AND store bytes unchanged ----
    for shard in 0..n {
        if touched.contains(&shard) {
            continue;
        }
        let Some(sys) = fleet.shard(shard) else { continue };
        assert!(
            sys.state
                .bits_equal(pre_state[shard as usize].as_ref().unwrap()),
            "non-owning shard {shard} serving state must be untouched"
        );
        let now = dir_bytes(&sys.cfg.run_dir);
        let before = pre_bytes[shard as usize].as_ref().unwrap();
        assert_eq!(
            now.len(),
            before.len(),
            "non-owning shard {shard}: file set changed"
        );
        for (path, bytes) in &now {
            assert_eq!(
                Some(bytes),
                before.get(path),
                "non-owning shard {shard}: {} changed bytes",
                path.display()
            );
        }
        assert_eq!(sys.manifest.len(), 0, "no manifest entry on shard {shard}");
    }

    // ---- idempotency across the fleet ---------------------------------
    let dup = fleet.forget(&req).unwrap();
    assert_eq!(dup.replays_run, 0, "duplicate suppressed on every shard");
    for so in &dup.outcomes[0].shards {
        assert!(!so.outcome.as_ref().unwrap().executed);
    }

    // ---- topology drift: reopening under a different spec refuses -----
    let drifted = Fleet::open_or_train(
        &rt,
        FleetConfig {
            root: root.clone(),
            spec: ShardSpec {
                n_shards: 8,
                salt: 0x51AB,
            },
            base: base_cfg(),
            scale_steps: false,
            launder_policy: Default::default(),
            auto_launder: false,
        },
        corpus.clone(),
    );
    let msg = format!("{:#}", drifted.err().expect("topology drift refused"));
    assert!(msg.contains("topology drift"), "{msg}");

    // ---- ensemble utility is well-formed ------------------------------
    let u = fleet.utility_ensemble().unwrap();
    assert!(u.fleet_ppl.is_finite() && u.fleet_ppl > 0.0);
    assert!(!u.per_shard.is_empty());
}

#[test]
fn fleet_admin_protocol_routes_and_drains() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let spec = ShardSpec {
        n_shards: 2,
        salt: 0xA11CE,
    };
    let fleet = Fleet::train(
        &rt,
        FleetConfig {
            root: unlearn::util::tempdir("fleet-proto"),
            spec,
            base: base_cfg(),
            scale_steps: false,
            launder_policy: Default::default(),
            auto_launder: false,
        },
        corpus.clone(),
    )
    .unwrap();
    let fleet = Mutex::new(fleet);
    let ctx = FleetCtx::new(&fleet);

    // ---- fleet_status: topology + per-shard rows ----------------------
    let r = dispatch_fleet(r#"{"op":"fleet_status"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert_eq!(r.get("n_shards").unwrap().as_u64(), Some(2));
    let rows = r.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);

    // a user guaranteed non-empty on its shard
    let user = 3u32;
    let owner = spec.assign(user);

    // ---- plan: fleet rollup dry-run -----------------------------------
    let r = dispatch_fleet(
        &format!(r#"{{"op":"plan","id":"fp-plan","user":{user}}}"#),
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert!(r.get("total_replay_steps").unwrap().as_u64().unwrap() > 0);

    // ---- routed submit + drain ----------------------------------------
    let r = dispatch_fleet(
        &format!(r#"{{"op":"submit","id":"fp-1","user":{user}}}"#),
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let job = r.get("job").unwrap().as_str().unwrap().to_string();
    assert_eq!(ctx.queued_len(), 1);
    assert_eq!(drain_fleet_once(&ctx), 1);
    let r = dispatch_fleet(&format!(r#"{{"op":"poll","job":"{job}"}}"#), &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("done"), "{r}");
    assert_eq!(
        r.get_path(&["result", "executed"]).unwrap().as_bool(),
        Some(true),
        "{r}"
    );
    // executed only on the owning shard
    let shards = r
        .get_path(&["result", "shards"])
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(shards.len(), 1, "{r}");
    assert_eq!(shards[0].get("shard").unwrap().as_u64(), Some(owner as u64));

    // ---- shard-addressed submit (operator override) -------------------
    let other_user = (0..24u32)
        .find(|&u| spec.assign(u) != owner)
        .expect("a user on the other shard exists");
    let r = dispatch_fleet(
        &format!(
            r#"{{"op":"submit","id":"fp-2","user":{other_user},"shard":{}}}"#,
            spec.assign(other_user)
        ),
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let job2 = r.get("job").unwrap().as_str().unwrap().to_string();
    // an out-of-range shard address is refused at submit
    let r = dispatch_fleet(
        r#"{"op":"submit","id":"fp-bad","user":1,"shard":9}"#,
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
    assert_eq!(drain_fleet_once(&ctx), 1);
    let r = dispatch_fleet(&format!(r#"{{"op":"poll","job":"{job2}"}}"#), &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("done"), "{r}");

    // ---- utility + jobs + malformed ops -------------------------------
    let r = dispatch_fleet(r#"{"op":"utility"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert!(r.get("fleet_ppl").unwrap().as_f64().unwrap() > 0.0);
    let r = dispatch_fleet(r#"{"op":"jobs"}"#, &ctx);
    // fp-1 and fp-2 were accepted; the out-of-range submit never
    // reached the queue
    assert_eq!(r.get("jobs").unwrap().as_arr().unwrap().len(), 2);
    let r = dispatch_fleet("not json", &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    let r = dispatch_fleet(r#"{"op":"nope"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));

    // ---- shutdown refuses further submissions -------------------------
    let r = dispatch_fleet(r#"{"op":"shutdown"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    let r = dispatch_fleet(r#"{"op":"submit","id":"late","user":1}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false), "{r}");
}
