//! The crash matrix: every erasure-critical on-disk sequence is run
//! under [`unlearn::util::faultfs`] with a crash injected at EVERY
//! filesystem operation it performs (plus torn-write variants of each
//! crash point), and after each injected crash the recovery path must
//! either complete the sequence or fail closed — never resurrect
//! forgotten data, never ack work it lost, never serve a torn file.
//!
//! Sequences swept (the six from DESIGN.md's failure model):
//!   1. jobs-WAL submit (append + fsync per acked job)
//!   2. jobs-WAL recovery compaction (seq header rewrite, tmp + rename)
//!   3. forgotten.json commit (`write_atomic`: tmp write + rename)
//!   4. IdMap save (entries, .map.sum, retired sidecar tmp, rename,
//!      .retired.sum)
//!   5. lineage stage → swap → retire (launder commit) and the
//!      laundered-set compaction
//!   6. replica pull → verify → adopt (cold mirror and post-launder
//!      re-sync): a half-pulled generation is never servable
//!   7. online-ingest round (doc segment append → staged IdMap grow →
//!      interleave record → tail-advance commit → checkpoint): a torn
//!      round is never trained on, a plain retry converges
//!
//! The sweeps are count-then-inject: a [`Plan::Count`] pass measures
//! how many ops the sequence performs on a pristine copy, then one
//! fresh copy per op index gets a [`Plan::CrashAt`] at that index.

use std::collections::{BTreeMap, HashSet};
use std::path::Path;

use unlearn::checkpoint::{write_atomic, CheckpointStore, TrainState};
use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::ingest::{
    self, IngestDoc, IngestLog, IngestScheduler, RecoveryReport,
};
use unlearn::replica::Replica;
use unlearn::runtime::Runtime;
use unlearn::server::{JobQueue, JobRequest};
use unlearn::util::faultfs::{arm, Plan};
use unlearn::util::json::{parse, Json};
use unlearn::util::tempdir;
use unlearn::wal::IdMap;

fn copy_dir_recursive(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let from = e.path();
        let to = dst.join(e.file_name());
        if e.file_type().unwrap().is_dir() {
            copy_dir_recursive(&from, &to);
        } else {
            std::fs::copy(&from, &to).unwrap();
        }
    }
}

fn forget_req(n: usize) -> JobRequest {
    JobRequest::Forget(ForgetRequest {
        id: format!("req-{n}"),
        user: Some(n as u32),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    })
}

/// `(job_id, request_id, status)` rows of a queue's job table.
fn job_rows(q: &JobQueue<JobRequest>) -> Vec<(String, String, String)> {
    let Json::Arr(rows) = q.jobs_json() else {
        panic!("jobs_json is an array")
    };
    rows.iter()
        .map(|j| {
            (
                j.get("job").and_then(|v| v.as_str()).unwrap().to_string(),
                j.get("request_id")
                    .and_then(|v| v.as_str())
                    .unwrap()
                    .to_string(),
                j.get("status")
                    .and_then(|v| v.as_str())
                    .unwrap()
                    .to_string(),
            )
        })
        .collect()
}

// ---------------------------------------------------------------------
// 1. jobs-WAL submit: crash at every append/fsync of three submissions.
//    Invariant: acked ⊆ recovered ⊆ submitted, all recovered jobs are
//    queued under their ORIGINAL ids, and a post-recovery submission
//    never aliases a recovered id.
// ---------------------------------------------------------------------

#[test]
fn jobs_wal_submit_crash_sweep() {
    // 3 submits × (append, fsync) = 6 ops on a fresh WAL (no recovery
    // compaction on a missing file).
    for torn in [false, true] {
        for k in 0..6u64 {
            let dir = tempdir("cm-submit");
            let wal = dir.join("jobs.wal");
            let q = JobQueue::<JobRequest>::with_wal(&wal).unwrap();

            let inj = arm(
                &dir,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_0000 + k,
                },
            );
            let mut acked: Vec<String> = Vec::new();
            let mut errs = 0usize;
            for n in 0..3 {
                match q.submit(forget_req(n)) {
                    Ok(Some(id)) => acked.push(id),
                    Ok(None) => panic!("queue not closed"),
                    Err(_) => errs += 1,
                }
            }
            assert!(inj.crashed(), "crash point {k} fired");
            assert!(
                errs > 0,
                "crash at op {k} must surface as at least one refused ack"
            );
            drop(inj); // the recovery boundary: disk is back
            drop(q);

            let q2 = JobQueue::<JobRequest>::with_wal(&wal)
                .expect("recovery tolerates the torn final line");
            let rows = job_rows(&q2);
            let recovered: HashSet<&str> =
                rows.iter().map(|(id, _, _)| id.as_str()).collect();
            assert_eq!(
                recovered.len(),
                rows.len(),
                "recovered job ids are unique (k={k} torn={torn})"
            );
            for (id, req_id, status) in &rows {
                assert_eq!(status, "queued", "{id} re-queued");
                assert!(
                    ["req-0", "req-1", "req-2"]
                        .contains(&req_id.as_str()),
                    "recovered row {id} carries a submitted request, \
                     never a corrupt one (k={k} torn={torn})"
                );
            }
            for id in &acked {
                assert!(
                    recovered.contains(id.as_str()),
                    "acked {id} survived the crash (k={k} torn={torn}) \
                     — durability promise broken"
                );
            }
            // un-acked lines may or may not have persisted (recovered ⊆
            // submitted is enforced by the req-id check above), but a
            // fresh submission must not alias anything recovered
            let fresh = q2
                .submit(forget_req(3))
                .unwrap()
                .expect("post-recovery queue accepts work");
            assert!(
                !recovered.contains(fresh.as_str()),
                "fresh id {fresh} aliases a recovered job"
            );
            assert!(!acked.contains(&fresh));
        }
    }
}

// ---------------------------------------------------------------------
// 2. jobs-WAL recovery compaction: crash inside the seq-header rewrite
//    (write_atomic: tmp write, rename).  Invariant: a crashed
//    compaction fails the open; the NEXT open still recovers every
//    pending job under its original id.
// ---------------------------------------------------------------------

#[test]
fn jobs_wal_recovery_compaction_crash_sweep() {
    // pristine WAL with three pending submissions
    let proto = tempdir("cm-compact-proto");
    let proto_wal = proto.join("jobs.wal");
    let q = JobQueue::<JobRequest>::with_wal(&proto_wal).unwrap();
    let mut ids = Vec::new();
    for n in 0..3 {
        ids.push(q.submit(forget_req(n)).unwrap().unwrap());
    }
    drop(q);

    for torn in [false, true] {
        for k in 0..2u64 {
            let dir = tempdir("cm-compact");
            let wal = dir.join("jobs.wal");
            std::fs::copy(&proto_wal, &wal).unwrap();

            let inj = arm(
                &dir,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_1000 + k,
                },
            );
            assert!(
                JobQueue::<JobRequest>::with_wal(&wal).is_err(),
                "compaction crash at op {k} fails the open (fail \
                 closed, not a silently un-compacted queue)"
            );
            drop(inj);

            let q2 = JobQueue::<JobRequest>::with_wal(&wal).unwrap();
            let rows = job_rows(&q2);
            let recovered: HashSet<&str> =
                rows.iter().map(|(id, _, _)| id.as_str()).collect();
            for id in &ids {
                assert!(
                    recovered.contains(id.as_str()),
                    "pending {id} survives a crashed compaction \
                     (k={k} torn={torn})"
                );
            }
            assert!(rows.iter().all(|(_, _, s)| s == "queued"));
        }
    }
}

// ---------------------------------------------------------------------
// 3. forgotten.json commit: crash at each write_atomic op (tmp write,
//    rename), torn variants included.  Invariant: the file parses as
//    exactly the OLD or NEW id set — never torn, never missing.  A
//    transient failure (FailAt) is retryable in place.
// ---------------------------------------------------------------------

#[test]
fn forgotten_set_commit_crash_sweep() {
    let old_text = "{\"ids\": [1, 2, 3]}";
    let new_text = "{\"ids\": [1, 2, 3, 7, 9]}";
    let read_ids = |p: &Path| -> Vec<u64> {
        let j = parse(&std::fs::read_to_string(p).unwrap())
            .expect("forgotten.json parses after any crash");
        j.get("ids")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap())
            .collect()
    };

    for torn in [false, true] {
        for k in 0..2u64 {
            let dir = tempdir("cm-forgotten");
            let target = dir.join("forgotten.json");
            write_atomic(&target, old_text).unwrap();

            let inj = arm(
                &dir,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_2000 + k,
                },
            );
            assert!(write_atomic(&target, new_text).is_err());
            drop(inj);

            let ids = read_ids(&target);
            assert!(
                ids == vec![1, 2, 3] || ids == vec![1, 2, 3, 7, 9],
                "forgotten set after crash at op {k} (torn={torn}) is \
                 old or new, got {ids:?}"
            );
        }
    }

    // transient injected failure: the commit errors once, then a plain
    // retry lands the new set
    let dir = tempdir("cm-forgotten-transient");
    let target = dir.join("forgotten.json");
    write_atomic(&target, old_text).unwrap();
    let inj = arm(&dir, Plan::FailAt { op: 0 });
    assert!(write_atomic(&target, new_text).is_err());
    assert!(
        write_atomic(&target, new_text).is_ok(),
        "FailAt is transient — the retry succeeds with the injector \
         still armed"
    );
    drop(inj);
    assert_eq!(read_ids(&target), vec![1, 2, 3, 7, 9]);
}

// ---------------------------------------------------------------------
// 4. IdMap save: crash at each of the five ops (entries, .map.sum,
//    retired sidecar tmp, sidecar rename, .retired.sum).  Invariant:
//    load either refuses (fail closed) or yields a verifying map whose
//    retired set is exactly the old or the new one — a crash can never
//    shrink the retired set below what was last durably committed.
// ---------------------------------------------------------------------

#[test]
fn idmap_save_crash_sweep() {
    // the map under test, rebuilt identically per iteration
    let build = || {
        let mut m = IdMap::new(None);
        let h1 = m.register(&[1, 2, 3]);
        let h2 = m.register(&[4, 5, 6]);
        (m, h1, h2)
    };

    // template: version A on disk (retired = {2})
    let proto = tempdir("cm-idmap-proto");
    let (mut m, h1, h2) = build();
    m.retire_ids([2]);
    m.save(&proto.join("ids.map")).unwrap();

    for torn in [false, true] {
        for k in 0..5u64 {
            let dir = tempdir("cm-idmap");
            copy_dir_recursive(&proto, &dir);
            let path = dir.join("ids.map");

            let (mut m2, _, _) = build();
            m2.retire_ids([2]);
            m2.retire_ids([5]); // version B
            let inj = arm(
                &dir,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_3000 + k,
                },
            );
            assert!(
                m2.save(&path).is_err(),
                "save crashes at op {k} (torn={torn})"
            );
            drop(inj);

            match IdMap::load(&path, None) {
                // refusing to load IS the fail-closed contract: the
                // caller must not replay with an unverifiable map
                Err(_) => {}
                Ok(l) => {
                    assert!(l.verify(h1) && l.verify(h2));
                    assert!(
                        l.is_retired(2),
                        "committed retirement lost (k={k} torn={torn})"
                    );
                    let extra = l.is_retired(5);
                    assert_eq!(
                        l.retired_len(),
                        if extra { 2 } else { 1 },
                        "retired set is exactly old or new \
                         (k={k} torn={torn})"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// 5. Lineage stage → swap → retire, and laundered-set compaction.
//    Invariant: after a crash at ANY op, reopening the store succeeds
//    and serves exactly one coherent generation — either the pre-commit
//    lineage (both original checkpoints bit-intact, no laundered ids)
//    or the committed one (filtered checkpoint + laundered ids), and
//    laundered-count accounting (`ids + retired`) is conserved.
// ---------------------------------------------------------------------

fn mk_state(fill: f32, step: u32) -> TrainState {
    let mut s = TrainState::zeros_like(vec![fill; 8]);
    s.logical_step = step;
    s.applied_updates = step;
    s
}

/// The launder commit sequence the controller runs (stage the filtered
/// successor, adopt the clean prefix, swap).
fn launder_commit(root: &Path) -> anyhow::Result<()> {
    let store = CheckpointStore::open(root, 16)?;
    let stage = store.begin_lineage()?;
    stage.adopt_full(4)?;
    stage.save_full(&mk_state(0.75, 8))?;
    stage.commit(&[7, 9], 8, 0)
}

fn lineage_template() -> std::path::PathBuf {
    let proto = tempdir("cm-lineage-proto");
    let store = CheckpointStore::open(&proto, 16).unwrap();
    store.save_full(&mk_state(0.25, 4)).unwrap();
    store.save_full(&mk_state(0.5, 8)).unwrap();
    proto
}

#[test]
fn lineage_commit_crash_sweep() {
    let proto = lineage_template();

    // count pass: how many fs ops does the commit sequence perform?
    let count_dir = tempdir("cm-lineage-count");
    copy_dir_recursive(&proto, &count_dir);
    let counter = arm(&count_dir, Plan::Count);
    launder_commit(&count_dir).unwrap();
    let n = counter.ops();
    drop(counter);
    assert!(n >= 6, "stage+swap is at least six ops, counted {n}");

    for torn in [false, true] {
        for k in 0..n {
            let dir = tempdir("cm-lineage");
            copy_dir_recursive(&proto, &dir);
            let inj = arm(
                &dir,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_4000 + k,
                },
            );
            // late crash points land in the best-effort post-swap
            // cleanup, where commit still returns Ok — both outcomes
            // are legal, the reopened store decides which state won
            let _ = launder_commit(&dir);
            drop(inj);

            let store = CheckpointStore::open(&dir, 16)
                .expect("store reopens after any crash point");
            let (ids, retired) = store.laundered_meta().unwrap();
            if ids.is_empty() && retired == 0 {
                // the swap did not land: pre-commit lineage, bit-intact
                let s4 = store.load_full(4).expect("step 4 intact");
                let s8 = store.load_full(8).expect("step 8 intact");
                assert!(
                    s4.bits_equal(&mk_state(0.25, 4))
                        && s8.bits_equal(&mk_state(0.5, 8)),
                    "pre-commit checkpoints bit-intact (k={k} \
                     torn={torn})"
                );
            } else {
                // the swap landed: committed lineage, laundered ids
                // visible, filtered checkpoint serving
                assert_eq!(ids, vec![7, 9], "k={k} torn={torn}");
                assert_eq!(retired, 0);
                let s4 = store.load_full(4).expect("adopted step 4");
                let s8 = store.load_full(8).expect("filtered step 8");
                assert!(s4.bits_equal(&mk_state(0.25, 4)));
                assert!(
                    s8.bits_equal(&mk_state(0.75, 8)),
                    "committed lineage serves the FILTERED step-8 \
                     state (k={k} torn={torn})"
                );
            }
        }
    }
}

#[test]
fn laundered_compaction_crash_sweep() {
    // template: a root with a COMMITTED laundered generation
    let proto = lineage_template();
    launder_commit(&proto).unwrap();

    for torn in [false, true] {
        for k in 0..2u64 {
            let dir = tempdir("cm-laundered");
            copy_dir_recursive(&proto, &dir);
            {
                let store = CheckpointStore::open(&dir, 16).unwrap();
                let inj = arm(
                    &dir,
                    Plan::CrashAt {
                        op: k,
                        torn,
                        seed: 0x5EED_5000 + k,
                    },
                );
                assert!(store.compact_laundered(2).is_err());
                drop(inj);
            }
            let store = CheckpointStore::open(&dir, 16).unwrap();
            let (ids, retired) = store.laundered_meta().unwrap();
            assert_eq!(
                ids.len() as u64 + retired,
                2,
                "laundered accounting conserved across a crashed \
                 compaction (k={k} torn={torn}): ids={ids:?} \
                 retired={retired}"
            );
            if retired == 0 {
                assert_eq!(ids, vec![7, 9]);
            } else {
                assert!(ids.is_empty());
            }
        }
    }
}

// ---------------------------------------------------------------------
// 6. Replica pull → verify → adopt.  Every filesystem op of a sync is
//    a crash point on the REPLICA's disk (the source is read-only by
//    construction).  Invariant: after any crash the replica either
//    refuses to serve (no adopted generation yet — fail closed) or
//    serves exactly one coherent generation, bit-identical to what the
//    source served at that generation; a plain retry then completes
//    the sync.  A half-pulled generation must never be servable.
// ---------------------------------------------------------------------

/// Cold mirror: crash at every op of a first sync into an empty
/// replica.  Old = nothing servable (refusal), new = the source's
/// generation 0 bit-intact.
#[test]
fn replica_cold_sync_crash_sweep() {
    let src = lineage_template();

    // count pass: how many fs ops does a cold sync perform?
    let count_local = tempdir("cm-replica-cold-count");
    let counter = arm(&count_local, Plan::Count);
    let mut rep = Replica::open(&src, &count_local).unwrap();
    rep.sync().unwrap();
    let n = counter.ops();
    drop(counter);
    assert!(n >= 6, "objects + manifests + swap is at least six ops, counted {n}");

    for torn in [false, true] {
        for k in 0..n {
            let local = tempdir("cm-replica-cold");
            let inj = arm(
                &local,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_6000 + k,
                },
            );
            let crashed = Replica::open(&src, &local)
                .and_then(|mut r| r.sync())
                .is_err();
            assert!(crashed, "crash at op {k} (torn={torn}) surfaces");
            drop(inj);

            let rep = Replica::open(&src, &local).unwrap();
            match rep.generation() {
                None => {
                    // the swap never landed: nothing is servable, and
                    // the replica says so rather than serving a
                    // half-pulled generation
                    assert!(
                        rep.load_serving_state().is_err(),
                        "unadopted replica must refuse to serve \
                         (k={k} torn={torn})"
                    );
                }
                Some(g) => {
                    // the swap landed, so the adopt-time completeness
                    // check had already passed: full fidelity
                    assert_eq!(g, 0, "k={k} torn={torn}");
                    let s = rep.load_serving_state().unwrap();
                    assert_eq!(s.step, 8);
                    assert!(
                        s.state.bits_equal(&mk_state(0.5, 8)),
                        "adopted replica serves the source's bits \
                         (k={k} torn={torn})"
                    );
                }
            }

            // recovery completes the sequence: a plain retry lands
            let mut rep = rep;
            rep.sync().expect("post-crash retry syncs clean");
            let s = rep.load_serving_state().unwrap();
            assert!(s.state.bits_equal(&mk_state(0.5, 8)));
        }
    }
}

/// Post-launder re-sync: the replica serves generation 0, the source
/// launders to generation 1, and the pull of the new lineage crashes
/// at every op.  Old = the pre-launder generation (still coherent,
/// watermarked stale), new = the laundered one — NEVER a mix of the
/// two lineages.
#[test]
fn replica_launder_resync_crash_sweep() {
    // source template: generation 0, then laundered to generation 1
    let src = lineage_template();
    let local_proto = tempdir("cm-replica-resync-proto");
    Replica::open(&src, &local_proto).unwrap().sync().unwrap();
    launder_commit(&src).unwrap();

    // count pass on a pristine copy of the synced replica
    let count_local = tempdir("cm-replica-resync-count");
    copy_dir_recursive(&local_proto, &count_local);
    let counter = arm(&count_local, Plan::Count);
    Replica::open(&src, &count_local).unwrap().sync().unwrap();
    let n = counter.ops();
    drop(counter);
    assert!(n >= 4, "re-sync writes at least the new object, two \
         manifests and the swap, counted {n}");

    for torn in [false, true] {
        for k in 0..n {
            let local = tempdir("cm-replica-resync");
            copy_dir_recursive(&local_proto, &local);
            let inj = arm(
                &local,
                Plan::CrashAt {
                    op: k,
                    torn,
                    seed: 0x5EED_7000 + k,
                },
            );
            let crashed = Replica::open(&src, &local)
                .and_then(|mut r| r.sync())
                .is_err();
            assert!(crashed, "crash at op {k} (torn={torn}) surfaces");
            drop(inj);

            let rep = Replica::open(&src, &local).unwrap();
            let s = rep
                .load_serving_state()
                .expect("a previously-adopted replica always serves");
            assert_eq!(s.step, 8);
            match rep.generation() {
                Some(0) => assert!(
                    s.state.bits_equal(&mk_state(0.5, 8)),
                    "pre-launder generation served bit-intact \
                     (k={k} torn={torn})"
                ),
                Some(1) => assert!(
                    s.state.bits_equal(&mk_state(0.75, 8)),
                    "laundered generation served bit-intact \
                     (k={k} torn={torn})"
                ),
                g => panic!("impossible generation {g:?} after crash"),
            }

            // retry converges on the laundered lineage
            let mut rep = rep;
            rep.sync().expect("post-crash retry syncs clean");
            assert_eq!(rep.generation(), Some(1));
            let s = rep.load_serving_state().unwrap();
            assert!(s.state.bits_equal(&mk_state(0.75, 8)));
        }
    }
}

// ---------------------------------------------------------------------
// 7. Online-ingest round: doc segment + checksum + `ingest` entry, then
//    WAL append/seal + staged IdMap + `train` entry (the tail-advance
//    commit point) + promote + post-commit checkpoint.  Crash at EVERY
//    fs op, clean and torn.  Invariants: the reopened system serves
//    exactly the committed program (a torn half-round is NEVER trained
//    on), and a plain retry of the same round converges bit-identically
//    to the never-crashed control — durable program definition (wal/,
//    ingest/, IdMap trio) byte for byte.
// ---------------------------------------------------------------------

fn ingest_cfg(dir: &Path) -> RunConfig {
    RunConfig {
        run_dir: dir.to_path_buf(),
        steps: 4,
        accum: 1,
        checkpoint_every: 2,
        checkpoint_keep: 8,
        ring_window: 2,
        warmup: 1,
        ..Default::default()
    }
}

fn collect_bytes(
    root: &Path,
    rel: &Path,
    out: &mut BTreeMap<String, Vec<u8>>,
) {
    let abs = root.join(rel);
    if abs.is_dir() {
        for e in std::fs::read_dir(&abs).unwrap() {
            let name = e.unwrap().file_name();
            collect_bytes(root, &rel.join(name), out);
        }
    } else if abs.is_file() {
        out.insert(
            rel.to_string_lossy().into_owned(),
            std::fs::read(&abs).unwrap(),
        );
    }
}

/// The durable program definition of a run: WAL segments, the ingest
/// plane (doc segments + interleave log) and the IdMap trio.  The
/// checkpoint store is deliberately excluded — equal program bytes plus
/// bit-equal serving state is the replayability contract.
fn program_bytes(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for sub in [
        "wal",
        "ingest",
        "ids.map",
        "ids.map.sum",
        "ids.map.retired",
        "ids.map.retired.sum",
    ] {
        collect_bytes(dir, Path::new(sub), &mut out);
    }
    out
}

#[test]
fn ingest_round_crash_sweep() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = || harness::small_corpus(rt.manifest.seq_len);

    // template: a trained base run with the interleave log attached
    let proto = tempdir("cm-ingest-proto");
    let base_len = {
        let trained =
            harness::build_system(&rt, ingest_cfg(&proto), corpus(), false)
                .unwrap();
        let n = trained.system.corpus.len();
        IngestLog::attach(&proto, n).unwrap();
        n
    };

    let sched = IngestScheduler::new(1);
    let round = ingest::round_of("cm-ingest-round");
    let docs = vec![IngestDoc {
        user: 30,
        text: "a new user arrives mid-serving".into(),
    }];

    // never-crashed control: one clean round on a pristine copy
    let control_dir = tempdir("cm-ingest-control");
    copy_dir_recursive(&proto, &control_dir);
    let (base_state, control, control_bytes) = {
        let (mut ts, mut log, report) =
            ingest::reopen(&rt, ingest_cfg(&control_dir), corpus(), false)
                .unwrap();
        assert_eq!(report, RecoveryReport::default());
        let sys = &mut ts.system;
        let base_state = sys.state.clone();
        let out = sched.run_round(sys, &mut log, round, &docs).unwrap();
        assert!(out.executed);
        (base_state, sys.state.clone(), program_bytes(&control_dir))
    };

    // count pass: how many fs ops does one round perform?
    let count_dir = tempdir("cm-ingest-count");
    copy_dir_recursive(&proto, &count_dir);
    let n = {
        let (mut ts, mut log, _) =
            ingest::reopen(&rt, ingest_cfg(&count_dir), corpus(), false)
                .unwrap();
        let counter = arm(&count_dir, Plan::Count);
        sched
            .run_round(&mut ts.system, &mut log, round, &docs)
            .unwrap();
        counter.ops()
    };
    assert!(
        n >= 12,
        "docs + wal + staged idmap + commit + promote + checkpoint is \
         at least a dozen ops, counted {n}"
    );

    for torn in [false, true] {
        for k in 0..n {
            let dir = tempdir("cm-ingest");
            copy_dir_recursive(&proto, &dir);
            {
                let (mut ts, mut log, _) =
                    ingest::reopen(&rt, ingest_cfg(&dir), corpus(), false)
                        .unwrap();
                let inj = arm(
                    &dir,
                    Plan::CrashAt {
                        op: k,
                        torn,
                        seed: 0x5EED_8000 + k,
                    },
                );
                let res =
                    sched.run_round(&mut ts.system, &mut log, round, &docs);
                assert!(
                    res.is_err(),
                    "crash at op {k} (torn={torn}) surfaces"
                );
                assert!(inj.crashed());
                drop(inj);
            }

            // recovery: the reopened system serves EXACTLY the
            // committed program — a torn half-round leaves no trace
            let (mut ts, mut log, _report) =
                ingest::reopen(&rt, ingest_cfg(&dir), corpus(), false)
                    .unwrap_or_else(|e| {
                        panic!(
                            "reopen after crash at op {k} \
                             (torn={torn}): {e:#}"
                        )
                    });
            let sys = &mut ts.system;
            assert_eq!(
                sys.corpus.len() as u64,
                base_len as u64 + log.ingested_docs(),
                "corpus covers exactly the committed docs \
                 (k={k} torn={torn})"
            );
            let oracle = ingest::oracle_state(sys, &HashSet::new()).unwrap();
            assert!(
                sys.state.bits_equal(&oracle),
                "serving state replays the committed program \
                 (k={k} torn={torn})"
            );
            if !log.has_train_round(round) {
                assert!(
                    sys.state.bits_equal(&base_state),
                    "uncommitted increment left no trace in the \
                     weights (k={k} torn={torn})"
                );
            }

            // plain retry of the SAME round key converges on the
            // never-crashed control, durable bytes included
            sched
                .run_round(sys, &mut log, round, &docs)
                .unwrap_or_else(|e| {
                    panic!(
                        "retry after crash at op {k} (torn={torn}): {e:#}"
                    )
                });
            assert!(
                sys.state.bits_equal(&control),
                "retry converges on the control weights \
                 (k={k} torn={torn})"
            );
            assert_eq!(sys.corpus.len(), base_len + 1);
            let got = program_bytes(&dir);
            assert_eq!(
                got.keys().collect::<Vec<_>>(),
                control_bytes.keys().collect::<Vec<_>>(),
                "program file sets differ (k={k} torn={torn})"
            );
            for (name, bytes) in &control_bytes {
                assert!(
                    got[name] == *bytes,
                    "{name} diverges from the control bytes \
                     (k={k} torn={torn})"
                );
            }
        }
    }
}
