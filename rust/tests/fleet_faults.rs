//! Fleet fault tolerance: the durable jobs WAL (an acked forget
//! request survives a crash between ack and drain — and provably does
//! NOT survive with the old in-memory queue) and degraded-mode shard
//! isolation (a shard whose erasure-critical I/O fails is quarantined
//! with drain-counted backoff while healthy shards keep serving, then
//! heals through a half-open probe).

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Mutex;

use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::fleet::server::{dispatch_fleet, drain_fleet_once, FleetCtx};
use unlearn::fleet::{Fleet, FleetConfig, ShardHealth};
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::shard::ShardSpec;
use unlearn::util::faultfs::{arm, Plan};
use unlearn::util::json::Json;

const STEPS: u32 = 8;

fn base_cfg() -> RunConfig {
    RunConfig {
        steps: STEPS,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 2,
        ..Default::default()
    }
}

fn fleet_cfg(root: PathBuf, spec: ShardSpec) -> FleetConfig {
    FleetConfig {
        root,
        spec,
        base: base_cfg(),
        scale_steps: false,
        launder_policy: Default::default(),
        auto_launder: false,
    }
}

fn freq(id: &str, user: u32) -> ForgetRequest {
    ForgetRequest {
        id: id.into(),
        user: Some(user),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    }
}

// ---------------------------------------------------------------------
// The WITHOUT/WITH contrast: the old in-memory fleet queue loses an
// acked forget job across a restart; the WAL-backed queue recovers it
// under its ORIGINAL id and drains it to a state bit-identical to a
// fleet that never crashed.
// ---------------------------------------------------------------------

#[test]
fn acked_fleet_job_survives_restart_only_with_jobs_wal() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let spec = ShardSpec {
        n_shards: 2,
        salt: 0xFA17,
    };
    let user = corpus.samples[0].user;
    let owner = spec.assign(user);

    let root = unlearn::util::tempdir("fleet-wal");
    let fleet = Fleet::train(&rt, fleet_cfg(root.clone(), spec), corpus.clone())
        .expect("fleet train");
    let fleet = Mutex::new(fleet);

    // WITHOUT the fix (in-memory queue): submit is acked, the "server"
    // restarts (ctx dropped), and the acked erasure obligation is GONE.
    {
        let ctx = FleetCtx::new(&fleet);
        let r = dispatch_fleet(
            &format!("{{\"op\":\"submit\",\"id\":\"lost-1\",\"user\":{user}}}"),
            &ctx,
        );
        assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
        let job = r
            .get("job")
            .and_then(|v| v.as_str())
            .expect("acked job id")
            .to_string();
        assert_eq!(ctx.queued_len(), 1);
        drop(ctx); // restart

        let ctx2 = FleetCtx::new(&fleet);
        assert_eq!(
            ctx2.queued_len(),
            0,
            "in-memory queue silently lost the acked forget job"
        );
        let r = dispatch_fleet(
            &format!("{{\"op\":\"poll\",\"job\":\"{job}\"}}"),
            &ctx2,
        );
        assert_eq!(
            r.get("ok").and_then(|v| v.as_bool()),
            Some(false),
            "the lost job id polls as unknown"
        );
    }

    // WITH the fix: same crash window, job recovered under its original
    // id and drained to completion.
    let wal = root.join("jobs.wal");
    let job_id = {
        let ctx = FleetCtx::with_jobs_wal(&fleet, &wal).unwrap();
        let r = dispatch_fleet(
            &format!(
                "{{\"op\":\"submit\",\"id\":\"durable-1\",\"user\":{user}}}"
            ),
            &ctx,
        );
        assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
        r.get("job").and_then(|v| v.as_str()).unwrap().to_string()
        // ctx dropped here: crash between ack and drain
    };

    let ctx = FleetCtx::with_jobs_wal(&fleet, &wal).unwrap();
    assert_eq!(ctx.queued_len(), 1, "acked job recovered from jobs WAL");
    let r = dispatch_fleet(
        &format!("{{\"op\":\"poll\",\"job\":\"{job_id}\"}}"),
        &ctx,
    );
    assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(
        r.get("status").and_then(|v| v.as_str()),
        Some("queued"),
        "recovered under the ORIGINAL job id, re-queued"
    );
    assert_eq!(
        r.get("request_id").and_then(|v| v.as_str()),
        Some("durable-1")
    );

    assert_eq!(drain_fleet_once(&ctx), 1);
    let r = dispatch_fleet(
        &format!("{{\"op\":\"poll\",\"job\":\"{job_id}\"}}"),
        &ctx,
    );
    assert_eq!(r.get("status").and_then(|v| v.as_str()), Some("done"));
    assert_eq!(
        r.get_path(&["result", "ok"]).and_then(|v| v.as_bool()),
        Some(true)
    );
    drop(ctx);

    // Bit-identical to a never-crashed control fleet executing the same
    // request synchronously (same corpus, spec and per-shard seeds —
    // only the root differs).
    let control_root = unlearn::util::tempdir("fleet-wal-ctl");
    let mut control =
        Fleet::train(&rt, fleet_cfg(control_root, spec), corpus.clone())
            .expect("control fleet train");
    let out = control.forget(&freq("durable-1", user)).unwrap();
    assert!(out.outcomes[0].executed());

    let fleet = fleet.into_inner().unwrap();
    let drained = fleet.shard(owner).expect("owner shard");
    let oracle = control.shard(owner).expect("owner shard");
    assert!(
        drained.state.bits_equal(&oracle.state),
        "crash-recovered drain is bit-identical to the never-crashed \
         control on shard {owner} (model {} vs {})",
        drained.state.model_hash(),
        oracle.state.model_hash()
    );
}

// ---------------------------------------------------------------------
// Degraded-mode shard isolation: an injected I/O failure on ONE shard's
// erasure-critical persist quarantines that shard only; healthy shards
// keep executing through the quarantine window; the half-open probe
// heals it.
// ---------------------------------------------------------------------

#[test]
fn quarantined_shard_does_not_block_healthy_shards() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);

    // Find a salt (training a throwaway fleet per candidate) giving
    // three single-shard users on one shard and two on the other —
    // "single-shard" per actual routing, so no request in this test
    // scatters across the quarantine boundary.
    let mut picked = None;
    for salt in 0u64..8 {
        let spec = ShardSpec { n_shards: 2, salt };
        let root = unlearn::util::tempdir("fleet-quar");
        let fleet =
            match Fleet::train(&rt, fleet_cfg(root.clone(), spec), corpus.clone())
            {
                Ok(f) => f,
                Err(_) => continue, // e.g. a shard with no users
            };
        let users: HashSet<u32> =
            corpus.samples.iter().map(|s| s.user).collect();
        let mut pure: [Vec<u32>; 2] = [Vec::new(), Vec::new()];
        for &u in &users {
            let shards: HashSet<u32> = fleet
                .route(&freq("probe", u))
                .unwrap()
                .iter()
                .map(|&(s, _)| s)
                .collect();
            if shards.len() == 1 {
                pure[*shards.iter().next().unwrap() as usize].push(u);
            }
        }
        pure[0].sort_unstable();
        pure[1].sort_unstable();
        let (v, h) = if pure[0].len() >= pure[1].len() {
            (0usize, 1usize)
        } else {
            (1, 0)
        };
        if pure[v].len() >= 3 && pure[h].len() >= 2 {
            picked = Some((fleet, root, v as u32, pure[v].clone(), pure[h].clone()));
            break;
        }
    }
    let (mut fleet, root, victim, vu, hu) =
        picked.expect("a salt with 3 + 2 single-shard users");

    let victim_dir = root.join(format!("shard-{victim:04}"));

    // Drain 1: the victim's forgotten-set persist fails (first
    // injected fs op under its run dir) — batch-level error, quarantine.
    let inj = arm(&victim_dir, Plan::FailAt { op: 0 });
    let out = fleet
        .execute_batch(&[freq("q-1", vu[0]), freq("q-2", hu[0])])
        .unwrap();
    drop(inj);

    let o_victim = &out.outcomes[0];
    assert_eq!(o_victim.shards.len(), 1);
    assert_eq!(o_victim.shards[0].shard, victim);
    assert!(
        o_victim.shards[0].outcome.is_err()
            && !o_victim.shards[0].quarantined,
        "drain 1: the victim ATTEMPTED and failed (not skipped)"
    );
    assert!(
        out.outcomes[1].executed(),
        "drain 1: the healthy shard executed while its neighbor failed"
    );
    assert!(matches!(
        fleet.shard_health(victim),
        Some(ShardHealth::Quarantined { .. })
    ));
    assert_eq!(fleet.quarantined_count(), 1);

    // fleet_status reports per-shard health + quarantine reason
    let st = fleet.status_json();
    assert_eq!(
        st.get("quarantined_shards").and_then(|v| v.as_u64()),
        Some(1)
    );
    let Some(Json::Arr(rows)) = st.get("shards") else {
        panic!("status has shard rows")
    };
    let row = rows
        .iter()
        .find(|r| r.get("shard").and_then(|v| v.as_u64()) == Some(victim as u64))
        .unwrap();
    assert_eq!(
        row.get("health").and_then(|v| v.as_str()),
        Some("quarantined")
    );
    assert!(row.get("quarantine_reason").is_some());
    assert_eq!(row.get("retry_in_drains").and_then(|v| v.as_u64()), Some(1));
    let healthy_row = rows
        .iter()
        .find(|r| r.get("shard").and_then(|v| v.as_u64()) != Some(victim as u64))
        .unwrap();
    assert_eq!(
        healthy_row.get("health").and_then(|v| v.as_str()),
        Some("healthy")
    );

    // Drain 2 (cooldown running): the victim's share is SKIPPED with a
    // typed quarantined outcome — no execution attempt — while the
    // healthy shard serves normally.
    let out = fleet
        .execute_batch(&[freq("q-3", vu[1]), freq("q-4", hu[1])])
        .unwrap();
    let o_victim = &out.outcomes[0];
    assert_eq!(o_victim.shards.len(), 1);
    assert!(
        o_victim.shards[0].quarantined && o_victim.shards[0].outcome.is_err(),
        "drain 2: skipped by isolation, not attempted"
    );
    let j = o_victim.to_json();
    assert_eq!(
        j.get_path(&["shards"])
            .and_then(|v| v.as_arr())
            .and_then(|a| a[0].get("status"))
            .and_then(|v| v.as_str()),
        Some("quarantined"),
        "per-shard outcome JSON distinguishes quarantined from failed"
    );
    assert!(
        out.outcomes[1].executed(),
        "drain 2: healthy shard unaffected during the quarantine window"
    );
    assert_eq!(
        out.shards_touched, 1,
        "only the healthy shard actually ran"
    );

    // Drain 3 (cooldown expired, injector long gone): the half-open
    // probe executes the victim's work and restores it to Healthy.
    let out = fleet.execute_batch(&[freq("q-5", vu[2])]).unwrap();
    assert!(
        out.outcomes[0].executed(),
        "drain 3: half-open probe executed the quarantined shard's work"
    );
    assert!(matches!(
        fleet.shard_health(victim),
        Some(ShardHealth::Healthy)
    ));
    assert_eq!(fleet.quarantined_count(), 0);
}
