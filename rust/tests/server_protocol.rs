//! Admin-server protocol integration: dispatch ops against a live
//! system (without sockets — `dispatch` is the protocol core; the TCP
//! layer is a thin line-framing loop around it).

use std::sync::atomic::AtomicBool;
use std::sync::Mutex;

use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::server::dispatch;

#[test]
fn protocol_ops_roundtrip() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("server-proto"),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        warmup: 2,
        ..Default::default()
    };
    let trained = harness::build_system(&rt, cfg, corpus, false).unwrap();
    let system = Mutex::new(trained.system);
    let shutdown = AtomicBool::new(false);

    // status
    let r = dispatch(r#"{"op":"status"}"#, &system, &shutdown);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    assert!(r.get("model_hash").unwrap().as_str().unwrap().len() == 16);

    // forget (normal)
    let r = dispatch(
        r#"{"op":"forget","id":"srv-1","user":3,"urgency":"normal"}"#,
        &system,
        &shutdown,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert_eq!(r.get("executed").unwrap().as_bool(), Some(true));
    assert!(r.get("action").unwrap().as_str().is_some());

    // duplicate suppressed
    let r = dispatch(
        r#"{"op":"forget","id":"srv-1","user":3}"#,
        &system,
        &shutdown,
    );
    assert_eq!(r.get("executed").unwrap().as_bool(), Some(false));

    // manifest verification
    let r = dispatch(r#"{"op":"manifest"}"#, &system, &shutdown);
    assert_eq!(r.get("signatures_valid").unwrap().as_bool(), Some(true));
    assert_eq!(r.get("entries").unwrap().as_u64(), Some(1));

    // malformed input -> structured error, no panic
    let r = dispatch("not json", &system, &shutdown);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    let r = dispatch(r#"{"op":"nope"}"#, &system, &shutdown);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    let r = dispatch(r#"{"op":"forget"}"#, &system, &shutdown);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));

    // shutdown flag
    let r = dispatch(r#"{"op":"shutdown"}"#, &system, &shutdown);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    assert!(shutdown.load(std::sync::atomic::Ordering::SeqCst));
}
