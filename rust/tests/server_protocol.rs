//! Admin-server protocol integration: dispatch ops against a live
//! system (without sockets — `dispatch` is the protocol core; the TCP
//! layer is a thin line-framing loop around it).  Covers the async job
//! queue (submit/poll/jobs + coalesced drain), the plan dry-run, the
//! lock-free read plane, and poisoned-lock containment.

use std::sync::Mutex;

use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::server::{dispatch, drain_queue_once, ServerCtx};

#[test]
fn job_wal_recovers_pending_and_launder_op_compacts() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("server-wal"),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        warmup: 2,
        ..Default::default()
    };
    let wal_path = cfg.run_dir.join("jobs.wal");
    let trained = harness::build_system(&rt, cfg, corpus, false).unwrap();
    let system = Mutex::new(trained.system);

    // a replay-bound user (offending steps in the base)
    let user = {
        let sys = system.lock().unwrap();
        (0..24u32)
            .find(|&u| {
                sys.plan(&unlearn::controller::ForgetRequest {
                    id: format!("probe-{u}"),
                    user: Some(u),
                    sample_ids: vec![],
                    urgency: unlearn::controller::Urgency::Normal,
                })
                .map(|p| !p.offending.is_empty())
                .unwrap_or(false)
            })
            .expect("a replay-bound user exists")
    };

    // ---- submit into a WAL-backed queue, then "crash" (drop the ctx
    // without draining): accepted work must survive ---------------------
    {
        let ctx = ServerCtx::with_jobs_wal(&system, &wal_path).unwrap();
        let r = dispatch(
            &format!(r#"{{"op":"submit","id":"wal-0","user":{user}}}"#),
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("job").unwrap().as_str(), Some("job-1"));
        let r = dispatch(r#"{"op":"launder"}"#, &ctx);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(r.get("job").unwrap().as_str(), Some("job-2"));
        assert_eq!(ctx.jobs.queued_len(), 2);
        // status surfaces the queue backlog: promised-but-unfinished
        // jobs plus the jobs-WAL footprint backing the promise
        let r = dispatch(r#"{"op":"status"}"#, &ctx);
        assert_eq!(r.get("pending_jobs").unwrap().as_u64(), Some(2), "{r}");
        assert!(
            r.get("jobs_wal_bytes").unwrap().as_u64().unwrap() > 0,
            "{r}"
        );
        // no drain — the process dies with the queue full
    }

    // ---- restart: the pending suffix is re-queued under its original
    // ids and the sequence resumes past them ----------------------------
    let ctx = ServerCtx::with_jobs_wal(&system, &wal_path).unwrap();
    assert_eq!(ctx.jobs.queued_len(), 2, "recovered pending jobs");
    let r = dispatch(r#"{"op":"poll","job":"job-1"}"#, &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("queued"), "{r}");
    assert_eq!(r.get("request_id").unwrap().as_str(), Some("wal-0"));
    let r = dispatch(r#"{"op":"poll","job":"job-2"}"#, &ctx);
    assert_eq!(r.get("kind").unwrap().as_str(), Some("launder"), "{r}");

    // ---- drain: forget batch first, then the laundering pass ----------
    assert_eq!(drain_queue_once(&ctx), 2);
    let r = dispatch(r#"{"op":"poll","job":"job-1"}"#, &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("done"), "{r}");
    let r = dispatch(r#"{"op":"poll","job":"job-2"}"#, &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("done"), "{r}");
    assert_eq!(
        r.get_path(&["result", "executed"]).unwrap().as_bool(),
        Some(true),
        "laundering executed: {r}"
    );
    {
        let sys = system.lock().unwrap();
        assert!(sys.forgotten.is_empty(), "laundering reset the set");
        assert!(sys.laundered_total() > 0);
    }

    // status reflects the compaction through the refreshed snapshot
    let r = dispatch(r#"{"op":"status"}"#, &ctx);
    assert_eq!(r.get("forgotten_pending").unwrap().as_u64(), Some(0), "{r}");
    assert!(r.get("laundered_ids").unwrap().as_u64().unwrap() > 0);
    // the backlog drained to zero and the recovered-then-compacted WAL
    // stays bounded by in-flight work
    assert_eq!(r.get("pending_jobs").unwrap().as_u64(), Some(0), "{r}");
    assert!(r.get("jobs_wal_bytes").unwrap().as_u64().is_some());
    assert_eq!(
        r.get("launder_recommended").unwrap().as_bool(),
        Some(false),
        "nothing left to compact: {r}"
    );
    assert!(
        r.get_path(&["cas", "generation"]).unwrap().as_u64().unwrap() >= 1,
        "lineage swapped: {r}"
    );
    assert!(
        r.get_path(&["cas", "objects"]).unwrap().as_u64().unwrap() > 0
    );

    // ---- a second restart sees a fully drained WAL --------------------
    drop(ctx);
    let ctx = ServerCtx::with_jobs_wal(&system, &wal_path).unwrap();
    assert_eq!(ctx.jobs.queued_len(), 0, "completed work is not re-run");
    // new submissions continue the id sequence instead of reusing ids
    let r = dispatch(
        &format!(r#"{{"op":"submit","id":"wal-1","user":{user}}}"#),
        &ctx,
    );
    assert_eq!(r.get("job").unwrap().as_str(), Some("job-3"), "{r}");
}

#[test]
fn auto_launder_runs_after_a_drained_burst_when_enabled() {
    // The worker-side compaction loop (ROADMAP "launder automatically
    // from the worker"): with `RunConfig::auto_launder` set, a drained
    // forget burst that flips `launder_recommended` is followed — under
    // the same system lock — by a laundering pass keyed off the burst's
    // first job id.  The operator never has to poll the status bit.
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("server-auto-launder"),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        warmup: 2,
        auto_launder: true,
        ..Default::default()
    };
    let trained = harness::build_system(&rt, cfg, corpus, false).unwrap();
    let system = Mutex::new(trained.system);

    // an EARLY-influence user: its forgotten history drags rebuild
    // targets before the latest checkpoint, which is what inflates
    // replay tails and makes laundering worthwhile
    let user = {
        let sys = system.lock().unwrap();
        (0..24u32)
            .find(|&u| {
                sys.plan(&unlearn::controller::ForgetRequest {
                    id: format!("probe-{u}"),
                    user: Some(u),
                    sample_ids: vec![],
                    urgency: unlearn::controller::Urgency::Normal,
                })
                .map(|p| {
                    p.offending.first().map(|&t| t < 4).unwrap_or(false)
                })
                .unwrap_or(false)
            })
            .expect("an early-influence user exists")
    };

    let mut ctx = ServerCtx::new(&system).unwrap();
    assert!(ctx.auto_launder, "flag captured from RunConfig");
    // the toy run's tail is short — lower the recommendation threshold
    // so one burst flips the bit (the same policy the status bit uses)
    ctx.launder_policy = unlearn::controller::LaunderPolicy {
        min_extra_replay_records: 1,
    };

    let r = dispatch(
        &format!(r#"{{"op":"submit","id":"auto-0","user":{user}}}"#),
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let job = r.get("job").unwrap().as_str().unwrap().to_string();
    assert_eq!(drain_queue_once(&ctx), 1);
    let r = dispatch(&format!(r#"{{"op":"poll","job":"{job}"}}"#), &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("done"), "{r}");

    {
        let sys = system.lock().unwrap();
        assert!(
            sys.forgotten.is_empty(),
            "auto-launder compacted the burst's forgotten set"
        );
        assert!(sys.laundered_total() > 0);
        // the pass reached the signed manifest under its derived key
        let chain = sys.manifest.verify_chain().unwrap();
        assert!(chain.iter().all(|(_, sig)| *sig));
        assert!(
            chain.iter().any(|(e, _)| {
                e.get("action").and_then(|v| v.as_str()) == Some("launder")
                    && e.get("idempotency_key")
                        .and_then(|v| v.as_str())
                        .map(|k| k.starts_with(&format!(
                            "auto-launder-{job}"
                        )))
                        .unwrap_or(false)
            }),
            "manifest records the auto pass"
        );
    }

    // the read plane sees the compaction through the refreshed snapshot
    let r = dispatch(r#"{"op":"status"}"#, &ctx);
    assert_eq!(r.get("forgotten_pending").unwrap().as_u64(), Some(0), "{r}");
    assert!(r.get("laundered_ids").unwrap().as_u64().unwrap() > 0);
    assert_eq!(r.get("launder_recommended").unwrap().as_bool(), Some(false));
    assert!(
        r.get_path(&["cas", "generation"]).unwrap().as_u64().unwrap() >= 1,
        "lineage swapped: {r}"
    );
}

#[test]
fn auto_launder_stays_off_by_default() {
    // Same burst, default config: the forgotten set survives the drain
    // (laundering remains an explicit operator/cron decision).
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("server-no-auto-launder"),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        warmup: 2,
        ..Default::default()
    };
    let trained = harness::build_system(&rt, cfg, corpus, false).unwrap();
    let system = Mutex::new(trained.system);
    let user = {
        let sys = system.lock().unwrap();
        (0..24u32)
            .find(|&u| {
                sys.plan(&unlearn::controller::ForgetRequest {
                    id: format!("probe-{u}"),
                    user: Some(u),
                    sample_ids: vec![],
                    urgency: unlearn::controller::Urgency::Normal,
                })
                .map(|p| !p.offending.is_empty())
                .unwrap_or(false)
            })
            .expect("a replay-bound user exists")
    };
    let mut ctx = ServerCtx::new(&system).unwrap();
    assert!(!ctx.auto_launder, "off unless the config opts in");
    ctx.launder_policy = unlearn::controller::LaunderPolicy {
        min_extra_replay_records: 1,
    };
    let r = dispatch(
        &format!(r#"{{"op":"submit","id":"noauto-0","user":{user}}}"#),
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert_eq!(drain_queue_once(&ctx), 1);
    let sys = system.lock().unwrap();
    assert!(
        !sys.forgotten.is_empty(),
        "no auto compaction without the flag"
    );
}

#[test]
fn protocol_ops_roundtrip() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("server-proto"),
        steps: 8,
        accum: 2,
        checkpoint_every: 4,
        warmup: 2,
        ..Default::default()
    };
    let trained = harness::build_system(&rt, cfg, corpus, false).unwrap();
    let system = Mutex::new(trained.system);
    let ctx = ServerCtx::new(&system).unwrap();

    // ---- status: read plane, snapshot-backed ---------------------------
    let r = dispatch(r#"{"op":"status"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    assert!(r.get("model_hash").unwrap().as_str().unwrap().len() == 16);
    assert_eq!(r.get("queued_jobs").unwrap().as_u64(), Some(0));

    // ---- pick three replay-bound users (offending steps in the base) ---
    let users: Vec<u32> = {
        let sys = system.lock().unwrap();
        (0..24u32)
            .filter(|&u| {
                sys.plan(&unlearn::controller::ForgetRequest {
                    id: format!("probe-{u}"),
                    user: Some(u),
                    sample_ids: vec![],
                    urgency: unlearn::controller::Urgency::Normal,
                })
                .map(|p| !p.offending.is_empty())
                .unwrap_or(false)
            })
            .take(3)
            .collect()
    };
    assert_eq!(users.len(), 3, "need three replay-bound users");

    // ---- plan: dry-run with cost estimates, zero mutation --------------
    let hashes_before = {
        let sys = system.lock().unwrap();
        (sys.state.model_hash(), sys.state.optimizer_hash())
    };
    let r = dispatch(
        &format!(r#"{{"op":"plan","id":"dry","user":{}}}"#, users[0]),
        &ctx,
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    let plan = r.get("plan").unwrap();
    let steps = plan.get("steps").unwrap().as_arr().unwrap();
    assert!(!steps.is_empty(), "plan has a fallback chain");
    let last = steps.last().unwrap();
    assert_eq!(last.get("kind").unwrap().as_str(), Some("exact_replay"));
    assert!(
        last.get_path(&["cost", "replay_steps"]).unwrap().as_u64().unwrap()
            > 0,
        "cost estimate populated"
    );
    {
        let sys = system.lock().unwrap();
        assert_eq!(
            (sys.state.model_hash(), sys.state.optimizer_hash()),
            hashes_before,
            "plan is a pure dry-run"
        );
        assert_eq!(sys.manifest.len(), 0, "no manifest entry from a dry-run");
    }

    // ---- submit: enqueue, return job ids immediately -------------------
    for (i, u) in users.iter().enumerate() {
        let r = dispatch(
            &format!(r#"{{"op":"submit","id":"srv-{i}","user":{u}}}"#),
            &ctx,
        );
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
        assert_eq!(
            r.get("job").unwrap().as_str(),
            Some(format!("job-{}", i + 1).as_str())
        );
        assert_eq!(r.get("status").unwrap().as_str(), Some("queued"));
    }
    let r = dispatch(r#"{"op":"poll","job":"job-1"}"#, &ctx);
    assert_eq!(r.get("status").unwrap().as_str(), Some("queued"));

    // ---- drain: one batch, one coalesced rebuild -----------------------
    assert_eq!(drain_queue_once(&ctx), 3);
    for i in 1..=3 {
        let r = dispatch(&format!(r#"{{"op":"poll","job":"job-{i}"}}"#), &ctx);
        assert_eq!(r.get("status").unwrap().as_str(), Some("done"), "{r}");
        let result = r.get("result").unwrap();
        assert_eq!(result.get("executed").unwrap().as_bool(), Some(true));
        // the shared rebuild is a ring revert when the union fits the
        // delta-ring window, else a tail replay — both exact
        let action = result.get("action").unwrap().as_str().unwrap();
        assert!(
            action == "exact_replay" || action == "recent_revert",
            "{r}"
        );
        assert_eq!(
            result.get_path(&["details", "coalesced"]).unwrap().as_u64(),
            Some(3),
            "all three requests shared one rebuild"
        );
    }
    let r = dispatch(r#"{"op":"jobs"}"#, &ctx);
    assert_eq!(r.get("jobs").unwrap().as_arr().unwrap().len(), 3);

    // snapshot refreshed by the drain
    let r = dispatch(r#"{"op":"status"}"#, &ctx);
    assert_ne!(
        r.get("model_hash").unwrap().as_str().unwrap(),
        hashes_before.0,
        "the coalesced replay changed the serving state"
    );
    assert_eq!(r.get("manifest_entries").unwrap().as_u64(), Some(3));

    // ---- duplicate idempotency key through the queue -------------------
    let r = dispatch(
        &format!(r#"{{"op":"submit","id":"srv-0","user":{}}}"#, users[0]),
        &ctx,
    );
    let dup_job = r.get("job").unwrap().as_str().unwrap().to_string();
    assert_eq!(drain_queue_once(&ctx), 1);
    let r = dispatch(&format!(r#"{{"op":"poll","job":"{dup_job}"}}"#), &ctx);
    assert_eq!(
        r.get_path(&["result", "executed"]).unwrap().as_bool(),
        Some(false),
        "duplicate suppressed: {r}"
    );

    // ---- legacy sync forget op still works -----------------------------
    let r = dispatch(r#"{"op":"forget","id":"sync-1","user":20}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert_eq!(r.get("executed").unwrap().as_bool(), Some(true));
    let r = dispatch(r#"{"op":"forget","id":"sync-1","user":20}"#, &ctx);
    assert_eq!(r.get("executed").unwrap().as_bool(), Some(false));

    // ---- manifest verification: lock-free, from disk -------------------
    let r = dispatch(r#"{"op":"manifest"}"#, &ctx);
    assert_eq!(r.get("signatures_valid").unwrap().as_bool(), Some(true));
    assert_eq!(r.get("entries").unwrap().as_u64(), Some(4));

    // ---- audit: lock-free, snapshot-backed -----------------------------
    let r = dispatch(r#"{"op":"audit"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r}");
    assert!(r.get("report").is_some());

    // ---- malformed input -> structured error, no panic -----------------
    let r = dispatch("not json", &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    let r = dispatch(r#"{"op":"nope"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    let r = dispatch(r#"{"op":"forget"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    let r = dispatch(r#"{"op":"poll","job":"job-99"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));

    // ---- shutdown flag -------------------------------------------------
    let r = dispatch(r#"{"op":"shutdown"}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    assert!(ctx.shutdown.load(std::sync::atomic::Ordering::SeqCst));

    // ---- poisoned system lock: typed error, read plane survives --------
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _g = system.lock().unwrap();
        panic!("poison the admin lock");
    }));
    std::panic::set_hook(prev);
    assert!(system.lock().is_err(), "lock is poisoned");
    let r = dispatch(r#"{"op":"forget","id":"after-poison","user":1}"#, &ctx);
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(
        r.get("error_kind").unwrap().as_str(),
        Some("lock_poisoned"),
        "{r}"
    );
    let r = dispatch(r#"{"op":"status"}"#, &ctx);
    assert_eq!(
        r.get("ok").unwrap().as_bool(),
        Some(true),
        "read plane never touches the poisoned lock"
    );
}
