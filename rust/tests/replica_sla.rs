//! The erasure-propagation SLA, end to end: a fleet trains, a user is
//! forgotten and the shard laundered, and every attached read replica
//! must (a) adopt the clean lineage through the launder pass's
//! invalidation fan-out, (b) serve eval losses BIT-IDENTICAL to the
//! source shard's, (c) ship strictly fewer bytes on the launder
//! re-sync than its cold mirror cost (content addressing pulls only
//! rewritten tensors), and (d) report the propagation watermark —
//! `fleet_status` carries per-replica `{generation, lag, last_sync}`
//! plus `erasure_propagation_ms`, and a stale replica's query plane
//! stamps `stale: true` on answers until it re-syncs.

use std::path::{Path, PathBuf};

use unlearn::audit::{per_example_loss_counts, ModelView};
use unlearn::checkpoint::{CheckpointStore, TrainState};
use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, LaunderPolicy, Urgency};
use unlearn::data::corpus::Corpus;
use unlearn::fleet::{Fleet, FleetConfig};
use unlearn::harness;
use unlearn::replica::{dispatch_replica, Replica, ReplicaCtx};
use unlearn::runtime::Runtime;
use unlearn::shard::ShardSpec;
use unlearn::util::tempdir;

const FORGET_USER: u32 = 2;

fn fleet_cfg(tag: &str) -> FleetConfig {
    FleetConfig {
        root: tempdir(tag),
        spec: ShardSpec {
            n_shards: 2,
            salt: 0x51AB,
        },
        base: RunConfig {
            steps: 8,
            accum: 2,
            checkpoint_every: 4,
            checkpoint_keep: 16,
            ring_window: 4,
            warmup: 2,
            ..Default::default()
        },
        scale_steps: false,
        // any pending forgotten set makes laundering due immediately
        launder_policy: LaunderPolicy {
            min_extra_replay_records: 0,
        },
        auto_launder: false,
    }
}

/// The latest full checkpoint of the store at `root` — what both the
/// source shard and a replica serve.
fn latest_full(root: &Path) -> (u32, TrainState) {
    let store = CheckpointStore::open(root, usize::MAX).expect("open");
    let steps = store.list_full().expect("list");
    let step = *steps.last().expect("at least one full checkpoint");
    (step, store.load_full(step).expect("load"))
}

/// Sample ids of a surviving user co-resident on the forgotten user's
/// shard — the eval workload whose losses must not depend on which
/// mirror answered.
fn survivor_ids(fleet: &Fleet, shard: u32, corpus: &Corpus) -> Vec<u64> {
    let shard_corpus = &fleet.shard(shard).expect("shard populated").corpus;
    (0..corpus.config.n_users as u32)
        .filter(|&u| u != FORGET_USER && fleet.spec.assign(u) == shard)
        .flat_map(|u| shard_corpus.user_samples(u))
        .collect()
}

fn src_root(fleet: &Fleet, shard: u32) -> PathBuf {
    fleet.root.join(format!("shard-{shard:04}")).join("ckpt")
}

#[test]
fn erasure_propagates_to_every_replica_bit_identically() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let mut fleet = Fleet::train(&rt, fleet_cfg("sla-fleet"), corpus.clone())
        .expect("fleet train");
    let shard = fleet.spec.assign(FORGET_USER);
    let shard_corpus = fleet.shard(shard).expect("shard").corpus.clone();
    let ids = survivor_ids(&fleet, shard, &corpus);
    assert!(!ids.is_empty(), "a survivor shares the forgotten shard");

    // cold mirrors: full fidelity from the first sync
    let source = src_root(&fleet, shard);
    let (pre_step, pre_state) = latest_full(&source);
    let mut cold = Vec::new();
    for r in 0..2 {
        let dir = tempdir(&format!("sla-replica-{r}"));
        let (_, stats) = fleet.attach_replica(shard, &dir).expect("attach");
        assert!(stats.objects_pulled > 0 && stats.bytes_pulled > 0);
        cold.push(stats);
    }
    for att in fleet.replicas() {
        let sv = att.replica.load_serving_state().expect("cold serve");
        assert_eq!(sv.step, pre_step);
        assert!(
            sv.state.bits_equal(&pre_state),
            "cold mirror serves the source's exact bits"
        );
    }

    // forget + launder: the fan-out inside `launder_due` must leave
    // every replica on the clean lineage
    let req = ForgetRequest {
        id: "sla-forget".to_string(),
        user: Some(FORGET_USER),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    let out = fleet.forget(&req).expect("fleet forget");
    assert!(out.outcomes[0].executed(), "forget must commit");
    let passes = fleet.launder_due("sla");
    assert!(
        passes
            .iter()
            .any(|(s, r)| *s == shard && matches!(r, Ok(o) if o.executed)),
        "the forgotten user's shard must launder"
    );

    // the SLA is observable: wall ms from launder trigger to the last
    // replica adopting, surfaced both on the struct and in fleet_status
    let ms = fleet
        .last_propagation_ms
        .expect("launder pass with attached replicas records the SLA");
    assert!(ms.is_finite() && ms >= 0.0);
    let status = fleet.status_json();
    assert_eq!(
        status
            .get("erasure_propagation_ms")
            .and_then(|v| v.as_f64())
            .map(|v| v.to_bits()),
        Some(ms.to_bits())
    );
    let reps = status
        .get("replicas")
        .and_then(|v| v.as_arr())
        .expect("fleet_status embeds replica rows");
    assert_eq!(reps.len(), 2);
    for row in reps {
        assert_eq!(row.get("shard").and_then(|v| v.as_u64()), Some(shard as u64));
        assert_eq!(row.get("lag").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(row.get("stale").and_then(|v| v.as_bool()), Some(false));
        assert!(
            row.get("last_sync")
                .and_then(|s| s.get("bytes_pulled"))
                .and_then(|v| v.as_u64())
                .is_some(),
            "per-replica transfer accounting is reported"
        );
    }

    // bit-identity: replica-served eval losses == source shard's
    let (post_step, post_state) = latest_full(&source);
    assert!(
        !post_state.bits_equal(&pre_state),
        "laundering rewrote the serving state"
    );
    let src_losses = per_example_loss_counts(
        &rt,
        ModelView::Base(&post_state.params),
        &shard_corpus,
        &ids,
    )
    .expect("source eval");
    for (r, att) in fleet.replicas().iter().enumerate() {
        let sv = att.replica.load_serving_state().expect("replica serves");
        assert_eq!(sv.step, post_step);
        assert!(
            sv.state.bits_equal(&post_state),
            "replica {r} adopted the laundered lineage bit-intact"
        );
        let rep_losses = per_example_loss_counts(
            &rt,
            ModelView::Base(&sv.state.params),
            &shard_corpus,
            &ids,
        )
        .expect("replica eval");
        assert_eq!(src_losses.len(), rep_losses.len());
        for (i, ((sl, sc), (rl, rc))) in
            src_losses.iter().zip(&rep_losses).enumerate()
        {
            assert_eq!(
                sl.to_bits(),
                rl.to_bits(),
                "replica {r} loss for id {} is bit-identical",
                ids[i]
            );
            assert_eq!(sc.to_bits(), rc.to_bits());
        }

        // dedup bound: the launder re-sync ships only rewritten
        // tensors — strictly fewer bytes than this mirror's cold sync,
        // with CAS hits on the untouched clean-prefix objects
        let warm = att.replica.last_sync().expect("synced in launder pass");
        assert!(!warm.already_current);
        assert!(
            warm.objects_reused > 0,
            "replica {r} re-used clean-prefix objects (got none)"
        );
        assert!(
            warm.bytes_pulled < cold[r].bytes_pulled,
            "replica {r} launder re-sync ({} B) must ship strictly \
             fewer bytes than its cold mirror ({} B)",
            warm.bytes_pulled,
            cold[r].bytes_pulled
        );
    }
}

#[test]
fn stale_replica_answers_are_watermarked_until_resync() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let mut fleet = Fleet::train(&rt, fleet_cfg("sla-wm"), corpus.clone())
        .expect("fleet train");
    let shard = fleet.spec.assign(FORGET_USER);
    let shard_corpus = fleet.shard(shard).expect("shard").corpus.clone();
    let ids = survivor_ids(&fleet, shard, &corpus);
    let eval_line = format!(
        "{{\"op\":\"eval\",\"ids\":[{}]}}",
        ids.iter()
            .map(|i| i.to_string())
            .collect::<Vec<_>>()
            .join(",")
    );

    // a standalone replica synced BEFORE the erasure (not attached to
    // the fleet, so the launder pass does not re-sync it for us)
    let source = src_root(&fleet, shard);
    let mut replica =
        Replica::open(&source, &tempdir("sla-wm-replica")).expect("open");
    replica.sync().expect("cold sync");
    let g0 = replica.generation().expect("adopted");
    let ctx = ReplicaCtx::new(&rt, shard_corpus.clone(), replica);

    let fresh = dispatch_replica("{\"op\":\"replica_status\"}", &ctx);
    assert_eq!(fresh.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(fresh.get("stale").and_then(|v| v.as_bool()), Some(false));

    // erase on the source: the replica is now one generation behind
    let req = ForgetRequest {
        id: "sla-wm-forget".to_string(),
        user: Some(FORGET_USER),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    assert!(fleet.forget(&req).expect("forget").outcomes[0].executed());
    assert!(fleet
        .launder_due("sla-wm")
        .iter()
        .any(|(s, r)| *s == shard && matches!(r, Ok(o) if o.executed)));

    // stale answers still flow, but carry the watermark — the query
    // plane never silently presents a pre-erasure state as current
    let stale = dispatch_replica(&eval_line, &ctx);
    assert_eq!(stale.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(stale.get("stale").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(stale.get("generation").and_then(|v| v.as_u64()), Some(g0));
    assert!(
        stale.get("lag").and_then(|v| v.as_u64()).unwrap_or(0) >= 1,
        "lag counts the missed lineage swap"
    );

    // re-sync through the query plane, then answers are clean AND
    // bit-identical to the source's laundered state
    let synced = dispatch_replica("{\"op\":\"sync\"}", &ctx);
    assert_eq!(synced.get("ok").and_then(|v| v.as_bool()), Some(true));
    let (_, post_state) = latest_full(&source);
    let direct = per_example_loss_counts(
        &rt,
        ModelView::Base(&post_state.params),
        &shard_corpus,
        &ids,
    )
    .expect("source eval");
    let clean = dispatch_replica(&eval_line, &ctx);
    assert_eq!(clean.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(clean.get("stale").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(clean.get("lag").and_then(|v| v.as_u64()), Some(0));
    let rows = clean
        .get("results")
        .and_then(|v| v.as_arr())
        .expect("eval rows");
    assert_eq!(rows.len(), direct.len());
    for (row, (l, _)) in rows.iter().zip(&direct) {
        let got = row.get("loss").and_then(|v| v.as_f64()).expect("loss");
        assert_eq!(
            got.to_bits(),
            (*l as f64).to_bits(),
            "replica-served loss is bit-identical to the source's"
        );
    }

    // the forgotten user's samples are gone from the query plane's
    // corpus view only if the caller filters them; an unknown id is a
    // typed refusal, not a silent zero
    let bogus = dispatch_replica("{\"op\":\"eval\",\"ids\":[999999]}", &ctx);
    assert_eq!(bogus.get("ok").and_then(|v| v.as_bool()), Some(false));
}
