//! Path-selection matrix over the pure `Planner` (paper Alg. A.7 as a
//! decision table): one case per `PlanStep` variant plus every
//! escalation edge, against *fabricated* `SystemView`s — a synthetic
//! WAL, a scripted ring window and checkpoint list, no training and no
//! runtime.  This is exactly what the planner/executor split buys:
//! routing policy is testable without executing anything.

use std::collections::HashSet;

use unlearn::adapters::{Adapter, AdapterRegistry};
use unlearn::controller::{
    ForgetRequest, PlanStep, Planner, SystemView, UnlearnError, Urgency,
};
use unlearn::curvature::HotPathParams;
use unlearn::data::corpus::Corpus;
use unlearn::deltas::RingBudget;
use unlearn::harness;
use unlearn::manifest::{ActionKind, ForgetManifest, ManifestEntry};
use unlearn::neardup::closure::build_index;
use unlearn::neardup::{ClosureParams, HammingIndex};
use unlearn::util::json::Json;
use unlearn::wal::{IdMap, WalRecord};

/// 12 logical steps, 4 samples each, in corpus order: sample `ids[i]`
/// influences exactly step `i / 4`.
struct Fix {
    corpus: Corpus,
    ndindex: HammingIndex,
    ids: Vec<u64>,
    records: Vec<WalRecord>,
    idmap: IdMap,
    manifest: ForgetManifest,
    adapters: AdapterRegistry,
    forgotten: HashSet<u64>,
}

fn fix() -> Fix {
    let corpus = harness::small_corpus(32);
    let ndindex = build_index(&corpus);
    let ids: Vec<u64> = corpus.samples.iter().map(|s| s.id).collect();
    assert!(ids.len() >= 60, "fixture needs spare samples outside the WAL");
    let mut idmap = IdMap::new(None);
    let mut records = Vec::new();
    for step in 0..12u32 {
        let chunk: Vec<u64> =
            ids[step as usize * 4..step as usize * 4 + 4].to_vec();
        let h = idmap.register(&chunk);
        records.push(WalRecord {
            hash64: h,
            seed64: 0,
            lr_bits: 0,
            opt_step: step,
            accum_end: true,
            mb_len: chunk.len() as u16,
        });
    }
    let manifest = ForgetManifest::open(
        &unlearn::util::tempdir("planner-matrix").join("forget.manifest"),
        b"k",
    )
    .unwrap();
    Fix {
        corpus,
        ndindex,
        ids,
        records,
        idmap,
        manifest,
        adapters: AdapterRegistry::new(),
        forgotten: HashSet::new(),
    }
}

/// Baseline view: ring covers steps [8, 12), checkpoints at 0/4/8/12,
/// serving step 12, no fisher, not diverged.
fn view<'a>(f: &'a Fix) -> SystemView<'a> {
    SystemView {
        corpus: &f.corpus,
        ndindex: &f.ndindex,
        // impossible thresholds: closure == requested ids exactly, so
        // each case controls its offending steps precisely
        closure_params: ClosureParams {
            tau_hamming: 0,
            tau_sim: 1.1,
        },
        adapters: &f.adapters,
        records: &f.records,
        idmap: &f.idmap,
        manifest: &f.manifest,
        forgotten: &f.forgotten,
        ring_earliest: Some(8),
        ring_available: 4,
        ring_budget: RingBudget {
            per_step_bytes_raw: 4000,
            window: 4,
            pre_compress_total: 16000,
            stored_bytes: 400,
            compress_ratio: 0.1,
            record_count: 12,
            record_secs_mean: 1e-4,
            record_secs_last: 1e-4,
            revert_secs_mean: 1e-4,
        },
        ring_patch_sizes: vec![100; 4],
        logical_step: 12,
        diverged: false,
        ring_bit_exact: true,
        fisher_available: false,
        hot_path: HotPathParams::default(),
        resume_after_revert: true,
        checkpoints: vec![0, 4, 8, 12],
        checkpoint_bytes: 1 << 20,
        param_count: 1000,
        lora_param_count: 64,
        step_secs_mean: 1e-3,
    }
}

fn req(id: &str, sample_ids: Vec<u64>, urgency: Urgency) -> ForgetRequest {
    ForgetRequest {
        id: id.into(),
        user: None,
        sample_ids,
        urgency,
    }
}

fn kinds(plan: &unlearn::controller::UnlearnPlan) -> Vec<&'static str> {
    plan.steps.iter().map(|s| s.step.kind()).collect()
}

#[test]
fn path_selection_matrix() {
    let f = fix();

    // Each row: (name, request, view tweak, expected step-kind chain,
    // expected note kinds).  `f.ids[i]` influences step i/4.
    type Tweak = fn(&mut SystemView<'_>);
    let rows: Vec<(&str, ForgetRequest, Tweak, Vec<&str>, Vec<&str>)> = vec![
        (
            "old influence -> exact replay, ring window miss noted",
            req("m-replay", vec![f.ids[4]], Urgency::Normal), // step 1
            |_| {},
            vec!["exact_replay"],
            vec!["ring_window_miss"],
        ),
        (
            "recent-only influence -> ring revert, replay fallback",
            req("m-ring", vec![f.ids[40]], Urgency::Normal), // step 10
            |_| {},
            vec!["ring_revert", "exact_replay"],
            vec![],
        ),
        (
            "urgent + fisher -> hot path before replay",
            req("m-hot", vec![f.ids[4]], Urgency::High),
            |v| v.fisher_available = true,
            vec!["hot_path_anti_update", "exact_replay"],
            vec!["ring_window_miss"],
        ),
        (
            "urgent without fisher -> escalation note, no hot path",
            req("m-nofisher", vec![f.ids[4]], Urgency::High),
            |_| {},
            vec!["exact_replay"],
            vec!["ring_window_miss", "no_fisher_cache"],
        ),
        (
            "diverged state -> ring ruled out even for recent influence",
            req("m-diverged", vec![f.ids[40]], Urgency::Normal),
            |v| v.diverged = true,
            vec!["exact_replay"],
            vec!["ring_diverged"],
        ),
        (
            "recent influence, emptied ring -> window miss",
            req("m-ringmiss", vec![f.ids[40]], Urgency::Normal),
            |v| {
                v.ring_earliest = None;
                v.ring_available = 0;
                v.ring_patch_sizes.clear();
            },
            vec!["exact_replay"],
            vec!["ring_window_miss"],
        ),
    ];

    for (name, request, tweak, want_steps, want_notes) in rows {
        let mut v = view(&f);
        tweak(&mut v);
        let plan = Planner::plan(&v, &request).unwrap_or_else(|e| {
            panic!("case {name:?}: planning failed: {e}")
        });
        assert_eq!(kinds(&plan), want_steps, "case {name:?}");
        let notes: Vec<&str> = plan.notes.iter().map(|n| n.kind()).collect();
        assert_eq!(notes, want_notes, "case {name:?}");
        assert!(!plan.offending.is_empty(), "case {name:?}");
        assert!(plan.effective_target.is_some(), "case {name:?}");
    }
}

#[test]
fn adapter_paths_and_noop() {
    let mut f = fix();
    // cohort adapter scoped over samples the base never saw (outside
    // the WAL: ids[48..52]) plus one the base DID see (ids[0]).
    let outside: Vec<u64> = f.ids[48..52].to_vec();
    f.adapters
        .insert(Adapter {
            cohort: 9,
            params: vec![0.0; 8],
            trained_on: outside.clone(),
            steps: 1,
            merged: false,
        })
        .unwrap();
    f.adapters
        .insert(Adapter {
            cohort: 10,
            params: vec![0.0; 8],
            trained_on: vec![f.ids[0]],
            steps: 1,
            merged: false,
        })
        .unwrap();

    // confined to an adapter, no base influence: single-step plan
    let v = view(&f);
    let plan =
        Planner::plan(&v, &req("m-adapter", vec![outside[0]], Urgency::Normal))
            .unwrap();
    assert_eq!(kinds(&plan), vec!["adapter_delete"]);
    assert!(plan.offending.is_empty());
    assert_eq!(plan.effective_target, None);
    match &plan.steps[0].step {
        PlanStep::AdapterDelete { cohorts } => assert_eq!(cohorts, &vec![9]),
        other => panic!("unexpected step {other:?}"),
    }

    // adapter-covered but ALSO in the base -> audit-failure fallback
    // chain behind the adapter step (the escalation edge is planned)
    let plan =
        Planner::plan(&v, &req("m-adapter2", vec![f.ids[0]], Urgency::Normal))
            .unwrap();
    assert_eq!(kinds(&plan), vec!["adapter_delete", "exact_replay"]);
    assert_eq!(plan.offending, vec![0]);

    // no adapter, no base influence -> audited no-op (Refused action)
    let f2 = fix();
    let v2 = view(&f2);
    let plan =
        Planner::plan(&v2, &req("m-noop", vec![f2.ids[48]], Urgency::Normal))
            .unwrap();
    assert_eq!(kinds(&plan), vec!["no_op"]);
    assert_eq!(plan.steps[0].step.action_kind(), ActionKind::Refused);
}

#[test]
fn planner_error_taxonomy() {
    let mut f = fix();

    // empty closure
    let v = view(&f);
    assert!(matches!(
        Planner::plan(&v, &req("m-empty", vec![], Urgency::Normal)),
        Err(UnlearnError::EmptyClosure)
    ));

    // no checkpoint at all -> fail-closed (nothing can rebuild)
    let mut v = view(&f);
    v.checkpoints.clear();
    match Planner::plan(&v, &req("m-nockpt", vec![f.ids[4]], Urgency::Normal))
    {
        Err(UnlearnError::NoCheckpoint { target }) => assert_eq!(target, 1),
        other => panic!("expected NoCheckpoint, got {other:?}"),
    }

    // duplicate idempotency key
    f.manifest
        .append(&ManifestEntry {
            idempotency_key: "m-dup".into(),
            request: Json::obj(),
            closure_summary: Json::obj(),
            action: ActionKind::ExactReplay,
            details: Json::obj(),
            audits: None,
            artifacts: Json::obj(),
        })
        .unwrap();
    let v = view(&f);
    match Planner::plan(&v, &req("m-dup", vec![f.ids[4]], Urgency::Normal)) {
        Err(UnlearnError::DuplicateRequest { id }) => assert_eq!(id, "m-dup"),
        other => panic!("expected DuplicateRequest, got {other:?}"),
    }
}

#[test]
fn cost_estimates_rank_paths() {
    let f = fix();
    let v = view(&f);

    // recent influence: revert(2 patches)+resume(2 records) undercuts a
    // 4-record replay from checkpoint 8
    let plan =
        Planner::plan(&v, &req("m-cost", vec![f.ids[40]], Urgency::Normal))
            .unwrap();
    let ring = &plan.steps[0];
    let replay = &plan.steps[1];
    assert!(matches!(ring.step, PlanStep::RingRevert { steps: 2, .. }));
    match replay.step {
        PlanStep::ExactReplay { from_checkpoint, target_step } => {
            assert_eq!(from_checkpoint, 8);
            assert_eq!(target_step, 10);
        }
        ref other => panic!("unexpected step {other:?}"),
    }
    assert_eq!(ring.cost.replay_steps, 2, "resume tail after revert");
    assert_eq!(replay.cost.replay_steps, 4, "tail from checkpoint 8");
    assert_eq!(ring.cost.bytes_touched % 1000, 200, "2 patches @ 100B");
    assert!(replay.cost.bytes_touched >= 1 << 20, "checkpoint load");
    assert!(
        ring.cost.est_wall_secs < replay.cost.est_wall_secs,
        "Alg. A.7 ordering is cost-ascending here"
    );
    assert_eq!(
        plan.cheapest().unwrap().step.kind(),
        "ring_revert",
        "budget query agrees"
    );

    // the cumulative-union rule: previously forgotten influence at step
    // 1 drags the rebuild target back even for a recent-only request
    let mut f2 = fix();
    f2.forgotten.insert(f2.ids[4]); // influences step 1
    let v2 = view(&f2);
    let plan2 =
        Planner::plan(&v2, &req("m-union", vec![f2.ids[40]], Urgency::Normal))
            .unwrap();
    assert_eq!(plan2.offending, vec![10], "request's own influence");
    assert_eq!(
        plan2.effective_target,
        Some(1),
        "rebuild target covers the union"
    );
    assert_eq!(kinds(&plan2), vec!["exact_replay"], "ring cannot reach");
}
