//! Controller policy integration (paper Alg. A.7 / Fig. 1): every
//! routing branch through the real stack, plus manifest/idempotency
//! semantics.  One shared fixture run keeps wall-clock bounded.

use std::collections::HashSet;

use unlearn::config::RunConfig;
use unlearn::controller::{ForgetRequest, PlanStep, UnlearnError, Urgency};
use unlearn::harness;
use unlearn::manifest::ActionKind;
use unlearn::runtime::Runtime;

#[test]
fn controller_routes_all_paths() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let mut corpus = harness::toy_corpus(rt.manifest.seq_len);
    corpus.tag_cohort(&[150, 151], 9);
    let cohort_ids: Vec<u64> = [150u32, 151]
        .iter()
        .flat_map(|&u| corpus.user_samples(u))
        .collect();
    let cohort_set: HashSet<u64> = cohort_ids.iter().copied().collect();

    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("ctl-paths"),
        steps: 12,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    let out = unlearn::trainer::Trainer::new(&rt, cfg.clone(), corpus.clone())
        .train_excluding(&cohort_set)
        .unwrap();
    let trained =
        harness::system_from_run(&rt, cfg, corpus.clone(), out, true).unwrap();
    let mut system = trained.system;
    system
        .adapters
        .train_cohort(&rt, &corpus, &system.state.params, 9, &cohort_ids, 4,
                      5e-3, 1)
        .unwrap();
    let base_hash = system.state.model_hash();

    // ---- path 1: cohort-confined -> adapter deletion, base untouched --
    let o = system
        .handle(&ForgetRequest {
            id: "t-adapter".into(),
            user: Some(150),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        })
        .unwrap();
    assert_eq!(o.action, ActionKind::AdapterDelete);
    assert_eq!(system.state.model_hash(), base_hash, "G2: base untouched");
    assert!(system.adapters.get(9).is_none());

    // ---- path 2: recent-only influence -> ring revert ------------------
    // candidates first seen inside the ring window whose *closure* also
    // stays inside it (near-dup expansion can reach back in time)
    let recent_set: HashSet<u64> = harness::ids_first_seen_at_or_after(
        &system.records,
        &system.idmap,
        10,
    )
    .into_iter()
    .collect();
    let mut recent_sorted: Vec<u64> = recent_set.iter().copied().collect();
    recent_sorted.sort_unstable(); // HashSet order is per-process random
    let recent: Vec<u64> = recent_sorted
        .into_iter()
        .filter(|&id| {
            let (cl, _) = system.closure_of(&ForgetRequest {
                id: "probe".into(),
                user: None,
                sample_ids: vec![id],
                urgency: Urgency::Normal,
            });
            cl.iter().all(|c| recent_set.contains(c))
        })
        .take(3)
        .collect();
    assert!(!recent.is_empty());
    let o = system
        .handle(&ForgetRequest {
            id: "t-revert".into(),
            user: None,
            sample_ids: recent,
            urgency: Urgency::Normal,
        })
        .unwrap();
    // the revert path must be TAKEN; with toy-scale audit noise it may
    // escalate to exact replay, which the manifest then records — both
    // are correct routings (Alg. A.7 escalates on audit failure)
    assert!(
        o.action == ActionKind::RecentRevert
            || (o.action == ActionKind::ExactReplay
                && o.escalations.iter().any(|e| matches!(
                    e,
                    UnlearnError::AuditFailed {
                        path: ActionKind::RecentRevert
                    }
                ))),
        "action {:?}, escalations {:?}",
        o.action,
        o.escalations
    );
    assert_ne!(system.state.model_hash(), base_hash);

    // ---- path 3: urgent -> hot path or audited escalation --------------
    let o = system
        .handle(&ForgetRequest {
            id: "t-urgent".into(),
            user: Some(1),
            sample_ids: vec![],
            urgency: Urgency::High,
        })
        .unwrap();
    assert!(
        matches!(
            o.action,
            ActionKind::HotPathAntiUpdate | ActionKind::ExactReplay
        ),
        "urgent requests go hot-path first, escalate on audit failure"
    );

    // ---- path 4: normal + old influence -> exact replay ----------------
    // dry-run first: the plan predicts the replay (ring is ruled out —
    // the state diverged from the logged trajectory) and mutates nothing
    let replay_req = ForgetRequest {
        id: "t-replay".into(),
        user: Some(2),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    let hashes = (system.state.model_hash(), system.state.optimizer_hash());
    let plan = system.plan(&replay_req).unwrap();
    assert!(matches!(
        plan.steps.last().unwrap().step,
        PlanStep::ExactReplay { .. }
    ));
    assert!(
        plan.notes.iter().any(|n| matches!(n, UnlearnError::RingDiverged)),
        "notes {:?}",
        plan.notes
    );
    assert!(plan.steps.last().unwrap().cost.replay_steps > 0);
    assert_eq!(
        (system.state.model_hash(), system.state.optimizer_hash()),
        hashes,
        "planning is a pure dry-run"
    );
    let o = system.handle(&replay_req).unwrap();
    assert_eq!(o.action, ActionKind::ExactReplay);
    assert!(o.details.get("from_checkpoint").is_some());

    // ---- idempotency + signed chain -------------------------------------
    let dup = system
        .handle(&ForgetRequest {
            id: "t-replay".into(),
            user: Some(2),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        })
        .unwrap();
    assert!(!dup.executed);
    let chain = system.manifest.verify_chain().unwrap();
    assert_eq!(chain.len(), 4);
    assert!(chain.iter().all(|(_, sig)| *sig), "all entries signed");
    let actions: Vec<String> = chain
        .iter()
        .map(|(e, _)| {
            e.get("action").and_then(|v| v.as_str()).unwrap().to_string()
        })
        .collect();
    assert_eq!(actions[0], "adapter_delete");
    assert!(actions[1] == "recent_revert" || actions[1] == "exact_replay");
    assert_eq!(actions[3], "exact_replay");
}
