//! Audit harness integration: the leakage signals must move in the
//! right direction — a model trained WITH the forget set looks more
//! member-like than one trained WITHOUT it; greedy decoding is
//! deterministic; exposure sits near chance on an untrained model.

use std::collections::HashSet;

use unlearn::audit::{self, AuditContext, ModelView};
use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

#[test]
fn leakage_signals_move_the_right_way() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("audit-pipe"),
        steps: 30,
        accum: 2,
        checkpoint_every: 10,
        checkpoint_keep: 8,
        warmup: 5,
        lr: 5e-3,
        ..Default::default()
    };
    let forget: Vec<u64> = corpus.user_samples(0); // canaried user
    let fset: HashSet<u64> = forget.iter().copied().collect();

    let with = Trainer::new(&rt, cfg.clone(), corpus.clone())
        .train(|_| false)
        .unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.run_dir = unlearn::util::tempdir("audit-pipe-oracle");
    let without = Trainer::new(&rt, cfg2, corpus.clone())
        .train(|id| fset.contains(&id))
        .unwrap();

    let (retain_ids, eval_ids) = harness::audit_splits(&corpus, &fset, 3);
    let ctx = AuditContext {
        rt: &rt,
        corpus: &corpus,
        forget_ids: &forget,
        retain_ids: &retain_ids,
        eval_ids: &eval_ids,
        baseline_ppl: None,
        thresholds: Default::default(),
        seed: 3,
    };
    let rep_with =
        audit::run_audits(&ctx, ModelView::Base(&with.state.params)).unwrap();
    let rep_without =
        audit::run_audits(&ctx, ModelView::Base(&without.state.params))
            .unwrap();

    assert!(
        rep_with.mia_auc > rep_without.mia_auc - 0.05,
        "MIA: with {} vs without {}",
        rep_with.mia_auc,
        rep_without.mia_auc
    );
    let ratio = rep_with.retain_ppl / rep_without.retain_ppl;
    assert!(ratio > 0.5 && ratio < 2.0, "ppl ratio {ratio}");
    assert!(rep_with.to_json().encode().contains("mia_auc"));
}

#[test]
fn shared_evals_are_bit_transparent() {
    // The batch-audit optimization: precomputed retain/utility chunks
    // must yield a report identical to the fully-inline path (both are
    // pure functions of (state, id list)), for different forget sets
    // sharing one precomputation — exactly the coalesced-batch shape.
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let params = rt.manifest.init_params().unwrap();
    let view = ModelView::Base(&params);
    let forget_a: Vec<u64> = corpus.user_samples(0);
    let forget_b: Vec<u64> = corpus.user_samples(3);
    let fset: HashSet<u64> =
        forget_a.iter().chain(forget_b.iter()).copied().collect();
    let (retain_ids, eval_ids) = harness::audit_splits(&corpus, &fset, 9);
    let ctx_a = AuditContext {
        rt: &rt,
        corpus: &corpus,
        forget_ids: &forget_a,
        retain_ids: &retain_ids,
        eval_ids: &eval_ids,
        baseline_ppl: Some(60.0),
        thresholds: Default::default(),
        seed: 11,
    };
    let ctx_b = AuditContext {
        forget_ids: &forget_b,
        thresholds: Default::default(),
        ..ctx_a
    };
    let shared = audit::shared_evals(&ctx_a, view).unwrap();
    for ctx in [&ctx_a, &ctx_b] {
        let inline = audit::run_audits(ctx, view).unwrap();
        let reused =
            audit::run_audits_with(ctx, view, Some(&shared)).unwrap();
        assert_eq!(
            inline.to_json().encode(),
            reused.to_json().encode(),
            "shared retain/utility chunks must not change the report"
        );
    }
}

#[test]
fn batched_forget_probes_are_bit_transparent() {
    // The coalesced-batch probe optimization: evaluating EVERY member's
    // forget-probe losses in one `eval_batch` call over the closure
    // union (audit::batch_forget_losses) must yield reports identical
    // to per-request `eval_loss` probing — per-slot losses are pure
    // functions of (state, sample), so neither the union's chunking nor
    // its ordering can move a bit.
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let params = rt.manifest.init_params().unwrap();
    let view = ModelView::Base(&params);
    let forget_a: Vec<u64> = corpus.user_samples(0);
    let forget_b: Vec<u64> = corpus.user_samples(3);
    let forget_c: Vec<u64> = corpus.user_samples(7);
    let fset: HashSet<u64> = forget_a
        .iter()
        .chain(forget_b.iter())
        .chain(forget_c.iter())
        .copied()
        .collect();
    let (retain_ids, eval_ids) = harness::audit_splits(&corpus, &fset, 17);
    // direct check on the primitive: the batched map holds exactly the
    // per-request per-example losses
    let closures: Vec<&[u64]> =
        vec![&forget_a, &forget_b, &forget_c];
    let map =
        audit::batch_forget_losses(&rt, view, &corpus, &closures).unwrap();
    for closure in &closures {
        let inline =
            audit::per_example_losses(&rt, view, &corpus, closure).unwrap();
        for (id, l) in closure.iter().zip(inline) {
            assert_eq!(
                map.get(id).copied().map(f32::to_bits),
                Some(l.to_bits()),
                "batched probe loss drifted for sample {id}"
            );
        }
    }
    // end-to-end: a report built from the shared+batched probes equals
    // the fully-inline report, for every member of the "batch"
    let forgets: Vec<Vec<u64>> = vec![forget_a, forget_b, forget_c];
    for forget in &forgets {
        let ctx = AuditContext {
            rt: &rt,
            corpus: &corpus,
            forget_ids: forget,
            retain_ids: &retain_ids,
            eval_ids: &eval_ids,
            baseline_ppl: Some(60.0),
            thresholds: Default::default(),
            seed: 23,
        };
        let mut shared = audit::shared_evals(&ctx, view).unwrap();
        shared.forget_losses = Some(map.clone());
        let inline = audit::run_audits(&ctx, view).unwrap();
        let batched =
            audit::run_audits_with(&ctx, view, Some(&shared)).unwrap();
        assert_eq!(
            inline.to_json().encode(),
            batched.to_json().encode(),
            "batched forget probes must not change the report"
        );
    }
}

#[test]
fn greedy_decode_is_deterministic_and_shaped() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let params = rt.manifest.init_params().unwrap();
    let prompts = vec![
        "the secret code of user aaaa is ".to_string(),
        "Alice (user bbbb) wrote about ".to_string(),
    ];
    let a = audit::extraction::greedy_decode(
        &rt,
        ModelView::Base(&params),
        &prompts,
        6,
    )
    .unwrap();
    let b = audit::extraction::greedy_decode(
        &rt,
        ModelView::Base(&params),
        &prompts,
        6,
    )
    .unwrap();
    assert_eq!(a, b, "greedy decode is deterministic");
    assert_eq!(a.len(), prompts.len());
    assert!(a.iter().all(|s| s.chars().count() == 6));
}

#[test]
fn exposure_near_chance_on_untrained_model() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let params = rt.manifest.init_params().unwrap();
    let forget: Vec<u64> = corpus.user_samples(0);
    let fset: HashSet<u64> = forget.iter().copied().collect();
    let (retain_ids, eval_ids) = harness::audit_splits(&corpus, &fset, 4);
    let ctx = AuditContext {
        rt: &rt,
        corpus: &corpus,
        forget_ids: &forget,
        retain_ids: &retain_ids,
        eval_ids: &eval_ids,
        baseline_ppl: None,
        thresholds: Default::default(),
        seed: 4,
    };
    let (mu, sigma) =
        audit::canary::exposure(&ctx, ModelView::Base(&params)).unwrap();
    assert!(mu < 4.0, "chance-level exposure, got {mu}");
    assert!(sigma >= 0.0);
    let ex = audit::extraction::extraction_rate(&ctx, ModelView::Base(&params))
        .unwrap();
    assert!(ex <= 0.5, "untrained model shouldn't extract secrets: {ex}");
}
