//! G1 through the real AOT/PJRT stack: deterministic microbatch-filtered
//! replay is bit-identical to the preserved-graph oracle retrain
//! (paper Theorem A.1, Tables 4 & 5).
//!
//! One training run is shared by all checks (PJRT compile + training
//! dominate wall-clock, so the suite trains once and replays many ways).

use std::collections::HashSet;

use unlearn::checkpoint::CheckpointStore;
use unlearn::config::RunConfig;
use unlearn::equality::{wal_segment_shas, EqualityProof};
use unlearn::harness;
use unlearn::replay::{
    load_run, offending_steps, replay_filter, replay_filter_nearest,
    ReplayOptions,
};
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

const STEPS: u32 = 12;
const CKPT_EVERY: u32 = 4;

struct Fixture {
    rt: Runtime,
    cfg: RunConfig,
    corpus: unlearn::data::corpus::Corpus,
}

fn fixture() -> Fixture {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("replay-eq"),
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 8,
        warmup: 4,
        ..Default::default()
    };
    Fixture { rt, cfg, corpus }
}

#[test]
fn g1_and_friends_through_real_stack() {
    let f = fixture();
    let trainer = Trainer::new(&f.rt, f.cfg.clone(), f.corpus.clone());
    let full = trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&f.cfg.run_dir, f.cfg.hmac_key.clone()).expect("load run");
    let store =
        CheckpointStore::open(&f.cfg.run_dir.join("ckpt"), 64).unwrap();

    // -------- pick a forget set first seen at/after the checkpoint ----
    let k = CKPT_EVERY; // checkpoint at logical step 4
    let candidates =
        harness::ids_first_seen_at_or_after(&records, &idmap, k + 1);
    assert!(
        candidates.len() >= 3,
        "need forget candidates after step {k}, got {}",
        candidates.len()
    );
    let closure: HashSet<u64> = candidates.into_iter().take(5).collect();
    let offending = offending_steps(&records, &idmap, &closure).unwrap();
    assert!(*offending.first().unwrap() > k, "precondition holds");

    let theta0 = store.load_full(0).unwrap();
    let ck = store.load_full(k).unwrap();
    let opts = ReplayOptions::default();

    // -------- oracle: preserved-graph retain-only run from θ0 ---------
    let oracle = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &opts,
    )
    .expect("oracle");

    // -------- replay: filtered tail from C_k ---------------------------
    let replay = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&pins), &opts,
    )
    .expect("replay");

    // -------- Table 5: bit-identical state + proof artifact -----------
    let proof = EqualityProof::build(
        &oracle.state,
        &replay.state,
        oracle.invariants.clone(),
        replay.invariants.clone(),
        wal_segment_shas(&f.cfg.run_dir.join("wal")).unwrap(),
    );
    assert!(
        proof.status_pass,
        "G1 violated: max|diff| = {} \n{}",
        proof.max_abs_diff,
        proof.render_table5()
    );
    assert_eq!(proof.model_hash_oracle, proof.model_hash_replay);
    assert!(proof.exp_avg_equal && proof.exp_avg_sq_equal && proof.step_equal);
    // the unlearned model differs from the full model (it forgot!)
    assert_ne!(full.state.model_hash(), replay.state.model_hash());

    // -------- Table 4 negative control ---------------------------------
    // forget something that influenced steps BEFORE the checkpoint:
    let early = harness::ids_first_seen_at_or_after(&records, &idmap, 0)
        .into_iter()
        .find(|id| {
            let cl: HashSet<u64> = [*id].into_iter().collect();
            offending_steps(&records, &idmap, &cl)
                .map(|s| s.first().map(|&t| t < k).unwrap_or(false))
                .unwrap_or(false)
        })
        .expect("an early-influence sample exists");
    let bad_closure: HashSet<u64> = [early].into_iter().collect();
    let bad_oracle = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &bad_closure,
        Some(&pins), &opts,
    )
    .unwrap();
    let bad_replay = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &bad_closure, Some(&pins),
        &opts,
    )
    .unwrap();
    let bad = EqualityProof::build(
        &bad_oracle.state,
        &bad_replay.state,
        bad_oracle.invariants.clone(),
        bad_replay.invariants.clone(),
        vec![],
    );
    assert!(
        !bad.status_pass,
        "checkpoint post-dating forget influence must NOT be bit-exact"
    );
    assert!(bad.max_abs_diff > 0.0);

    // -------- content-scrubbed vs content-present replay ---------------
    let replay_keep = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions { zero_content: false, check_pins: true },
    )
    .unwrap();
    assert!(
        replay.state.bits_equal(&replay_keep.state),
        "content-independence: scrubbing filtered slots must not change bits"
    );

    // -------- pin drift fails closed -----------------------------------
    let mut drifted = pins.clone();
    drifted.reduction = "mean".into();
    let err = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&drifted),
        &opts,
    );
    assert!(err.is_err(), "pin drift must refuse to replay");
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("pin drift"), "{msg}");

    // -------- unfiltered replay == direct training (CI-gate core) ------
    let clean = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &HashSet::new(),
        Some(&pins), &opts,
    )
    .unwrap();
    assert!(clean.state.bits_equal(&full.state));
}

#[test]
fn nearest_checkpoint_tail_replay_is_bit_identical_to_full_replay() {
    // The optimized path: pick the latest checkpoint at or before the
    // earliest offending step and replay only that tail.  Bit-identity
    // regression: the tail result must equal the full from-θ0 replay.
    let f = fixture();
    let trainer = Trainer::new(&f.rt, f.cfg.clone(), f.corpus.clone());
    let full_train = trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&f.cfg.run_dir, f.cfg.hmac_key.clone()).unwrap();
    let store =
        CheckpointStore::open(&f.cfg.run_dir.join("ckpt"), 64).unwrap();
    let opts = ReplayOptions::default();

    // forget set whose influence starts strictly after checkpoint 4
    // (the small corpus is fully covered within ~7 steps, so candidates
    // first seen later than that do not exist)
    let closure: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&records, &idmap, 5)
            .into_iter()
            .take(4)
            .collect();
    assert!(!closure.is_empty());
    let offending = offending_steps(&records, &idmap, &closure).unwrap();
    let first_offending = *offending.first().unwrap();
    assert!(first_offending >= 5);

    let theta0 = store.load_full(0).unwrap();
    let full = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &opts,
    )
    .unwrap();
    let (k, tail) = replay_filter_nearest(
        &f.rt, &f.corpus, &store, &records, &idmap, &closure, Some(&pins),
        &opts,
    )
    .unwrap();
    assert!(k <= first_offending, "start must precede all forget influence");
    assert!(k > 0, "nearest selection must beat the θ0 fallback");
    assert!(
        tail.state.bits_equal(&full.state),
        "G1: tail replay from C_{k} must be bit-identical to full replay"
    );
    // the tail traversal is strictly cheaper than the full one
    assert!(tail.invariants.records < full.invariants.records);

    // empty closure degenerates to "latest checkpoint, minimal tail"
    // and reproduces the direct training state exactly
    let (k2, clean) = replay_filter_nearest(
        &f.rt, &f.corpus, &store, &records, &idmap, &HashSet::new(),
        Some(&pins), &opts,
    )
    .unwrap();
    assert_eq!(k2, STEPS, "latest checkpoint is the final state");
    assert!(clean.state.bits_equal(&full_train.state));
    assert_eq!(clean.invariants.records, 0, "nothing left to replay");
}

#[test]
fn empty_step_skip_through_real_stack() {
    // forget EVERYTHING in one logical step -> that step must apply no
    // update and advance no counters, and G1 must still hold.
    let f = fixture();
    let mut cfg = f.cfg.clone();
    cfg.run_dir = unlearn::util::tempdir("replay-empty");
    let trainer = Trainer::new(&f.rt, cfg.clone(), f.corpus.clone());
    trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&cfg.run_dir, cfg.hmac_key.clone()).unwrap();
    let store = CheckpointStore::open(&cfg.run_dir.join("ckpt"), 64).unwrap();

    // every sample of logical step 6 (both microbatches)
    let mut closure: HashSet<u64> = HashSet::new();
    for rec in records.iter().filter(|r| r.opt_step == 6) {
        closure.extend(idmap.lookup(rec.hash64).unwrap());
    }
    assert!(!closure.is_empty());
    // drop samples that also appear elsewhere? — irrelevant: the point
    // is step 6 becomes fully empty; other occurrences are masked too.

    let theta0 = store.load_full(0).unwrap();
    let oracle = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions::default(),
    )
    .unwrap();
    assert!(
        oracle.invariants.empty_logical_steps >= 1,
        "step 6 must be empty after filtering"
    );
    assert_eq!(
        oracle.state.applied_updates as u32 +
            oracle.invariants.empty_logical_steps,
        STEPS,
        "counters advance only on applied updates (Prop. A.5)"
    );

    // replay from the checkpoint before step 6 agrees bit-for-bit
    let k = 4;
    let ck = store.load_full(k).unwrap();
    // precondition: no forget influence before k
    let offending = offending_steps(&records, &idmap, &closure).unwrap();
    if offending.iter().any(|&t| t < k) {
        // closure leaked into earlier steps (samples recur across epochs
        // or duplicates) — fall back to θ0 replay, which is always sound
        return;
    }
    let replay = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions::default(),
    )
    .unwrap();
    assert!(oracle.state.bits_equal(&replay.state));
}
