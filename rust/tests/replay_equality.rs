//! G1 through the real AOT/PJRT stack: deterministic microbatch-filtered
//! replay is bit-identical to the preserved-graph oracle retrain
//! (paper Theorem A.1, Tables 4 & 5).
//!
//! One training run is shared by all checks (PJRT compile + training
//! dominate wall-clock, so the suite trains once and replays many ways).

use std::collections::HashSet;

use unlearn::checkpoint::CheckpointStore;
use unlearn::config::RunConfig;
use unlearn::controller::{execute_batch, ForgetRequest, Urgency};
use unlearn::equality::{wal_segment_shas, EqualityProof};
use unlearn::harness;
use unlearn::manifest::ActionKind;
use unlearn::replay::{
    load_run, offending_steps, replay_filter, replay_filter_nearest,
    ReplayOptions,
};
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

const STEPS: u32 = 12;
const CKPT_EVERY: u32 = 4;

struct Fixture {
    rt: Runtime,
    cfg: RunConfig,
    corpus: unlearn::data::corpus::Corpus,
}

fn fixture() -> Fixture {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("replay-eq"),
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 8,
        warmup: 4,
        ..Default::default()
    };
    Fixture { rt, cfg, corpus }
}

#[test]
fn g1_and_friends_through_real_stack() {
    let f = fixture();
    let trainer = Trainer::new(&f.rt, f.cfg.clone(), f.corpus.clone());
    let full = trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&f.cfg.run_dir, f.cfg.hmac_key.clone()).expect("load run");
    let store =
        CheckpointStore::open(&f.cfg.run_dir.join("ckpt"), 64).unwrap();

    // -------- pick a forget set first seen at/after the checkpoint ----
    let k = CKPT_EVERY; // checkpoint at logical step 4
    let candidates =
        harness::ids_first_seen_at_or_after(&records, &idmap, k + 1);
    assert!(
        candidates.len() >= 3,
        "need forget candidates after step {k}, got {}",
        candidates.len()
    );
    let closure: HashSet<u64> = candidates.into_iter().take(5).collect();
    let offending = offending_steps(&records, &idmap, &closure).unwrap();
    assert!(*offending.first().unwrap() > k, "precondition holds");

    let theta0 = store.load_full(0).unwrap();
    let ck = store.load_full(k).unwrap();
    let opts = ReplayOptions::default();

    // -------- oracle: preserved-graph retain-only run from θ0 ---------
    let oracle = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &opts,
    )
    .expect("oracle");

    // -------- replay: filtered tail from C_k ---------------------------
    let replay = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&pins), &opts,
    )
    .expect("replay");

    // -------- Table 5: bit-identical state + proof artifact -----------
    let proof = EqualityProof::build(
        &oracle.state,
        &replay.state,
        oracle.invariants.clone(),
        replay.invariants.clone(),
        wal_segment_shas(&f.cfg.run_dir.join("wal")).unwrap(),
    );
    assert!(
        proof.status_pass,
        "G1 violated: max|diff| = {} \n{}",
        proof.max_abs_diff,
        proof.render_table5()
    );
    assert_eq!(proof.model_hash_oracle, proof.model_hash_replay);
    assert!(proof.exp_avg_equal && proof.exp_avg_sq_equal && proof.step_equal);
    // the unlearned model differs from the full model (it forgot!)
    assert_ne!(full.state.model_hash(), replay.state.model_hash());

    // -------- Table 4 negative control ---------------------------------
    // forget something that influenced steps BEFORE the checkpoint:
    let early = harness::ids_first_seen_at_or_after(&records, &idmap, 0)
        .into_iter()
        .find(|id| {
            let cl: HashSet<u64> = [*id].into_iter().collect();
            offending_steps(&records, &idmap, &cl)
                .map(|s| s.first().map(|&t| t < k).unwrap_or(false))
                .unwrap_or(false)
        })
        .expect("an early-influence sample exists");
    let bad_closure: HashSet<u64> = [early].into_iter().collect();
    let bad_oracle = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &bad_closure,
        Some(&pins), &opts,
    )
    .unwrap();
    let bad_replay = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &bad_closure, Some(&pins),
        &opts,
    )
    .unwrap();
    let bad = EqualityProof::build(
        &bad_oracle.state,
        &bad_replay.state,
        bad_oracle.invariants.clone(),
        bad_replay.invariants.clone(),
        vec![],
    );
    assert!(
        !bad.status_pass,
        "checkpoint post-dating forget influence must NOT be bit-exact"
    );
    assert!(bad.max_abs_diff > 0.0);

    // -------- content-scrubbed vs content-present replay ---------------
    let replay_keep = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions { zero_content: false, ..ReplayOptions::default() },
    )
    .unwrap();
    assert!(
        replay.state.bits_equal(&replay_keep.state),
        "content-independence: scrubbing filtered slots must not change bits"
    );

    // -------- pin drift fails closed -----------------------------------
    let mut drifted = pins.clone();
    drifted.reduction = "mean".into();
    let err = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&drifted),
        &opts,
    );
    assert!(err.is_err(), "pin drift must refuse to replay");
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("pin drift"), "{msg}");

    // -------- unfiltered replay == direct training (CI-gate core) ------
    let clean = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &HashSet::new(),
        Some(&pins), &opts,
    )
    .unwrap();
    assert!(clean.state.bits_equal(&full.state));
}

#[test]
fn nearest_checkpoint_tail_replay_is_bit_identical_to_full_replay() {
    // The optimized path: pick the latest checkpoint at or before the
    // earliest offending step and replay only that tail.  Bit-identity
    // regression: the tail result must equal the full from-θ0 replay.
    let f = fixture();
    let trainer = Trainer::new(&f.rt, f.cfg.clone(), f.corpus.clone());
    let full_train = trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&f.cfg.run_dir, f.cfg.hmac_key.clone()).unwrap();
    let store =
        CheckpointStore::open(&f.cfg.run_dir.join("ckpt"), 64).unwrap();
    let opts = ReplayOptions::default();

    // forget set whose influence starts strictly after checkpoint 4
    // (the small corpus is fully covered within ~7 steps, so candidates
    // first seen later than that do not exist)
    let closure: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&records, &idmap, 5)
            .into_iter()
            .take(4)
            .collect();
    assert!(!closure.is_empty());
    let offending = offending_steps(&records, &idmap, &closure).unwrap();
    let first_offending = *offending.first().unwrap();
    assert!(first_offending >= 5);

    let theta0 = store.load_full(0).unwrap();
    let full = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &opts,
    )
    .unwrap();
    let (k, tail) = replay_filter_nearest(
        &f.rt, &f.corpus, &store, &records, &idmap, &closure, Some(&pins),
        &opts,
    )
    .unwrap();
    assert!(k <= first_offending, "start must precede all forget influence");
    assert!(k > 0, "nearest selection must beat the θ0 fallback");
    assert!(
        tail.state.bits_equal(&full.state),
        "G1: tail replay from C_{k} must be bit-identical to full replay"
    );
    // the tail traversal is strictly cheaper than the full one
    assert!(tail.invariants.records < full.invariants.records);

    // empty closure degenerates to "latest checkpoint, minimal tail"
    // and reproduces the direct training state exactly
    let (k2, clean) = replay_filter_nearest(
        &f.rt, &f.corpus, &store, &records, &idmap, &HashSet::new(),
        Some(&pins), &opts,
    )
    .unwrap();
    assert_eq!(k2, STEPS, "latest checkpoint is the final state");
    assert!(clean.state.bits_equal(&full_train.state));
    assert_eq!(clean.invariants.records, 0, "nothing left to replay");
}

#[test]
fn empty_step_skip_through_real_stack() {
    // forget EVERYTHING in one logical step -> that step must apply no
    // update and advance no counters, and G1 must still hold.
    let f = fixture();
    let mut cfg = f.cfg.clone();
    cfg.run_dir = unlearn::util::tempdir("replay-empty");
    let trainer = Trainer::new(&f.rt, cfg.clone(), f.corpus.clone());
    trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&cfg.run_dir, cfg.hmac_key.clone()).unwrap();
    let store = CheckpointStore::open(&cfg.run_dir.join("ckpt"), 64).unwrap();

    // every sample of logical step 6 (both microbatches)
    let mut closure: HashSet<u64> = HashSet::new();
    for rec in records.iter().filter(|r| r.opt_step == 6) {
        closure.extend(idmap.lookup(rec.hash64).unwrap());
    }
    assert!(!closure.is_empty());
    // drop samples that also appear elsewhere? — irrelevant: the point
    // is step 6 becomes fully empty; other occurrences are masked too.

    let theta0 = store.load_full(0).unwrap();
    let oracle = replay_filter(
        &f.rt, &f.corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions::default(),
    )
    .unwrap();
    assert!(
        oracle.invariants.empty_logical_steps >= 1,
        "step 6 must be empty after filtering"
    );
    assert_eq!(
        oracle.state.applied_updates as u32 +
            oracle.invariants.empty_logical_steps,
        STEPS,
        "counters advance only on applied updates (Prop. A.5)"
    );

    // replay from the checkpoint before step 6 agrees bit-for-bit
    let k = 4;
    let ck = store.load_full(k).unwrap();
    // precondition: no forget influence before k
    let offending = offending_steps(&records, &idmap, &closure).unwrap();
    if offending.iter().any(|&t| t < k) {
        // closure leaked into earlier steps (samples recur across epochs
        // or duplicates) — fall back to θ0 replay, which is always sound
        return;
    }
    let replay = replay_filter(
        &f.rt, &f.corpus, &ck, &records, &idmap, &closure, Some(&pins),
        &ReplayOptions::default(),
    )
    .unwrap();
    assert!(oracle.state.bits_equal(&replay.state));
}

#[test]
fn segment_parallel_replay_is_bit_identical_to_sequential() {
    // The Executor-trait acceptance proof: replay dispatching each
    // accumulation segment through `grad_accumulate` (per-microbatch
    // gradients computed across a scoped thread pool, combined via the
    // pinned reduce) must produce params AND optimizer state (m, v,
    // counters) bit-identical to the pre-redesign sequential traversal
    // (`ReplayOptions::sequential`).  accum=4 gives every segment real
    // intra-segment parallelism.
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("replay-seg-par"),
        steps: 10,
        accum: 4,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    let trainer = Trainer::new(&rt, cfg.clone(), corpus.clone());
    trainer.train(|_| false).expect("train");
    let (records, idmap, pins) =
        load_run(&cfg.run_dir, cfg.hmac_key.clone()).unwrap();
    let store =
        CheckpointStore::open(&cfg.run_dir.join("ckpt"), 64).unwrap();
    let theta0 = store.load_full(0).unwrap();

    // a non-trivial closure so filtering (skipped microbatches, maybe
    // empty steps) is exercised under both modes.  accum=4 covers the
    // small corpus within ~3 logical steps, so pick ids first seen at
    // or after step 2 (the last fresh cohort).
    let closure: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&records, &idmap, 2)
            .into_iter()
            .take(6)
            .collect();
    assert!(!closure.is_empty());

    let par_opts = ReplayOptions::default();
    assert!(!par_opts.sequential, "parallel segments are the default");
    let seq_opts = ReplayOptions {
        sequential: true,
        ..ReplayOptions::default()
    };

    let par = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &par_opts,
    )
    .expect("parallel replay");
    let seq = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &closure, Some(&pins),
        &seq_opts,
    )
    .expect("sequential replay");

    // bits_equal covers params + exp_avg (m) + exp_avg_sq (v) + both
    // step counters — the full (θ, Ω) state of Theorem A.1
    assert!(
        seq.state.bits_equal(&par.state),
        "segment-parallel replay drifted from sequential (model {} vs \
         {}, optimizer {} vs {})",
        seq.state.model_hash(),
        par.state.model_hash(),
        seq.state.optimizer_hash(),
        par.state.optimizer_hash()
    );
    assert_eq!(seq.state.model_hash(), par.state.model_hash());
    assert_eq!(seq.state.optimizer_hash(), par.state.optimizer_hash());
    assert_eq!(seq.invariants, par.invariants, "traversal invariants");

    // the empty-closure degenerate case agrees too (every microbatch
    // retained — maximal segment width)
    let par_clean = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &HashSet::new(),
        Some(&pins), &par_opts,
    )
    .unwrap();
    let seq_clean = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &HashSet::new(),
        Some(&pins), &seq_opts,
    )
    .unwrap();
    assert!(seq_clean.state.bits_equal(&par_clean.state));
}

#[test]
fn coalesced_batch_is_bit_identical_to_sequential() {
    // Batch-coalescing exactness (Thm. A.1 applied to a request
    // stream): N requests handled as ONE union-filtered tail replay
    // must produce a model bit-identical to handling the same requests
    // sequentially (each of which replays filtering the cumulative
    // union).  Two independently trained — hence bit-identical — systems
    // take the two routes.
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let mk = |tag: &str| RunConfig {
        run_dir: unlearn::util::tempdir(tag),
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    let mut seq = harness::build_system(&rt, mk("batch-seq"), corpus.clone(), false)
        .unwrap()
        .system;
    let mut coal =
        harness::build_system(&rt, mk("batch-coal"), corpus.clone(), false)
            .unwrap()
            .system;
    assert!(
        seq.state.bits_equal(&coal.state),
        "deterministic training: identical starting points"
    );

    // three replay-bound requests: users whose earliest influence
    // predates the ring window (so sequential handling replays too)
    let earliest_ring = seq.ring.earliest_step().expect("ring populated");
    let mut reqs: Vec<ForgetRequest> = Vec::new();
    for u in 0..24u32 {
        let req = ForgetRequest {
            id: format!("batch-{u}"),
            user: Some(u),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        };
        let (cl, _) = seq.closure_of(&req);
        if cl.is_empty() {
            continue;
        }
        let set: HashSet<u64> = cl.iter().copied().collect();
        let off = offending_steps(&seq.records, &seq.idmap, &set).unwrap();
        if off.first().map(|&t| t < earliest_ring).unwrap_or(false) {
            reqs.push(req);
            if reqs.len() == 3 {
                break;
            }
        }
    }
    assert_eq!(reqs.len(), 3, "need three replay-bound users");

    // sequential: three separate tail replays
    for r in &reqs {
        let o = seq.handle(r).unwrap();
        assert_eq!(o.action, ActionKind::ExactReplay, "{:?}", o.escalations);
        assert!(o.executed);
    }

    // coalesced: exactly one shared tail replay
    let batch = execute_batch(&mut coal, &reqs).unwrap();
    assert_eq!(batch.replays_run, 1, "one replay serves the whole batch");
    assert_eq!(batch.coalesced_requests, 3);
    assert!(batch.from_checkpoint.is_some());
    for res in &batch.outcomes {
        let o = res.as_ref().unwrap();
        assert!(o.executed);
        assert_eq!(o.action, ActionKind::ExactReplay);
        assert_eq!(o.details.get("coalesced").unwrap().as_u64(), Some(3));
    }

    // G1 for batches: bit-identical state both ways
    assert!(
        seq.state.bits_equal(&coal.state),
        "coalesced batch must be bit-identical to sequential handling \
         (model {} vs {})",
        seq.state.model_hash(),
        coal.state.model_hash()
    );
    assert_eq!(seq.state.model_hash(), coal.state.model_hash());
    assert_eq!(seq.state.optimizer_hash(), coal.state.optimizer_hash());

    // per-request manifest entries on both sides, all signed
    let cs = seq.manifest.verify_chain().unwrap();
    let cc = coal.manifest.verify_chain().unwrap();
    assert_eq!(cs.len(), 3);
    assert_eq!(cc.len(), 3);
    assert!(cc.iter().all(|(_, sig)| *sig));

    // idempotency across the batch boundary: resubmitting one of the
    // coalesced requests is suppressed
    let dup = execute_batch(&mut coal, &reqs[..1].to_vec()).unwrap();
    assert_eq!(dup.replays_run, 0);
    assert!(!dup.outcomes[0].as_ref().unwrap().executed);
}

#[test]
fn laundering_is_bit_identical_and_strictly_cheaper() {
    // The compaction path (checkpoint laundering): after laundering
    // away closure F, a fresh forget request G replayed from the
    // laundered lineage must be bit-identical to a union-filtered
    // (F ∪ G) replay from the original lineage — and G's plan must get
    // strictly cheaper, because the rebuild target no longer reaches
    // back to F's influence.  Two independently trained (hence
    // bit-identical) systems take the two routes.
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let mk = |tag: &str| RunConfig {
        run_dir: unlearn::util::tempdir(tag),
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    let mut laundry =
        harness::build_system(&rt, mk("launder-a"), corpus.clone(), false)
            .unwrap()
            .system;
    let mut union =
        harness::build_system(&rt, mk("launder-b"), corpus.clone(), false)
            .unwrap()
            .system;
    assert!(laundry.state.bits_equal(&union.state));

    // F: a user whose influence starts early (before checkpoint 4), so
    // un-laundered history drags every later rebuild back to step < 4
    let f_req = (0..24u32)
        .map(|u| ForgetRequest {
            id: format!("launder-f-{u}"),
            user: Some(u),
            sample_ids: vec![],
            urgency: Urgency::Normal,
        })
        .find(|r| {
            laundry
                .plan(r)
                .map(|p| {
                    p.offending.first().map(|&t| t < CKPT_EVERY).unwrap_or(false)
                })
                .unwrap_or(false)
        })
        .expect("an early-influence user exists");
    // G: samples first seen at/after step 5 whose closure stays there
    let late_set: HashSet<u64> =
        harness::ids_first_seen_at_or_after(&laundry.records, &laundry.idmap, 5)
            .into_iter()
            .collect();
    let mut g_ids: Vec<u64> = late_set
        .iter()
        .copied()
        .filter(|&id| {
            let (cl, _) = laundry.closure_of(&ForgetRequest {
                id: "probe".into(),
                user: None,
                sample_ids: vec![id],
                urgency: Urgency::Normal,
            });
            cl.iter().all(|c| late_set.contains(c))
        })
        .collect();
    g_ids.sort_unstable();
    g_ids.truncate(3);
    assert!(!g_ids.is_empty(), "need late-influence G candidates");
    let g_req = |id: &str| ForgetRequest {
        id: id.into(),
        user: None,
        sample_ids: g_ids.clone(),
        urgency: Urgency::Normal,
    };

    // ---- both systems forget F (exact path) ---------------------------
    for sys in [&mut laundry, &mut union] {
        let o = sys.handle(&f_req).unwrap();
        assert!(o.executed);
        assert!(!sys.forgotten.is_empty());
    }
    assert!(laundry.state.bits_equal(&union.state));

    // ---- pre-launder plan for G: inflated by F's history --------------
    let cost_pre = laundry
        .plan(&g_req("launder-g-pre"))
        .unwrap()
        .steps
        .iter()
        .find(|s| s.step.kind() == "exact_replay")
        .expect("replay plannable")
        .cost
        .replay_steps;

    // ---- launder F on system A ----------------------------------------
    let gen_before = laundry.cas_stats().unwrap().generation;
    let out = laundry
        .launder(
            "t-launder",
            &unlearn::controller::LaunderPolicy {
                min_extra_replay_records: 1,
            },
            false,
        )
        .unwrap();
    assert!(out.executed);
    assert!(out.checkpoints_written > 0, "contaminated ckpts rewritten");
    assert!(out.checkpoints_adopted > 0, "θ0 adopted for free");
    assert_eq!(out.generation, gen_before + 1, "lineage swapped");
    assert!(laundry.forgotten.is_empty(), "forgotten set reset");
    // laundered-set compaction: the closure moved into the IdMap's
    // retired set (replays mask it automatically), the in-memory
    // residue stays empty — neither grows with service lifetime
    assert!(laundry.laundered.is_empty(), "residue compacted away");
    assert!(
        laundry.idmap.retired_len() > 0,
        "closure retired into the IdMap"
    );
    assert_eq!(out.laundered_total, laundry.laundered_total());
    assert_eq!(laundry.ring.available(), 0, "ring invalidated by the swap");
    assert!(
        laundry.state.bits_equal(&union.state),
        "laundering must not change the serving state (it IS the \
         retain-only state already)"
    );
    // the store agrees with the in-memory view (the cached handle was
    // revalidated by the lineage swap): residue empty, retired count
    // matches the IdMap — and the compacted laundered.json stays
    // bounded regardless of how many ids were ever laundered
    let (residue, retired) = laundry.store().laundered_meta().unwrap();
    assert!(residue.is_empty());
    assert_eq!(retired as usize, laundry.idmap.retired_len());
    // idempotency: a second pass under the same key is suppressed
    let dup = laundry
        .launder(
            "t-launder",
            &unlearn::controller::LaunderPolicy {
                min_extra_replay_records: 0,
            },
            true,
        )
        .unwrap();
    assert!(!dup.executed);

    // ---- post-launder plan for G: strictly cheaper --------------------
    let plan_post = laundry.plan(&g_req("launder-g")).unwrap();
    let cost_post = plan_post
        .steps
        .iter()
        .find(|s| s.step.kind() == "exact_replay")
        .expect("replay plannable from the laundered lineage")
        .cost
        .replay_steps;
    assert!(
        cost_post < cost_pre,
        "laundering must strictly reduce G's replay cost: {cost_post} \
         vs {cost_pre}"
    );

    // ---- execute G both ways: bit-identical ---------------------------
    let o = laundry.handle(&g_req("launder-g")).unwrap();
    assert_eq!(o.action, ActionKind::ExactReplay, "{:?}", o.escalations);
    let o = union.handle(&g_req("launder-g")).unwrap();
    assert_eq!(o.action, ActionKind::ExactReplay, "{:?}", o.escalations);
    assert!(
        laundry.state.bits_equal(&union.state),
        "G from the laundered lineage must equal the union-filtered \
         (F ∪ G) replay from the original lineage (model {} vs {})",
        laundry.state.model_hash(),
        union.state.model_hash()
    );

    // the laundered store still dedups: adopted + rewritten manifests
    // share every blob that didn't change
    let stats = laundry.cas_stats().unwrap();
    assert!(stats.objects > 0);
    // manifest chain intact, launder action recorded and signed
    let chain = laundry.manifest.verify_chain().unwrap();
    assert!(chain.iter().all(|(_, sig)| *sig));
    assert!(chain.iter().any(|(e, _)| {
        e.get("action").and_then(|v| v.as_str()) == Some("launder")
    }));
}

#[test]
fn coalesced_ring_revert_matches_sequential() {
    // The batch coalescer's second mode: when the union's influence is
    // entirely inside the delta-ring window, the shared rebuild is a
    // bounded ring revert + resumed filtered tail instead of a
    // checkpoint replay.  Must still be bit-identical to sequential
    // handling (XOR patches revert the trajectory state exactly; the
    // resumed tail is the same filtered program).
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    // toy corpus: the small corpus is fully covered within ~7 steps, so
    // it has no samples first seen inside a late ring window
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let mk = |tag: &str| RunConfig {
        run_dir: unlearn::util::tempdir(tag),
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 16,
        ring_window: 4,
        warmup: 4,
        ..Default::default()
    };
    let mut seq =
        harness::build_system(&rt, mk("ring-batch-seq"), corpus.clone(), false)
            .unwrap()
            .system;
    let mut coal =
        harness::build_system(&rt, mk("ring-batch-coal"), corpus.clone(), false)
            .unwrap()
            .system;
    assert!(seq.state.bits_equal(&coal.state));

    // candidate ids first seen inside the ring window whose closure
    // also stays inside it (near-dup expansion can reach back in time)
    let earliest = seq.ring.earliest_step().expect("ring populated");
    let recent_set: std::collections::HashSet<u64> =
        harness::ids_first_seen_at_or_after(&seq.records, &seq.idmap, earliest + 2)
            .into_iter()
            .collect();
    let mut recent: Vec<u64> = recent_set
        .iter()
        .copied()
        .filter(|&id| {
            let (cl, _) = seq.closure_of(&ForgetRequest {
                id: "probe".into(),
                user: None,
                sample_ids: vec![id],
                urgency: Urgency::Normal,
            });
            cl.iter().all(|c| recent_set.contains(c))
        })
        .collect();
    recent.sort_unstable();
    assert!(recent.len() >= 2, "need two recent-only candidates");
    let reqs = vec![
        ForgetRequest {
            id: "ring-batch-1".into(),
            user: None,
            sample_ids: vec![recent[0]],
            urgency: Urgency::Normal,
        },
        ForgetRequest {
            id: "ring-batch-2".into(),
            user: None,
            sample_ids: vec![recent[1]],
            urgency: Urgency::Normal,
        },
    ];

    for r in &reqs {
        let o = seq.handle(r).unwrap();
        assert!(o.executed);
    }
    let batch = execute_batch(&mut coal, &reqs).unwrap();
    assert_eq!(batch.replays_run, 1, "one shared rebuild");
    assert_eq!(batch.coalesced_requests, 2);
    assert!(
        batch.from_checkpoint.is_none(),
        "ring mode rebuilds without touching the checkpoint store"
    );
    for res in &batch.outcomes {
        let o = res.as_ref().unwrap();
        assert!(o.executed);
        assert_eq!(o.action, ActionKind::RecentRevert);
        assert!(o.details.get("reverted_steps").is_some());
    }
    assert!(
        seq.state.bits_equal(&coal.state),
        "ring-mode coalescing must be bit-identical to sequential \
         handling (model {} vs {})",
        seq.state.model_hash(),
        coal.state.model_hash()
    );
}
