//! The online-ingest acceptance proof: after K interleaved rounds of
//! ingest (durable doc append) and bounded train-increments, forgetting
//! user `u` is **bit-identical** (params + optimizer state) to the
//! retain-only oracle over the FINAL corpus — the preserved-graph
//! replay of the entire logged program from θ0 with `u`'s closure
//! masked.  Also proven here: laundering stays exact under a moving
//! tail (launder → another round → forget → oracle), laundering
//! REFUSES while an increment is in flight (typed error), round keys
//! make retries idempotent, and the `trained_step`/`ingested_docs`/
//! `tail_lag_steps` watermarks track the tail.
//!
//! One training run is shared by every check (training + replays
//! dominate wall-clock, so the suite trains once and interleaves many
//! ways).

use std::collections::HashSet;

use unlearn::config::RunConfig;
use unlearn::controller::{
    execute_batch, ForgetRequest, LaunderPolicy, UnlearnError, Urgency,
};
use unlearn::harness;
use unlearn::ingest::{
    self, IngestDoc, IngestLog, IngestScheduler, InterleaveEntry,
};
use unlearn::runtime::Runtime;

const STEPS: u32 = 8;
const CKPT_EVERY: u32 = 4;
const INC_STEPS: u32 = 2;

fn forget_req(id: &str, user: u32) -> ForgetRequest {
    ForgetRequest {
        id: id.to_string(),
        user: Some(user),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    }
}

#[test]
fn interleaved_ingest_forget_is_bit_identical_to_retain_oracle() {
    let rt = Runtime::load(&harness::artifacts_dir()).expect("artifacts");
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: unlearn::util::tempdir("ingest-eq"),
        steps: STEPS,
        accum: 2,
        checkpoint_every: CKPT_EVERY,
        checkpoint_keep: 32,
        ring_window: 4,
        warmup: 2,
        ..Default::default()
    };
    let trained =
        harness::build_system(&rt, cfg.clone(), corpus, false).expect("train");
    let mut sys = trained.system;
    let base_len = sys.corpus.len();
    let mut log =
        IngestLog::attach(&cfg.run_dir, base_len).expect("attach log");

    // a verbatim copy of one of user 2's documents, ingested under a
    // NEW user: the live near-dup index must pull it into user 2's
    // closure later (distance 0)
    let dup_text = {
        let ids = sys.corpus.user_samples(2);
        assert!(!ids.is_empty(), "user 2 has documents");
        sys.corpus.by_id(ids[0]).unwrap().text.clone()
    };

    // ---- round 1, run as explicit halves to watch the watermarks ----
    let r1 = ingest::round_of("round-1");
    let round1_docs = vec![
        IngestDoc {
            user: 3,
            text: "user three returns with a note about sailing".into(),
        },
        IngestDoc {
            user: 101,
            text: "a brand-new user writes their first document".into(),
        },
        IngestDoc {
            user: 102,
            text: dup_text,
        },
    ];
    let dup_gid = base_len as u64 + 2;
    ingest::ingest_docs(&mut sys, &mut log, r1, &round1_docs)
        .expect("ingest round 1");
    assert_eq!(sys.corpus.len(), base_len + 3, "corpus grew");
    assert_eq!(sys.ingest.ingested_docs, 3);
    assert!(
        sys.tail_lag_steps() > 0,
        "committed docs not yet trained on must show as tail lag"
    );
    let out =
        ingest::train_increment(&mut sys, &mut log, r1, INC_STEPS).unwrap();
    assert!(out.executed);
    assert_eq!(out.updates_applied, INC_STEPS);
    assert_eq!(sys.state.logical_step, STEPS + INC_STEPS);
    assert_eq!(sys.tail_lag_steps(), 0, "increment covered the tail");

    // the increment's WAL records replay bit-identically: with nothing
    // forgotten, the full-program oracle IS the serving state
    let oracle = ingest::oracle_state(&sys, &HashSet::new()).unwrap();
    assert!(
        sys.state.bits_equal(&oracle),
        "increment must extend the deterministic logged program \
         (model {} vs {})",
        sys.state.model_hash(),
        oracle.model_hash()
    );

    // ---- forget an ORIGINAL user between rounds ----------------------
    let out = execute_batch(&mut sys, &[forget_req("eq-forget-v", 7)])
        .expect("forget v");
    assert!(out.outcomes[0].as_ref().unwrap().executed);
    log.record_forget("eq-forget-v", sys.forgotten.len()).unwrap();

    // ---- rounds 2 and 3 through the scheduler ------------------------
    let sched = IngestScheduler::new(INC_STEPS);
    sched
        .run_round(
            &mut sys,
            &mut log,
            ingest::round_of("round-2"),
            &[
                IngestDoc {
                    user: 5,
                    text: "user five adds an observation about tides".into(),
                },
                IngestDoc {
                    user: 103,
                    text: "another new user appears mid-stream".into(),
                },
            ],
        )
        .expect("round 2");

    // forget a user who exists ONLY through ingest (round 1's 101)
    let out = execute_batch(&mut sys, &[forget_req("eq-forget-ingested", 101)])
        .expect("forget ingested-only user");
    assert!(out.outcomes[0].as_ref().unwrap().executed);
    log.record_forget("eq-forget-ingested", sys.forgotten.len())
        .unwrap();

    let r3 = ingest::round_of("round-3");
    let round3_docs = vec![IngestDoc {
        user: 4,
        text: "user four files a late addendum".into(),
    }];
    sched
        .run_round(&mut sys, &mut log, r3, &round3_docs)
        .expect("round 3");

    // ---- round keys make a retry a committed no-op -------------------
    let pre = sys.state.clone();
    let pre_docs = sys.ingest.ingested_docs;
    let retry = sched
        .run_round(&mut sys, &mut log, r3, &round3_docs)
        .expect("idempotent retry");
    assert!(!retry.executed, "both halves already committed");
    assert!(sys.state.bits_equal(&pre), "retry must not retrain");
    assert_eq!(sys.ingest.ingested_docs, pre_docs);

    // ---- headline: forget u after K rounds == retain-only oracle -----
    let req_u = forget_req("eq-forget-u", 2);
    let (cl, _) = sys.closure_of(&req_u);
    assert!(
        cl.contains(&dup_gid),
        "closure must reach the near-duplicate ingested mid-stream"
    );
    let out = execute_batch(&mut sys, &[req_u]).expect("forget u");
    assert!(out.outcomes[0].as_ref().unwrap().executed);
    log.record_forget("eq-forget-u", sys.forgotten.len()).unwrap();

    let mut union: HashSet<u64> = sys.forgotten.clone();
    union.extend(sys.laundered.iter().copied());
    let oracle = ingest::oracle_state(&sys, &union).unwrap();
    assert!(
        sys.state.bits_equal(&oracle),
        "forget after interleaved ingest must be bit-identical to the \
         retain-only oracle over the final corpus (model {} vs {}, \
         optimizer {} vs {})",
        sys.state.model_hash(),
        oracle.model_hash(),
        sys.state.optimizer_hash(),
        oracle.optimizer_hash()
    );

    // ---- laundering refuses while an increment is in flight ----------
    let policy = LaunderPolicy {
        min_extra_replay_records: 0,
    };
    sys.ingest.in_flight = true;
    let err = sys
        .launder("eq-launder-guard", &policy, true)
        .expect_err("launder under an in-flight increment must refuse");
    assert!(
        matches!(
            err.downcast_ref::<UnlearnError>(),
            Some(UnlearnError::IngestInFlight)
        ),
        "typed refusal, got: {err:#}"
    );
    sys.ingest.in_flight = false;

    // ---- laundering stays exact under a moving tail ------------------
    let lout = sys.launder("eq-launder", &policy, true).expect("launder");
    assert!(lout.executed);
    log.record_launder("eq-launder").unwrap();

    sched
        .run_round(
            &mut sys,
            &mut log,
            ingest::round_of("round-4"),
            &[IngestDoc {
                user: 6,
                text: "the tail keeps moving after laundering".into(),
            }],
        )
        .expect("round 4 (post-launder)");

    let out = execute_batch(&mut sys, &[forget_req("eq-forget-w", 103)])
        .expect("forget w");
    assert!(out.outcomes[0].as_ref().unwrap().executed);
    log.record_forget("eq-forget-w", sys.forgotten.len()).unwrap();

    let mut union: HashSet<u64> = sys.forgotten.clone();
    union.extend(sys.laundered.iter().copied());
    let oracle = ingest::oracle_state(&sys, &union).unwrap();
    assert!(
        sys.state.bits_equal(&oracle),
        "moving-tail laundering must stay exact: serving state {} vs \
         oracle {}",
        sys.state.model_hash(),
        oracle.model_hash()
    );

    // ---- the interleave log survives a reopen as a faithful transcript
    let replayed = IngestLog::open(&cfg.run_dir)
        .expect("reopen log")
        .expect("log exists");
    assert_eq!(replayed.entries.len(), log.entries.len());
    assert!(matches!(
        replayed.entries[0],
        InterleaveEntry::Open { .. }
    ));
    let mut last_seq = None;
    for e in &replayed.entries[1..] {
        let seq = e.seq().expect("non-open entries carry a seq");
        assert!(last_seq.map_or(true, |p| seq > p), "seqs strictly grow");
        last_seq = Some(seq);
    }
    assert_eq!(replayed.ingested_docs(), sys.ingest.ingested_docs);
}
