//! Fixture tests for every `detlint` rule (firing / clean / allow-
//! suppressed / empty-reason-rejected), lexer span properties on
//! adversarial input, and the repo-conformance gate: scanning the
//! actual `src/` tree against the committed baseline must produce zero
//! new findings — which also means deleting any single true-positive
//! `detlint: allow` annotation in `src/` makes tier-1 (and the CI
//! detlint job) fail.

use std::path::PathBuf;

use unlearn::cigate::lint as gate;
use unlearn::lint::lexer::lex;
use unlearn::lint::rules::{
    RULE_ALLOW_HYGIENE, RULE_ENTROPY, RULE_FLOAT_REDUCE, RULE_RAW_FS,
    RULE_UNORDERED_ITER, RULE_UNSAFE_COMMENT, RULE_WALL_CLOCK,
};
use unlearn::lint::{check_file, scan_dir};
use unlearn::util::prop::for_all;

/// Rule ids of all findings for `src` checked under module path `rel`.
fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
    check_file(rel, src).findings.iter().map(|f| f.rule).collect()
}

fn fires(rel: &str, src: &str, rule: &str) -> bool {
    rules_of(rel, src).contains(&rule)
}

fn suppressed_count(rel: &str, src: &str) -> usize {
    check_file(rel, src).suppressed
}

// ---------------------------------------------------------------- wall-clock

#[test]
fn wall_clock_fires_outside_timing_modules() {
    let src = "fn f() { let t = std::time::Instant::now(); }";
    assert!(fires("controller/mod.rs", src, RULE_WALL_CLOCK));
    let src2 = "fn f() { let t = SystemTime::now(); }";
    assert!(fires("wal/mod.rs", src2, RULE_WALL_CLOCK));
}

#[test]
fn wall_clock_clean_in_allowlisted_modules_and_strings() {
    let src = "fn f() { let t = Instant::now(); }";
    assert!(rules_of("metrics/mod.rs", src).is_empty());
    assert!(rules_of("deltas/mod.rs", src).is_empty());
    let in_str = r#"fn f() { let s = "Instant::now()"; } // Instant::now()"#;
    assert!(rules_of("controller/mod.rs", in_str).is_empty());
}

#[test]
fn wall_clock_suppressed_by_allow() {
    let above = "// detlint: allow(wall-clock) — log timing only\n\
                 fn f() { let t = Instant::now(); }";
    let out = check_file("controller/mod.rs", above);
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    assert_eq!(out.suppressed, 1);

    let trailing = "fn f() { let t = Instant::now(); } \
                    // detlint: allow(wall-clock) — log timing only";
    assert_eq!(suppressed_count("controller/mod.rs", trailing), 1);
}

#[test]
fn empty_reason_is_rejected_and_does_not_suppress() {
    let src = "fn f() { let t = Instant::now(); } // detlint: allow(wall-clock)";
    let got = rules_of("controller/mod.rs", src);
    assert!(got.contains(&RULE_WALL_CLOCK), "{got:?}"); // NOT suppressed
    assert!(got.contains(&RULE_ALLOW_HYGIENE), "{got:?}");
}

#[test]
fn unknown_rule_in_allow_is_rejected() {
    let src = "fn f() { let t = Instant::now(); } \
               // detlint: allow(no-such-rule) — misguided";
    let got = rules_of("controller/mod.rs", src);
    assert!(got.contains(&RULE_WALL_CLOCK), "{got:?}");
    assert!(got.contains(&RULE_ALLOW_HYGIENE), "{got:?}");
}

// ------------------------------------------------------------ unordered-iter

const FOR_OVER_FIELD: &str = "\
struct S { m: HashMap<u64, u64> }
impl S {
    fn ser(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for (k, v) in &self.m {
            out.extend_from_slice(&k.to_le_bytes());
        }
        out
    }
}";

#[test]
fn unordered_iter_fires_on_for_loop_and_keys() {
    assert!(fires("wal/x.rs", FOR_OVER_FIELD, RULE_UNORDERED_ITER));
    let keys = "\
struct S { m: HashMap<u64, u64> }
impl S {
    fn ser(&self) {
        let ks: Vec<u64> = self.m.keys().copied().collect();
        emit(ks);
    }
}";
    assert!(fires("checkpoint/x.rs", keys, RULE_UNORDERED_ITER));
}

#[test]
fn unordered_iter_fires_via_fn_return_inference() {
    let src = "\
fn build() -> HashMap<String, u64> { HashMap::new() }
fn ser() -> Vec<u8> {
    let live = build();
    let mut out = Vec::new();
    for (k, v) in &live {
        out.extend_from_slice(k.as_bytes());
    }
    out
}";
    assert!(fires("manifest/x.rs", src, RULE_UNORDERED_ITER));
}

#[test]
fn unordered_iter_clean_when_sorted_or_btree_or_elsewhere() {
    let sorted = "\
struct S { m: HashMap<u64, u64> }
impl S {
    fn ser(&self) {
        let mut ks: Vec<u64> = self.m.keys().copied().collect();
        ks.sort_unstable();
        emit(ks);
    }
}";
    assert!(rules_of("wal/x.rs", sorted).is_empty());

    let btree = "\
struct S { m: HashMap<u64, u64> }
impl S {
    fn ser(&self) {
        let ordered: BTreeMap<u64, u64> =
            self.m.iter().map(|(k, v)| (*k, *v)).collect();
        emit(ordered);
    }
}";
    assert!(rules_of("wal/x.rs", btree).is_empty());

    // sort BEFORE a for-loop over a shadowing Vec also pins order
    let presorted = "\
struct S { m: HashSet<u64> }
impl S {
    fn ser(&self) {
        let mut m: Vec<u64> = self.m.iter().copied().collect();
        m.sort_unstable();
        for x in m {
            emit(x);
        }
    }
}";
    assert!(rules_of("wal/x.rs", presorted).is_empty());

    // same code outside the serialize-module list is not in scope
    assert!(rules_of("audit/x.rs", FOR_OVER_FIELD).is_empty());
}

#[test]
fn unordered_iter_suppressed_by_allow() {
    let src = "\
struct S { m: HashMap<u64, u64> }
impl S {
    fn count(&self) -> u64 {
        // detlint: allow(unordered-iter) — u64 sum is order-independent
        self.m.values().copied().sum()
    }
}";
    let out = check_file("shard/x.rs", src);
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    assert_eq!(out.suppressed, 1);
}

// -------------------------------------------------------------------- raw-fs

#[test]
fn raw_fs_fires_in_erasure_critical_modules() {
    let w = "fn f(p: &Path) -> anyhow::Result<()> { fs::write(p, b\"x\")?; Ok(()) }";
    assert!(fires("wal/x.rs", w, RULE_RAW_FS));
    let c = "fn f(p: &Path) { let f = File::create(p).unwrap(); }";
    assert!(fires("checkpoint/x.rs", c, RULE_RAW_FS));
    assert!(fires("fleet/x.rs", w, RULE_RAW_FS));
}

#[test]
fn raw_fs_clean_via_wrappers_other_modules_and_tests() {
    let wrapped =
        "fn f(p: &Path) -> anyhow::Result<()> { crate::util::faultfs::write(p, b)?; Ok(()) }";
    assert!(rules_of("wal/x.rs", wrapped).is_empty());
    let w = "fn f(p: &Path) { fs::write(p, b\"x\").unwrap(); }";
    assert!(rules_of("trainer/x.rs", w).is_empty()); // not erasure-critical
    let in_tests = "\
fn prod() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { std::fs::write(\"/tmp/x\", b\"y\").unwrap(); }
}";
    assert!(rules_of("wal/x.rs", in_tests).is_empty());
}

#[test]
fn raw_fs_suppressed_by_allow() {
    let src = "\
fn f(p: &Path) {
    // detlint: allow(raw-fs) — debug sidecar, never read at recovery
    fs::write(p, b\"x\").unwrap();
}";
    let out = check_file("wal/x.rs", src);
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    assert_eq!(out.suppressed, 1);
}

// -------------------------------------------------------------- float-reduce

#[test]
fn float_reduce_fires_on_sum_turbofish_and_float_fold() {
    let sum = "fn f(v: &[f32]) -> f32 { v.iter().sum::<f32>() }";
    assert!(fires("audit/x.rs", sum, RULE_FLOAT_REDUCE));
    let sum64 = "fn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }";
    assert!(fires("fleet/x.rs", sum64, RULE_FLOAT_REDUCE));
    let fold = "fn f(v: &[f32]) -> f32 { v.iter().fold(0.0f32, |a, &x| a + x) }";
    assert!(fires("controller/x.rs", fold, RULE_FLOAT_REDUCE));
    let fold_min = "fn f(v: &[f32]) -> f32 { v.iter().copied().fold(f32::MIN, f32::max) }";
    assert!(fires("controller/x.rs", fold_min, RULE_FLOAT_REDUCE));
}

#[test]
fn float_reduce_clean_on_int_reduce_and_in_runtime() {
    let int_sum = "fn f(v: &[u64]) -> u64 { v.iter().sum() }";
    assert!(rules_of("audit/x.rs", int_sum).is_empty());
    let int_fold = "fn f(v: &[i64]) -> i64 { v.iter().fold(0i64, |a, &x| a + x) }";
    assert!(rules_of("audit/x.rs", int_fold).is_empty());
    // reduce_pinned's home module is exempt — the pinned order lives there
    let sum = "fn reduce_pinned(v: &[f32]) -> f32 { v.iter().sum::<f32>() }";
    assert!(rules_of("runtime/mod.rs", sum).is_empty());
}

#[test]
fn float_reduce_suppressed_by_allow() {
    let src = "\
fn f(v: &[f32]) -> f32 {
    // detlint: allow(float-reduce) — max is order-insensitive
    v.iter().copied().fold(0.0f32, f32::max)
}";
    let out = check_file("audit/x.rs", src);
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    assert_eq!(out.suppressed, 1);
}

// ------------------------------------------------------------------- entropy

#[test]
fn entropy_fires_on_ambient_sources() {
    assert!(fires("data/x.rs", "fn f() { let r = thread_rng(); }", RULE_ENTROPY));
    assert!(fires("wal/x.rs", "use rand::Rng;", RULE_ENTROPY));
    assert!(fires(
        "server/x.rs",
        "fn f() { let s = RandomState::new(); }",
        RULE_ENTROPY
    ));
}

#[test]
fn entropy_clean_on_util_rng() {
    let src = "\
fn f() {
    let mut rng = crate::util::rng::SplitMix64::new(7);
    let x = crate::util::rng::philox_u64(1, 2);
    let _ = (rng.next_u64(), x);
}";
    assert!(rules_of("data/x.rs", src).is_empty());
}

#[test]
fn entropy_suppressed_by_allow() {
    let src = "fn f() { let r = thread_rng(); } \
               // detlint: allow(entropy) — quarantined example, never built";
    assert_eq!(suppressed_count("data/x.rs", src), 1);
}

// ------------------------------------------------------------ unsafe-comment

#[test]
fn unsafe_without_safety_comment_fires() {
    let src = "fn f(p: *const u8) -> u8 { unsafe { *p } }";
    assert!(fires("util/x.rs", src, RULE_UNSAFE_COMMENT));
    let imp = "unsafe impl Send for X {}";
    assert!(fires("runtime/x.rs", imp, RULE_UNSAFE_COMMENT));
}

#[test]
fn unsafe_with_safety_comment_is_clean() {
    let above = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid for reads
    unsafe { *p }
}";
    assert!(rules_of("util/x.rs", above).is_empty());
    let trailing = "fn f(p: *const u8) -> u8 { unsafe { *p } } // SAFETY: valid";
    assert!(rules_of("util/x.rs", trailing).is_empty());
    let with_attr = "\
// SAFETY: no interior mutability, all fields Send
#[cfg(feature = \"x\")]
unsafe impl Send for X {}";
    assert!(rules_of("runtime/x.rs", with_attr).is_empty());
}

#[test]
fn unsafe_suppressed_by_allow() {
    let src = "fn f(p: *const u8) -> u8 { unsafe { *p } } \
               // detlint: allow(unsafe-comment) — documented at the call site";
    assert_eq!(suppressed_count("util/x.rs", src), 1);
}

// ------------------------------------------------- scoping & classification

#[test]
fn cfg_test_regions_are_not_scanned() {
    let src = "\
fn prod() {}
#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn t() {
        let t0 = Instant::now();
        let r = thread_rng();
        unsafe { std::hint::unreachable_unchecked() }
    }
}";
    assert!(rules_of("controller/x.rs", src).is_empty());
}

#[test]
fn code_before_a_test_region_still_fires() {
    let src = "\
fn prod() { let t = Instant::now(); }
#[cfg(test)]
mod tests {}";
    assert!(fires("controller/x.rs", src, RULE_WALL_CLOCK));
}

#[test]
fn patterns_inside_strings_and_comments_never_fire() {
    let src = r##"
fn f() {
    let a = "SystemTime::now() fs::write(p) thread_rng() unsafe";
    let b = r#"for (k, v) in &self.m { .sum::<f32>() }"#;
    // Instant::now(); File::create(p); rand::random()
    /* RandomState::new(); .fold(0.0f32, f32::max) */
}
"##;
    assert!(rules_of("wal/x.rs", src).is_empty());
}

// ------------------------------------------------------------ lexer property

/// Adversarial source fragments: nested comments, raw strings, char
/// literals containing `//` and quotes, lifetimes, floats, non-ASCII.
const FRAGMENTS: &[&str] = &[
    "fn f() {}",
    "let s = \"a // not a comment \\\" quoted\";",
    "let c = '\\'';",
    "let d = '/'; let e = '\\\\';",
    "let u = '\\u{41}';",
    "/* outer /* nested */ tail */",
    "// line comment with \" and '\n",
    "r#\"raw // \" inside\"#",
    "r\"plain raw\"",
    "b\"bytes \\\" esc\"",
    "b'x'",
    "'a'",
    "fn g<'a>(x: &'a str) -> &'a str { x }",
    "let n = 1.5e-3f32 + 0x1F as f32;",
    "for i in 0..10 { a[i] += 1; }",
    "let url = \"http://example\";",
    "x.0.to_string()",
    "日本語",
    "// detlint: allow(wall-clock) — fragment\n",
    "#[cfg(test)] mod t { }",
];

fn check_lex_invariants(src: &str) {
    let toks = lex(src);
    let mut prev_end = 0usize;
    for t in &toks {
        assert!(t.start >= prev_end, "overlap at {t:?}");
        assert!(t.end > t.start && t.end <= src.len(), "bad span {t:?}");
        assert!(
            src.is_char_boundary(t.start) && src.is_char_boundary(t.end),
            "span splits a UTF-8 scalar: {t:?}"
        );
        let prefix = &src[..t.start];
        let line = 1 + prefix.bytes().filter(|&b| b == b'\n').count() as u32;
        let col =
            (t.start - prefix.rfind('\n').map(|i| i + 1).unwrap_or(0) + 1) as u32;
        assert_eq!(
            (t.line, t.col),
            (line, col),
            "line/col drift for {:?} (text {:?})",
            t,
            t.text(src)
        );
        prev_end = t.end;
    }
    // every byte outside a token span is whitespace
    let mut covered = vec![false; src.len()];
    for t in &toks {
        for c in covered.iter_mut().take(t.end).skip(t.start) {
            *c = true;
        }
    }
    for (i, b) in src.bytes().enumerate() {
        if !covered[i] {
            assert!(
                matches!(b, b' ' | b'\t' | b'\r' | b'\n'),
                "non-whitespace byte {b:#04x} at {i} not covered by any token"
            );
        }
    }
}

#[test]
fn lexer_spans_roundtrip_on_adversarial_input() {
    // the fixed fragments individually and concatenated
    for f in FRAGMENTS {
        check_lex_invariants(f);
    }
    for_all("lexer span/line/col roundtrip", |rng| {
        let n = 1 + rng.below(30) as usize;
        let mut src = String::new();
        for _ in 0..n {
            src.push_str(FRAGMENTS[rng.below(FRAGMENTS.len() as u64) as usize]);
            match rng.below(4) {
                0 => src.push(' '),
                1 => src.push('\n'),
                2 => src.push_str("\r\n"),
                _ => {}
            }
        }
        check_lex_invariants(&src);
        assert_eq!(lex(&src), lex(&src)); // deterministic
    });
}

// --------------------------------------------------------- repo conformance

/// Scan the real `src/` tree and gate against the committed baseline:
/// zero new findings.  The baseline is EMPTY, so this asserts the repo
/// is clean by construction — and because every sanctioned exception is
/// a `detlint: allow` in source, deleting any one of them turns its
/// finding into a NEW finding and fails this test (and the CI job).
#[test]
fn repo_is_conformant_vs_committed_baseline() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let report = scan_dir(&manifest.join("src")).expect("scan src/");
    assert!(report.files_scanned > 40, "suspiciously few files scanned");
    let verdict = gate::gate_against_file(
        &report.findings,
        &manifest.join("detlint-baseline.json"),
    )
    .expect("load committed baseline");
    assert!(
        verdict.pass(),
        "new detlint findings (fix or detlint: allow with a reason):\n{:#?}",
        verdict.new
    );
    // the sanctioned-exception inventory (PR 7 audit): 2 wall-clock,
    // 1 raw-fs, 1 unordered-iter, 7 float-reduce = 11 allows minimum
    assert!(
        report.suppressed >= 11,
        "expected the audited allow annotations to be live, saw {}",
        report.suppressed
    );
}
