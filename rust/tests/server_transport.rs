//! Adversarial transport tests for the shared line-framed connection
//! loop (`serve_line_conn`) over a REAL socket pair: oversized lines
//! are refused with a typed response, an idle connection observes
//! shutdown through its read timeout, and a partial line followed by a
//! disconnect never becomes an enqueued job.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::server::{serve_event_loop, serve_line_conn, JobQueue, JobRequest};
use unlearn::util::json::{parse, Json};
use unlearn::util::tempdir;

/// The dispatch a real admin server wires in, reduced to its queue
/// interaction: a well-formed submit enqueues (durably) and acks with
/// the job id; everything else is refused.  Tests assert on the QUEUE,
/// the consistency target of the transport hardening.
fn dispatch_submit(line: &str, q: &JobQueue<JobRequest>) -> Json {
    let mut out = Json::obj();
    let parsed = match parse(line) {
        Ok(j) => j,
        Err(e) => {
            out.set("ok", false).set("error", format!("bad json: {e}"));
            return out;
        }
    };
    match parsed.get("op").and_then(|v| v.as_str()) {
        Some("submit") => {
            let Some(id) = parsed.get("id").and_then(|v| v.as_str()) else {
                out.set("ok", false).set("error", "request needs id");
                return out;
            };
            let req = JobRequest::Forget(ForgetRequest {
                id: id.to_string(),
                user: parsed.get("user").and_then(|v| v.as_u64()).map(|u| u as u32),
                sample_ids: vec![],
                urgency: Urgency::Normal,
            });
            match q.submit(req) {
                Ok(Some(job)) => {
                    out.set("ok", true).set("job", job.as_str());
                }
                Ok(None) => {
                    out.set("ok", false).set("error", "closed");
                }
                Err(e) => {
                    out.set("ok", false).set("error", format!("{e:#}"));
                }
            }
        }
        _ => {
            out.set("ok", false).set("error", "unknown op");
        }
    }
    out
}

/// Accept ONE connection and serve it with `serve_line_conn` against a
/// WAL-backed queue; run `client` against the other end.  Returns the
/// handler's result and the queue for post-mortem assertions.
fn with_conn(
    shutdown: &AtomicBool,
    client: impl FnOnce(TcpStream) + Send,
) -> (anyhow::Result<()>, JobQueue<JobRequest>) {
    let q = JobQueue::<JobRequest>::with_wal(
        &tempdir("transport").join("jobs.wal"),
    )
    .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let local = listener.local_addr().unwrap();
    let mut served = Err(anyhow::anyhow!("handler never ran"));
    std::thread::scope(|s| {
        let handler = s.spawn(|| {
            let (conn, _) = listener.accept().unwrap();
            serve_line_conn(conn, local, shutdown, |line| {
                dispatch_submit(line, &q)
            })
        });
        let conn = TcpStream::connect(local).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        client(conn);
        served = handler.join().unwrap();
    });
    (served, q)
}

#[test]
fn oversized_line_is_refused_with_typed_response() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_conn(&shutdown, |mut conn| {
        // > 1 MiB with NO newline: a client streaming bytes to grow the
        // handler's buffer without ever completing a request
        let blob = vec![b'a'; (1 << 20) + 1];
        conn.write_all(&blob).unwrap();
        conn.flush().unwrap();

        let mut r = BufReader::new(conn);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = parse(line.trim()).expect("typed refusal is valid json");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert!(
            j.get("error")
                .and_then(|v| v.as_str())
                .unwrap()
                .contains("exceeds 1 MiB"),
            "refusal names the line cap"
        );
        // and the server closed the connection afterwards
        let mut rest = Vec::new();
        assert_eq!(r.read_to_end(&mut rest).unwrap(), 0);
    });
    served.expect("handler exits cleanly after refusing");
    assert_eq!(q.queued_len(), 0, "nothing was enqueued from the flood");
}

#[test]
fn idle_connection_observes_shutdown_via_read_timeout() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_conn(&shutdown, |conn| {
        // say nothing; the handler must not block past shutdown
        std::thread::sleep(Duration::from_millis(50));
        shutdown.store(true, Ordering::SeqCst);
        // the handler notices within one 200ms read-timeout tick; hold
        // the socket open the whole time so only the flag can free it
        std::thread::sleep(Duration::from_millis(450));
        drop(conn);
    });
    served.expect("idle handler returned cleanly on shutdown");
    assert_eq!(q.queued_len(), 0);
}

#[test]
fn partial_line_then_disconnect_leaves_queue_consistent() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_conn(&shutdown, |mut conn| {
        // one complete request...
        conn.write_all(b"{\"op\":\"submit\",\"id\":\"t-1\",\"user\":3}\n")
            .unwrap();
        let mut r = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
        let job = j.get("job").and_then(|v| v.as_str()).unwrap().to_string();
        assert!(!job.is_empty());

        // ...then a request torn mid-line by a disconnect
        conn.write_all(b"{\"op\":\"submit\",\"id\":\"t-2\"").unwrap();
        conn.flush().unwrap();
        conn.shutdown(Shutdown::Write).unwrap();

        // the fragment is refused, never enqueued
        line.clear();
        r.read_line(&mut line).unwrap();
        let j = parse(line.trim()).expect("refusal is valid json");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
    });
    served.expect("handler exits cleanly after client disconnect");
    assert_eq!(
        q.queued_len(),
        1,
        "exactly the complete request is queued — the torn one is not"
    );
    let Json::Arr(rows) = q.jobs_json() else { panic!() };
    assert_eq!(
        rows[0].get("request_id").and_then(|v| v.as_str()),
        Some("t-1")
    );
}

// ---------------------------------------------------------------------
// Event-loop transport: the same adversarial contract, but against the
// shared nonblocking poll loop (`serve_event_loop`) serving MANY
// connections from one thread.
// ---------------------------------------------------------------------

/// Run `serve_event_loop` on an ephemeral listener against a WAL-backed
/// queue; run `client` with the address, then flip shutdown and join.
fn with_event_loop(
    shutdown: &AtomicBool,
    client: impl FnOnce(std::net::SocketAddr) + Send,
) -> (anyhow::Result<()>, JobQueue<JobRequest>) {
    let q = JobQueue::<JobRequest>::with_wal(
        &tempdir("transport-evt").join("jobs.wal"),
    )
    .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let local = listener.local_addr().unwrap();
    let mut served = Err(anyhow::anyhow!("loop never ran"));
    std::thread::scope(|s| {
        let looper = s.spawn(|| {
            serve_event_loop(listener, shutdown, |line| {
                dispatch_submit(line, &q)
            })
        });
        client(local);
        shutdown.store(true, Ordering::SeqCst);
        served = looper.join().unwrap();
    });
    (served, q)
}

/// One round-trip submit over an existing connection.
fn submit_roundtrip(conn: &mut TcpStream, id: &str) -> Json {
    conn.write_all(
        format!("{{\"op\":\"submit\",\"id\":\"{id}\",\"user\":7}}\n")
            .as_bytes(),
    )
    .unwrap();
    let mut r = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    parse(line.trim()).expect("response is valid json")
}

#[test]
fn event_loop_multiplexes_past_a_slow_loris() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_event_loop(&shutdown, |addr| {
        // a slow-loris client parks a PARTIAL frame on the loop and
        // holds the socket open — under thread-per-conn this costs a
        // thread; under a single blocking read it would stall everyone
        let mut loris = TcpStream::connect(addr).unwrap();
        loris
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        loris
            .write_all(b"{\"op\":\"submit\",\"id\":\"loris")
            .unwrap();
        loris.flush().unwrap();

        // 8 well-behaved clients all complete full round-trips while
        // the loris frame sits unfinished (read timeout = the test's
        // stall detector: a blocked loop fails these reads)
        for c in 0..8 {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(10)))
                .unwrap();
            let j = submit_roundtrip(&mut conn, &format!("fast-{c}"));
            assert_eq!(
                j.get("ok").and_then(|v| v.as_bool()),
                Some(true),
                "fast client {c} served while loris stalls: {j:?}"
            );
        }

        // the loris finally completes its line and is served too
        loris.write_all(b"\",\"user\":1}\n").unwrap();
        let mut r = BufReader::new(loris);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
    });
    served.expect("event loop exits cleanly");
    assert_eq!(q.queued_len(), 9, "8 fast submits + the completed loris");
}

#[test]
fn event_loop_refuses_oversized_line_without_harming_neighbors() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_event_loop(&shutdown, |addr| {
        let mut flooder = TcpStream::connect(addr).unwrap();
        flooder
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let blob = vec![b'a'; (1 << 20) + 1];
        flooder.write_all(&blob).unwrap();
        flooder.flush().unwrap();

        let mut r = BufReader::new(flooder);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = parse(line.trim()).expect("typed refusal is valid json");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert!(
            j.get("error")
                .and_then(|v| v.as_str())
                .unwrap()
                .contains("exceeds 1 MiB"),
            "refusal names the line cap"
        );
        let mut rest = Vec::new();
        assert_eq!(r.read_to_end(&mut rest).unwrap(), 0, "flooder closed");

        // the loop is still healthy for everyone else
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let j = submit_roundtrip(&mut conn, "after-flood");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
    });
    served.expect("event loop survives the flood");
    assert_eq!(q.queued_len(), 1, "only the honest submit was enqueued");
}

#[test]
fn event_loop_idle_connections_observe_shutdown() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_event_loop(&shutdown, |addr| {
        // several clients connect and say nothing
        let conns: Vec<TcpStream> = (0..4)
            .map(|_| {
                let c = TcpStream::connect(addr).unwrap();
                c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                c
            })
            .collect();
        std::thread::sleep(Duration::from_millis(50));
        shutdown.store(true, Ordering::SeqCst);
        // the loop notices within an idle tick, drains and drops every
        // connection: each idle client sees EOF, not a hang
        for c in conns {
            let mut r = BufReader::new(c);
            let mut line = String::new();
            assert_eq!(
                r.read_line(&mut line).unwrap(),
                0,
                "idle connection closed by shutdown"
            );
        }
    });
    served.expect("event loop returned cleanly on shutdown");
    assert_eq!(q.queued_len(), 0);
}

#[test]
fn event_loop_partial_line_then_disconnect_never_enqueues() {
    let shutdown = AtomicBool::new(false);
    let (served, q) = with_event_loop(&shutdown, |addr| {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let j = submit_roundtrip(&mut conn, "e-1");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));

        // torn mid-line by a disconnect: dispatched as a fragment,
        // refused, never enqueued
        conn.write_all(b"{\"op\":\"submit\",\"id\":\"e-2\"").unwrap();
        conn.flush().unwrap();
        conn.shutdown(Shutdown::Write).unwrap();

        let mut r = BufReader::new(conn);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = parse(line.trim()).expect("refusal is valid json");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(false));
    });
    served.expect("event loop exits cleanly after client disconnect");
    assert_eq!(
        q.queued_len(),
        1,
        "exactly the complete request is queued — the torn one is not"
    );
    let Json::Arr(rows) = q.jobs_json() else { panic!() };
    assert_eq!(
        rows[0].get("request_id").and_then(|v| v.as_str()),
        Some("e-1")
    );
}

#[test]
fn event_loop_delivers_multi_mib_response_to_slow_reader() {
    // A multi-MiB response (a replica CAS manifest dump, a fleet status
    // with per-replica rows) must reach a reader that drains slowly but
    // STEADILY.  The loop flushes in `WRITE_CHUNK`-bounded slices and
    // starts the 5s stall clock only on zero-progress sweeps, so a
    // transfer whose total wall time is far past the stall limit is
    // fine as long as bytes keep moving.  Before flush-owned stall
    // accounting, mid-pump flushes discarded progress and a draining
    // client could be evicted mid-response.
    const BLOB: usize = 8 * (1 << 20);
    let shutdown = AtomicBool::new(false);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut served = Err(anyhow::anyhow!("loop never ran"));
    std::thread::scope(|s| {
        let looper = s.spawn(|| {
            serve_event_loop(listener, &shutdown, |_line| {
                let mut out = Json::obj();
                out.set("ok", true).set("blob", "x".repeat(BLOB));
                out
            })
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        conn.write_all(b"{\"op\":\"big\"}\n").unwrap();
        conn.flush().unwrap();

        // drain in small chunks with sub-limit pauses: total elapsed
        // exceeds WRITE_STALL_LIMIT but every sweep sees progress
        let t0 = Instant::now();
        let mut buf = vec![0u8; 128 * 1024];
        let mut got: Vec<u8> = Vec::with_capacity(BLOB + 64);
        loop {
            let n = conn.read(&mut buf).unwrap();
            assert!(
                n > 0,
                "server evicted the slow reader after {} of {} bytes \
                 ({:?} elapsed)",
                got.len(),
                BLOB,
                t0.elapsed()
            );
            got.extend_from_slice(&buf[..n]);
            if got.last() == Some(&b'\n') {
                break;
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        assert!(
            t0.elapsed() > Duration::from_secs(5),
            "the drain must outlast the stall limit for the test to \
             mean anything (took {:?})",
            t0.elapsed()
        );
        let line = String::from_utf8(got).expect("utf8 response");
        let j = parse(line.trim()).expect("full response is valid json");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            j.get("blob").and_then(|v| v.as_str()).map(|s| s.len()),
            Some(BLOB),
            "every byte of the response arrived"
        );
        drop(conn);
        shutdown.store(true, Ordering::SeqCst);
        served = looper.join().unwrap();
    });
    served.expect("event loop exits cleanly after the slow drain");
}
