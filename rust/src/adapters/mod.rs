//! Cohort-scoped LoRA adapter registry (paper G2, Alg. A.5, §4.2(ii)).
//!
//! Each cohort trains its own low-rank patch `P_j` against a **strictly
//! frozen** base (the `lora_step` graph computes gradients w.r.t. the
//! adapter only).  Deleting `P_j` removes the cohort's parametric
//! influence exactly; adapters are never merged into the base (merging
//! is checked and refused — Alg. A.5 line 1).  Compaction folds several
//! adapters into one low-rank patch *without touching the base*.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::data::corpus::Corpus;
use crate::runtime::Runtime;
use crate::trainer::build_microbatch_tensors;
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::json::Json;

/// One cohort adapter.
#[derive(Debug, Clone)]
pub struct Adapter {
    pub cohort: u32,
    /// Flat LoRA parameter vector (layout in the AOT manifest).
    pub params: Vec<f32>,
    /// Sample IDs this cohort was trained on (its parametric scope).
    pub trained_on: Vec<u64>,
    /// Training steps applied.
    pub steps: u32,
    /// G2 precondition flag: never merged into the base.
    pub merged: bool,
}

/// Registry of live adapters (the "patch registry & router" of §3.4).
#[derive(Debug, Default)]
pub struct AdapterRegistry {
    adapters: BTreeMap<u32, Adapter>,
}

/// Result of training a cohort adapter.
#[derive(Debug, Clone)]
pub struct CohortTrainStats {
    pub cohort: u32,
    pub steps: u32,
    pub final_loss_per_token: f32,
}

impl AdapterRegistry {
    pub fn new() -> AdapterRegistry {
        AdapterRegistry::default()
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }

    pub fn get(&self, cohort: u32) -> Option<&Adapter> {
        self.adapters.get(&cohort)
    }

    pub fn cohorts(&self) -> Vec<u32> {
        self.adapters.keys().copied().collect()
    }

    /// Are ALL of `ids` confined to cohort adapters?  (Alg. A.7 line 2's
    /// routing predicate.)  Returns the owning cohorts if so.
    pub fn covering_cohorts(&self, ids: &[u64]) -> Option<Vec<u32>> {
        let mut cohorts = Vec::new();
        'outer: for &id in ids {
            for (c, a) in &self.adapters {
                if a.trained_on.contains(&id) {
                    if !cohorts.contains(c) {
                        cohorts.push(*c);
                    }
                    continue 'outer;
                }
            }
            return None; // id not confined to any adapter
        }
        Some(cohorts)
    }

    /// Register an already-trained adapter (production registries load
    /// persisted patches at startup; tests fabricate scopes directly).
    /// Refuses to shadow a live cohort — scoped deletion must never
    /// silently lose a patch.
    pub fn insert(&mut self, adapter: Adapter) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.adapters.contains_key(&adapter.cohort),
            "cohort {} already registered",
            adapter.cohort
        );
        self.adapters.insert(adapter.cohort, adapter);
        Ok(())
    }

    /// Train a cohort adapter on its samples, base strictly frozen.
    pub fn train_cohort(
        &mut self,
        rt: &Runtime,
        corpus: &Corpus,
        base: &[f32],
        cohort: u32,
        ids: &[u64],
        steps: u32,
        lr: f32,
        seed: u64,
    ) -> anyhow::Result<CohortTrainStats> {
        anyhow::ensure!(!ids.is_empty(), "cohort {cohort} has no samples");
        let man = &rt.manifest;
        let mut lora = man.init_lora()?;
        let mut m = vec![0.0f32; lora.len()];
        let mut v = vec![0.0f32; lora.len()];
        let mut rng = crate::util::rng::SplitMix64::new(seed ^ cohort as u64);
        let mut last_loss = 0.0f32;
        for t in 0..steps {
            let take = man.batch.min(ids.len());
            let chunk: Vec<u64> = (0..take)
                .map(|_| ids[rng.below(ids.len() as u64) as usize])
                .collect();
            let (tokens, mask, _) = build_microbatch_tensors(
                corpus, &chunk, man.batch, man.seq_len, |_| false, false,
            )?;
            let out = rt.lora_step(base, &lora, &tokens, &mask,
                                   (seed as i32).wrapping_add(t as i32))?;
            let (l2, m2, v2) =
                rt.lora_adamw(&lora, &out.grad, &m, &v, t as i32 + 1, lr)?;
            lora = l2;
            m = m2;
            v = v2;
            last_loss = out.loss_sum / out.tok_count.max(1.0);
        }
        self.adapters.insert(
            cohort,
            Adapter {
                cohort,
                params: lora,
                trained_on: ids.to_vec(),
                steps,
                merged: false,
            },
        );
        Ok(CohortTrainStats {
            cohort,
            steps,
            final_loss_per_token: last_loss,
        })
    }

    /// DELETECOHORTADAPTER (Alg. A.5): exact scoped deletion.  Refuses
    /// (routing the controller to replay) if the adapter was merged.
    pub fn delete_cohort(&mut self, cohort: u32) -> anyhow::Result<Adapter> {
        let a = self
            .adapters
            .get(&cohort)
            .ok_or_else(|| anyhow::anyhow!("unknown cohort {cohort}"))?;
        anyhow::ensure!(
            !a.merged,
            "cohort {cohort} was merged into the base — exact adapter \
             deletion impossible, escalate to replay (Alg. A.5 line 1)"
        );
        Ok(self.adapters.remove(&cohort).expect("checked"))
    }

    /// Mark an adapter merged (test hook modelling the forbidden state).
    pub fn mark_merged(&mut self, cohort: u32) {
        if let Some(a) = self.adapters.get_mut(&cohort) {
            a.merged = true;
        }
    }

    /// Compact several adapters into one patch by summing their flat
    /// vectors (the low-rank factors add in the patch space because all
    /// adapters share the same (A,B) geometry; no base update happens).
    /// The compacted adapter's scope is the union of the sources'.
    pub fn compact(
        &mut self,
        cohorts: &[u32],
        new_cohort: u32,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(!cohorts.is_empty(), "nothing to compact");
        let mut sum: Option<Vec<f32>> = None;
        let mut scope = Vec::new();
        let mut steps = 0;
        for c in cohorts {
            let a = self
                .adapters
                .get(c)
                .ok_or_else(|| anyhow::anyhow!("unknown cohort {c}"))?;
            anyhow::ensure!(!a.merged, "cannot compact merged cohort {c}");
            match &mut sum {
                None => sum = Some(a.params.clone()),
                Some(s) => {
                    for (x, y) in s.iter_mut().zip(&a.params) {
                        *x += y;
                    }
                }
            }
            scope.extend_from_slice(&a.trained_on);
            steps += a.steps;
        }
        for c in cohorts {
            self.adapters.remove(c);
        }
        scope.sort_unstable();
        scope.dedup();
        self.adapters.insert(
            new_cohort,
            Adapter {
                cohort: new_cohort,
                params: sum.expect("non-empty"),
                trained_on: scope,
                steps,
                merged: false,
            },
        );
        Ok(())
    }

    /// Persist the registry (one .lora file per cohort + index.json).
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut index = Json::obj();
        for (c, a) in &self.adapters {
            let file = format!("cohort-{c:04}.lora");
            std::fs::write(dir.join(&file), f32s_to_bytes(&a.params))?;
            let mut meta = Json::obj();
            meta.set("file", file.as_str())
                .set("steps", a.steps)
                .set("merged", a.merged)
                .set(
                    "trained_on",
                    Json::Arr(
                        a.trained_on.iter().map(|&i| i.into()).collect(),
                    ),
                );
            index.set(&c.to_string(), meta);
        }
        std::fs::write(dir.join("index.json"), index.pretty())?;
        Ok(())
    }

    pub fn load(dir: &Path) -> anyhow::Result<AdapterRegistry> {
        let mut reg = AdapterRegistry::new();
        let idx_path = dir.join("index.json");
        if !idx_path.exists() {
            return Ok(reg);
        }
        let idx = crate::util::json::parse(&std::fs::read_to_string(idx_path)?)
            .map_err(|e| anyhow::anyhow!("adapter index: {e}"))?;
        if let Some(obj) = idx.as_obj() {
            for (c, meta) in obj {
                let cohort: u32 = c.parse()?;
                let file = meta
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("adapter meta"))?;
                let params = bytes_to_f32s(&std::fs::read(dir.join(file))?)?;
                reg.adapters.insert(
                    cohort,
                    Adapter {
                        cohort,
                        params,
                        trained_on: meta
                            .get("trained_on")
                            .and_then(|v| v.as_arr())
                            .map(|a| {
                                a.iter()
                                    .filter_map(|x| x.as_u64())
                                    .collect()
                            })
                            .unwrap_or_default(),
                        steps: meta
                            .get("steps")
                            .and_then(|v| v.as_u64())
                            .unwrap_or(0) as u32,
                        merged: meta
                            .get("merged")
                            .and_then(|v| v.as_bool())
                            .unwrap_or(false),
                    },
                );
            }
        }
        Ok(reg)
    }

    /// Path of a cohort file inside a registry dir (content addressing
    /// for the forget manifest).
    pub fn cohort_path(dir: &Path, cohort: u32) -> PathBuf {
        dir.join(format!("cohort-{cohort:04}.lora"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adapter(c: u32, ids: &[u64]) -> Adapter {
        Adapter {
            cohort: c,
            params: vec![c as f32; 8],
            trained_on: ids.to_vec(),
            steps: 1,
            merged: false,
        }
    }

    fn reg_with(adapters: Vec<Adapter>) -> AdapterRegistry {
        let mut r = AdapterRegistry::new();
        for a in adapters {
            r.adapters.insert(a.cohort, a);
        }
        r
    }

    #[test]
    fn covering_cohorts_routing_predicate() {
        let r = reg_with(vec![adapter(1, &[10, 11]), adapter(2, &[20])]);
        assert_eq!(r.covering_cohorts(&[10, 20]), Some(vec![1, 2]));
        assert_eq!(r.covering_cohorts(&[10]), Some(vec![1]));
        assert_eq!(r.covering_cohorts(&[10, 99]), None);
        assert_eq!(r.covering_cohorts(&[]), Some(vec![]));
    }

    #[test]
    fn delete_refuses_merged() {
        let mut r = reg_with(vec![adapter(1, &[1])]);
        r.mark_merged(1);
        assert!(r.delete_cohort(1).is_err());
        assert_eq!(r.len(), 1, "refusal must not delete");
    }

    #[test]
    fn delete_removes_exactly_one() {
        let mut r = reg_with(vec![adapter(1, &[1]), adapter(2, &[2])]);
        let a = r.delete_cohort(1).unwrap();
        assert_eq!(a.cohort, 1);
        assert_eq!(r.cohorts(), vec![2]);
        assert!(r.delete_cohort(1).is_err());
    }

    #[test]
    fn compact_sums_patches_and_unions_scope() {
        let mut r = reg_with(vec![adapter(1, &[1, 2]), adapter(2, &[2, 3])]);
        r.compact(&[1, 2], 7).unwrap();
        assert_eq!(r.cohorts(), vec![7]);
        let a = r.get(7).unwrap();
        assert_eq!(a.params, vec![3.0; 8]); // 1.0 + 2.0
        assert_eq!(a.trained_on, vec![1, 2, 3]);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::tempdir("adapters");
        let r = reg_with(vec![adapter(3, &[5, 6]), adapter(9, &[7])]);
        r.save(&dir).unwrap();
        let back = AdapterRegistry::load(&dir).unwrap();
        assert_eq!(back.cohorts(), vec![3, 9]);
        assert_eq!(back.get(3).unwrap().params, vec![3.0; 8]);
        assert_eq!(back.get(3).unwrap().trained_on, vec![5, 6]);
    }
}
