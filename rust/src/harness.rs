//! Shared experiment harness used by examples, benches and integration
//! tests: builds a trained [`UnlearnSystem`] from scratch (artifacts →
//! runtime → corpus → training run → controller state) with small
//! defaults so every paper experiment starts from the same scaffolding.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use crate::adapters::AdapterRegistry;
use crate::audit::AuditThresholds;
use crate::checkpoint::CheckpointStore;
use crate::config::RunConfig;
use crate::controller::{IngestStatus, UnlearnSystem};
use crate::curvature::{FisherCache, HotPathParams};
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::manifest::ForgetManifest;
use crate::neardup::closure::build_index;
use crate::neardup::ClosureParams;
use crate::replay::load_run;
use crate::runtime::Runtime;
use crate::trainer::{TrainOutput, Trainer};
use crate::util::rng::SplitMix64;

/// Locate the artifacts directory (env `UNLEARN_ARTIFACTS` overrides).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("UNLEARN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Default toy corpus (paper §6 scale: ~2k samples, canaried users 0-4).
pub fn toy_corpus(seq_len: usize) -> Corpus {
    Corpus::generate(CorpusConfig {
        seq_len,
        ..CorpusConfig::default()
    })
}

/// A smaller corpus for fast tests/benches.
pub fn small_corpus(seq_len: usize) -> Corpus {
    Corpus::generate(CorpusConfig {
        n_users: 24,
        docs_per_user: 4,
        n_canary_users: 2,
        canaries_per_user: 2,
        near_dup_rate: 0.08,
        seq_len,
        seed: 7,
    })
}

/// Train a run and assemble the full controller system around it.
pub struct TrainedSystem<'rt> {
    pub system: UnlearnSystem<'rt>,
    pub train_output_losses: Vec<(u32, f32)>,
}

/// Split non-forget IDs into (retain member controls, held-out eval).
pub fn audit_splits(
    corpus: &Corpus,
    forget: &HashSet<u64>,
    seed: u64,
) -> (Vec<u64>, Vec<u64>) {
    let mut rng = SplitMix64::new(seed);
    let mut rest: Vec<u64> = corpus
        .samples
        .iter()
        .map(|s| s.id)
        .filter(|id| !forget.contains(id))
        .collect();
    rng.shuffle(&mut rest);
    let n_ctl = rest.len().min(48);
    let n_eval = rest.len().saturating_sub(n_ctl).min(64);
    let controls = rest[..n_ctl].to_vec();
    let eval = rest[n_ctl..n_ctl + n_eval].to_vec();
    (controls, eval)
}

/// Train from scratch (any existing run dir is wiped) and build the
/// system — the fresh-experiment path tests and benches use.
pub fn build_system<'rt>(
    rt: &'rt Runtime,
    mut cfg: RunConfig,
    corpus: Corpus,
    estimate_fisher: bool,
) -> anyhow::Result<TrainedSystem<'rt>> {
    if cfg.run_dir.exists() {
        std::fs::remove_dir_all(&cfg.run_dir)?;
    }
    cfg.artifacts_dir = rt.manifest.dir.clone();
    let trainer = Trainer::new(rt, cfg.clone(), corpus.clone());
    let out: TrainOutput = trainer.train(|_| false)?;
    system_from_run(rt, cfg, corpus, out, estimate_fisher)
}

/// Reopen a finished run directory when one exists, else train from
/// scratch — the restart path (`unlearn serve`): the WAL, checkpoint
/// lineages, signed manifest, jobs WAL and persisted forgotten set all
/// survive the process.  The serving state is reloaded from the latest
/// checkpoint and, when un-laundered forgotten influence is pending,
/// rebuilt by `system_from_run`'s filtered replay.  The delta ring does
/// not persist (its patches describe transitions this process never
/// recorded), so it restarts empty — ring paths simply miss until new
/// training records into it.  The corpus must be regenerated with the
/// same config/seed as the original run; the pin check fails closed on
/// drift.
pub fn open_or_build_system<'rt>(
    rt: &'rt Runtime,
    mut cfg: RunConfig,
    corpus: Corpus,
    estimate_fisher: bool,
) -> anyhow::Result<(TrainedSystem<'rt>, bool)> {
    let resumable = cfg.run_dir.join("wal").exists()
        && cfg.run_dir.join("pins.json").exists()
        && cfg.run_dir.join("ids.map").exists();
    if !resumable {
        return Ok((build_system(rt, cfg, corpus, estimate_fisher)?, false));
    }
    cfg.artifacts_dir = rt.manifest.dir.clone();
    let store = store_of(&cfg.run_dir, cfg.checkpoint_keep)?;
    let latest = store.list_full()?.into_iter().max().ok_or_else(|| {
        anyhow::anyhow!(
            "run dir {} has a WAL but no checkpoints — cannot resume",
            cfg.run_dir.display()
        )
    })?;
    let out = TrainOutput {
        state: store.load_full(latest)?,
        ring: crate::deltas::DeltaRing::new(
            rt.manifest.param_count,
            cfg.ring_window,
            crate::deltas::PatchMode::Xor,
            cfg.ring_revert_optimizer,
        ),
        idmap: crate::wal::IdMap::new(cfg.hmac_key.clone()),
        losses: Vec::new(),
        wal_dir: cfg.run_dir.join("wal"),
        run_dir: cfg.run_dir.clone(),
    };
    Ok((
        system_from_run_with_store(rt, cfg, corpus, out, estimate_fisher, store)?,
        true,
    ))
}

/// Assemble the controller system from a finished training run.
pub fn system_from_run<'rt>(
    rt: &'rt Runtime,
    cfg: RunConfig,
    corpus: Corpus,
    out: TrainOutput,
    estimate_fisher: bool,
) -> anyhow::Result<TrainedSystem<'rt>> {
    let store =
        CheckpointStore::open(&cfg.run_dir.join("ckpt"), cfg.checkpoint_keep)?;
    system_from_run_with_store(rt, cfg, corpus, out, estimate_fisher, store)
}

/// [`system_from_run`] over an already-validated store handle — the
/// resume path opened (and fail-closed-swept) one to find the latest
/// checkpoint; re-opening here would double the startup I/O the cached
/// handle exists to avoid.
fn system_from_run_with_store<'rt>(
    rt: &'rt Runtime,
    cfg: RunConfig,
    corpus: Corpus,
    out: TrainOutput,
    estimate_fisher: bool,
    store: CheckpointStore,
) -> anyhow::Result<TrainedSystem<'rt>> {
    let (records, idmap, pins) = load_run(&cfg.run_dir, cfg.hmac_key.clone())?;
    let ndindex = build_index(&corpus);
    let manifest = ForgetManifest::open(
        &cfg.run_dir.join("forget.manifest"),
        cfg.hmac_key.as_deref().unwrap_or(b"toy-manifest-key"),
    )?;
    let (retain_ids, eval_ids) =
        audit_splits(&corpus, &HashSet::new(), cfg.run_seed ^ 0xA0D1);
    let fisher = if estimate_fisher {
        let sample: Vec<u64> = retain_ids.iter().take(32).copied().collect();
        Some(FisherCache::estimate(
            rt,
            &corpus,
            &out.state.params,
            &sample,
            cfg.run_seed,
        )?)
    } else {
        None
    };
    let losses = out.losses.clone();
    // a reopened run may already have a laundered lineage and/or a
    // persisted cumulative forgotten set: both survive with the run
    // dir, not the process (exactness across restarts)
    let (laundered_residue, lineage_retired) = store.laundered_meta()?;
    // Fail-closed cross-check for the laundered-set compaction: the
    // lineage records how many ids were folded into the IdMap's retired
    // set; an IdMap carrying fewer (a lost/rolled-back ids.map.retired
    // sidecar) would silently resurrect erased data in every rebuild.
    anyhow::ensure!(
        lineage_retired <= idmap.retired_len() as u64,
        "lineage records {lineage_retired} retired id(s) but the IdMap \
         carries only {} — ids.map.retired is missing or stale; \
         refusing to serve (erased data would reenter replays)",
        idmap.retired_len()
    );
    let laundered: HashSet<u64> = laundered_residue.into_iter().collect();
    let forgotten: HashSet<u64> = crate::checkpoint::read_ids_json(
        &cfg.run_dir.join("forgotten.json"),
    )?
    .into_iter()
    .collect();
    // un-laundered forgotten influence means the trained/loaded state
    // is NOT the serving state: rebuild it so the stream-exactness
    // invariant survives a restart.  The rebuild TARGET comes from the
    // forgotten set alone (active-lineage checkpoints are already clean
    // w.r.t. `laundered` — reaching back past laundered influence would
    // re-pay the tail laundering eliminated); the FILTER is the union.
    let (state, diverged) = if forgotten.is_empty() {
        (out.state, false)
    } else {
        let off = crate::replay::offending_steps(&records, &idmap, &forgotten)?;
        let target = match off.first() {
            Some(&t) => t,
            None => records
                .iter()
                .map(|r| r.opt_step)
                .max()
                .map(|s| s.saturating_add(1))
                .unwrap_or(0),
        };
        let mut filter = forgotten.clone();
        filter.extend(laundered.iter().copied());
        // IDs a past compaction retired into the IdMap are masked by
        // the traversal itself; the filter only needs the residue.
        let (_, rebuilt) = crate::replay::replay_filter_from_nearest_to(
            rt,
            &corpus,
            &store,
            &records,
            &idmap,
            &filter,
            target,
            Some(&pins),
            &crate::replay::ReplayOptions {
                shard_pin: cfg.shard_pin.clone(),
                ..crate::replay::ReplayOptions::default()
            },
        )?;
        (rebuilt.state, true)
    };
    let corpus_len = corpus.len();
    let system = UnlearnSystem {
        rt,
        cfg,
        corpus,
        state,
        // the validated handle is cached on the system from here on —
        // store() no longer re-runs open's sweep per call
        store,
        ring: out.ring,
        adapters: AdapterRegistry::new(),
        fisher,
        manifest,
        records,
        idmap,
        pins,
        ndindex,
        retain_ids,
        eval_ids,
        thresholds: AuditThresholds::default(),
        baseline_ppl: None,
        closure_params: ClosureParams::default(),
        hot_path: HotPathParams::default(),
        resume_after_revert: true,
        audit_seed: 0xAD17,
        forgotten,
        laundered,
        diverged,
        // covered_len starts at the corpus the caller handed us; a
        // reopen through `ingest::reopen` re-derives it from the
        // interleave log (the corpus there includes committed ingest
        // docs the latest increment may not have covered yet)
        ingest: IngestStatus {
            ingested_docs: 0,
            covered_len: corpus_len,
            in_flight: false,
        },
    };
    Ok(TrainedSystem {
        system,
        train_output_losses: losses,
    })
}

/// Checkpoint store of a run dir.
pub fn store_of(run_dir: &Path, keep: usize) -> anyhow::Result<CheckpointStore> {
    CheckpointStore::open(&run_dir.join("ckpt"), keep)
}

/// IDs whose *first* WAL occurrence is at or after `step` — candidates
/// for the controlled G1 experiment (forget influence strictly after
/// the checkpoint).
pub fn ids_first_seen_at_or_after(
    records: &[crate::wal::WalRecord],
    idmap: &crate::wal::IdMap,
    step: u32,
) -> Vec<u64> {
    use std::collections::HashMap;
    let mut first: HashMap<u64, u32> = HashMap::new();
    for rec in records {
        if let Some(ids) = idmap.lookup(rec.hash64) {
            for &id in ids {
                first.entry(id).or_insert(rec.opt_step);
            }
        }
    }
    let mut out: Vec<u64> = first
        .into_iter()
        .filter(|&(_, s)| s >= step)
        .map(|(id, _)| id)
        .collect();
    out.sort_unstable();
    out
}
