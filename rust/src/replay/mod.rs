//! `ReplayFilter` — deterministic microbatch replay with forget
//! filtering (paper Alg. A.9, Theorem A.1).
//!
//! Reconstructs the logical microbatch graph G from the WAL + IdMap,
//! removes only samples in cl(F) (mask-based, shape-preserving —
//! Lemma A.2(ii)), sets the optimizer LR from the recorded `lr_f32`
//! before each applied update (never calls the scheduler — Lemma A.4),
//! skips counter advances on steps that become empty (Prop. A.5), and
//! asserts the logged `opt_step_u32` against the traversal (fail-closed
//! on any inconsistency).
//!
//! Within each accumulation segment the independent microbatches are
//! dispatched through [`Runtime::grad_accumulate`] — one batched call
//! the backend may parallelize across a scoped thread pool — and
//! combined via the pinned reduce (the logged sequential order), so
//! segment-parallel replay is bit-identical to the sequential
//! traversal (`ReplayOptions::sequential` keeps the old path for the
//! regression proof and the bench A/B).
//!
//! The same entry point with `from` = the θ0 checkpoint and the same
//! closure IS the preserved-graph retain-only oracle RETAINTRAIN
//! (Def. A.12 / Lemma A.14) — oracle and replay literally share this
//! code path plus the pinned executables, which is how the paper's
//! bit-identity argument becomes mechanically checkable here.

use std::collections::HashSet;
use std::path::Path;

use crate::checkpoint::{CheckpointStore, TrainState};
use crate::config::Pins;
use crate::data::corpus::Corpus;
use crate::runtime::{Runtime, StepOut};
use crate::trainer::{accumulate, SegmentStage};
use crate::wal::{IdMap, WalReader, WalRecord};

/// Replay options.
#[derive(Debug, Clone)]
pub struct ReplayOptions {
    /// Scrub the token content of filtered slots (exactness unaffected —
    /// bitwise content-independence; privacy-preferable since forget
    /// data never enters the compute graph).
    pub zero_content: bool,
    /// Verify pins before running (fail-closed).  Disable only in tests.
    pub check_pins: bool,
    /// Force the pre-redesign traversal: one `train_step` call per
    /// microbatch, accumulated sequentially.  The default (`false`)
    /// dispatches each accumulation segment through
    /// [`Runtime::grad_accumulate`], whose pinned reduce makes the
    /// (possibly parallel) result bit-identical to this path — the
    /// equality regression test and `bench_replay`'s A/B both flip
    /// this flag to prove/measure exactly that.
    pub sequential: bool,
    /// The fleet topology pin the caller's environment presents ("" =
    /// unsharded).  The runtime itself is topology-blind, so the
    /// captured pins get this value before comparison against the
    /// stored training-time pins — a shard's WAL replayed under a
    /// different topology (or an unsharded reopen of a sharded run)
    /// fails closed.  Use [`crate::controller::UnlearnSystem::
    /// replay_options`] to inherit the system's configured pin.
    pub shard_pin: String,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        ReplayOptions {
            zero_content: true,
            check_pins: true,
            sequential: false,
            shard_pin: String::new(),
        }
    }
}

/// Traversal invariants recorded for the equality-proof artifact
/// (the "Replay invariants" row of Table 5).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplayInvariants {
    /// Updates actually applied (bias-correction counter advances).
    pub applied_steps: u32,
    /// Logical steps whose microbatches were all empty after filtering.
    pub empty_logical_steps: u32,
    /// Inclusive logical-step range traversed [first, last].
    pub logical_range: Option<(u32, u32)>,
    /// Microbatch records consumed.
    pub records: u64,
    /// Microbatch executions skipped because every slot was filtered.
    pub skipped_microbatches: u64,
}

/// Result of a replay.
pub struct ReplayOutcome {
    pub state: TrainState,
    pub invariants: ReplayInvariants,
}

/// Run `ReplayFilter` from checkpoint `from`, filtering `closure`.
///
/// `records` must be the full WAL stream of the original run (records
/// before `from.logical_step` are skipped — they are already inside the
/// checkpoint).  `stored_pins` is the training-time pin snapshot.
pub fn replay_filter(
    rt: &Runtime,
    corpus: &Corpus,
    from: &TrainState,
    records: &[WalRecord],
    idmap: &IdMap,
    closure: &HashSet<u64>,
    stored_pins: Option<&Pins>,
    opts: &ReplayOptions,
) -> anyhow::Result<ReplayOutcome> {
    replay_filter_with_snapshots(
        rt,
        corpus,
        from,
        records,
        idmap,
        closure,
        stored_pins,
        opts,
        &[],
        |_| Ok(()),
    )
}

/// [`replay_filter`] that additionally hands intermediate states to
/// `sink` at the requested logical-step boundaries — the checkpoint
/// *laundering* primitive: one filtered tail traversal both rebuilds
/// the serving state AND emits the retain-only checkpoint sequence the
/// new lineage stores, with no second replay.
///
/// `snapshot_steps` must be sorted, deduplicated accumulation-boundary
/// steps of the traversal (original checkpoints are saved exactly at
/// such boundaries).  Steps at or before `from.logical_step` are
/// ignored (they precede the traversal — adopt those checkpoints
/// instead of re-deriving them); a step the traversal cannot land on
/// exactly fails closed rather than snapshotting a nearby state.
#[allow(clippy::too_many_arguments)]
pub fn replay_filter_with_snapshots(
    rt: &Runtime,
    corpus: &Corpus,
    from: &TrainState,
    records: &[WalRecord],
    idmap: &IdMap,
    closure: &HashSet<u64>,
    stored_pins: Option<&Pins>,
    opts: &ReplayOptions,
    snapshot_steps: &[u32],
    mut sink: impl FnMut(&TrainState) -> anyhow::Result<()>,
) -> anyhow::Result<ReplayOutcome> {
    let mut snap_i = snapshot_steps
        .partition_point(|&s| s <= from.logical_step);
    // fail-closed pin verification (Table 2 / §7)
    if opts.check_pins {
        let stored = stored_pins
            .ok_or_else(|| anyhow::anyhow!("pins required (fail-closed)"))?;
        let accum = infer_accum(records)?;
        let mut current = rt.capture_pins(accum);
        // the runtime is topology-blind: the caller's configured fleet
        // pin IS the current environment's topology claim
        current.shard = opts.shard_pin.clone();
        stored.ensure_match(&current)?;
    }

    let man = &rt.manifest;
    anyhow::ensure!(
        from.params.len() == man.param_count,
        "checkpoint param count mismatch"
    );
    let mut state = from.clone();
    let mut inv = ReplayInvariants::default();

    // The current accumulation segment, staged record by record
    // (trainer-shared `SegmentStage` — one buffer set for the whole
    // tail traversal) and executed as ONE batched `grad_accumulate`
    // call at `accum_end`.  Legal because every microbatch of a
    // segment sees the same pre-update params; bit-exact because the
    // backend's combine is the pinned reduce (the logged sequential
    // order).
    let mut seg = SegmentStage::new();
    let mut pending_lr: Option<f32> = None;
    let mut last_step: Option<u32> = None;

    for rec in records {
        if rec.opt_step < state.logical_step {
            continue; // already inside the checkpoint
        }
        // WAL traversal order sanity (Alg. A.9 "in order")
        if let Some(prev) = last_step {
            anyhow::ensure!(
                rec.opt_step >= prev,
                "WAL records out of order at step {}",
                rec.opt_step
            );
        }
        last_step = Some(rec.opt_step);
        inv.records += 1;
        inv.logical_range = Some(match inv.logical_range {
            None => (rec.opt_step, rec.opt_step),
            Some((a, _)) => (a, rec.opt_step),
        });

        // line 5: recover ordered IDs from M; assert |B| = mb_len
        let ids = idmap.lookup(rec.hash64).ok_or_else(|| {
            anyhow::anyhow!(
                "IdMap missing hash {:016x} — cannot reconstruct \
                 microbatch (fail-closed)",
                rec.hash64
            )
        })?;
        anyhow::ensure!(
            ids.len() == rec.mb_len as usize,
            "mb_len mismatch for hash {:016x}: WAL {} vs IdMap {}",
            rec.hash64,
            rec.mb_len,
            ids.len()
        );

        // Filter = the caller's closure ∪ the IdMap's retired set.
        // Retired ids are closure members a past laundering pass folded
        // into the rewritten manifest M (laundered-set compaction): the
        // WAL records still reference them, but every traversal must
        // mask them forever — enforcing that here means the in-memory
        // laundered set can stay empty instead of growing with service
        // lifetime.
        let retained = seg.stage(
            corpus,
            ids,
            man.batch,
            man.seq_len,
            |id| closure.contains(&id) || idmap.is_retired(id),
            opts.zero_content,
            rec.seed64 as i32,
        )?;
        if retained == 0 {
            inv.skipped_microbatches += 1;
        }
        pending_lr = Some(rec.lr());

        if rec.accum_end {
            // lines 7-8 + 12-14: g with the SAME seeds (reduction=sum,
            // pinned combine order), then LR from the WAL, never a
            // scheduler; the opt_step assertion from §4.1 (original
            // training had no empty steps, so applied == logical there;
            // replay's applied counter is the retain-only program's)
            match run_segment(rt, &state.params, &seg, opts)? {
                Some(out) => {
                    let lr = pending_lr.expect("accum boundary saw records");
                    let (p, m, v) = rt.adamw_update(
                        &state.params,
                        &out.grad,
                        &state.m,
                        &state.v,
                        state.applied_updates as i32 + 1,
                        lr,
                    )?;
                    state.params = p;
                    state.m = m;
                    state.v = v;
                    state.applied_updates += 1;
                    inv.applied_steps += 1;
                }
                None => {
                    // Prop. A.5: empty-step skip — no counter advance
                    inv.empty_logical_steps += 1;
                }
            }
            state.logical_step = rec.opt_step + 1;
            seg.reset();
            pending_lr = None;
            while snap_i < snapshot_steps.len()
                && snapshot_steps[snap_i] <= state.logical_step
            {
                anyhow::ensure!(
                    snapshot_steps[snap_i] == state.logical_step,
                    "snapshot step {} is not an accumulation boundary of \
                     this traversal (at boundary {}) — refusing an \
                     inexact snapshot",
                    snapshot_steps[snap_i],
                    state.logical_step
                );
                sink(&state)?;
                snap_i += 1;
            }
        }
    }
    anyhow::ensure!(
        pending_lr.is_none(),
        "WAL ended mid-accumulation (unterminated segment)"
    );
    anyhow::ensure!(
        snap_i == snapshot_steps.len(),
        "snapshot steps beyond the WAL end: {:?}",
        &snapshot_steps[snap_i..]
    );
    Ok(ReplayOutcome {
        state,
        invariants: inv,
    })
}

/// Execute the retained microbatches of one staged accumulation
/// segment; `None` when every slot was filtered (the Prop. A.5
/// empty-step input).  Default path: ONE [`Runtime::grad_accumulate`]
/// call — the backend may dispatch the independent microbatches across
/// a thread pool; the pinned reduce keeps the result bit-identical to
/// `opts.sequential`, which preserves the pre-redesign per-microbatch
/// traversal (deliberately an INDEPENDENT fold, not a call into
/// `reduce_pinned` — it is the oracle the equality regression test and
/// the bench A/B compare the batched path against).
fn run_segment(
    rt: &Runtime,
    params: &[f32],
    seg: &SegmentStage,
    opts: &ReplayOptions,
) -> anyhow::Result<Option<StepOut>> {
    let inputs = seg.inputs();
    if inputs.is_empty() {
        return Ok(None);
    }
    if opts.sequential {
        let mut grad = vec![0.0f32; rt.manifest.param_count];
        let mut loss_sum = 0.0f32;
        let mut tok_count = 0.0f32;
        for mb in &inputs {
            let out = rt.train_step(params, mb.tokens, mb.mask, mb.seed)?;
            accumulate(&mut grad, &out.grad);
            loss_sum += out.loss_sum;
            tok_count += out.tok_count;
        }
        return Ok(Some(StepOut {
            grad,
            loss_sum,
            tok_count,
        }));
    }
    Ok(Some(rt.grad_accumulate(params, &inputs)?))
}

/// Nearest-checkpoint tail replay (Alg. A.7 line 14, now owned by the
/// replay layer): given the forget closure, pick the **latest** stored
/// full checkpoint at or before the earliest affected logical step and
/// replay only that tail.  Exact by Theorem A.1: every update before
/// the chosen checkpoint is untouched by cl(F), so the state at C_k is
/// already the retain-only state — the bit-identity regression test in
/// `tests/replay_equality.rs` checks the tail result against a full
/// from-θ0 replay.
///
/// With an empty closure this degenerates to "latest checkpoint, replay
/// the remaining tail" (the cheapest state reconstruction).
///
/// Returns the chosen checkpoint step alongside the outcome.
#[allow(clippy::too_many_arguments)]
pub fn replay_filter_nearest(
    rt: &Runtime,
    corpus: &Corpus,
    store: &CheckpointStore,
    records: &[WalRecord],
    idmap: &IdMap,
    closure: &HashSet<u64>,
    stored_pins: Option<&Pins>,
    opts: &ReplayOptions,
) -> anyhow::Result<(u32, ReplayOutcome)> {
    let offending = offending_steps(records, idmap, closure)?;
    // first step whose microbatches intersect cl(F); past the WAL end
    // when nothing is affected (replay nothing beyond the last ckpt)
    let target = match offending.first() {
        Some(&t) => t,
        None => records
            .iter()
            .map(|r| r.opt_step)
            .max()
            .map(|s| s.saturating_add(1))
            .unwrap_or(0),
    };
    replay_filter_from_nearest_to(
        rt, corpus, store, records, idmap, closure, target, stored_pins, opts,
    )
}

/// The tail-replay half of [`replay_filter_nearest`] for callers that
/// already know the earliest affected step (the controller computes the
/// offending set for routing anyway — no second WAL scan).  `target` is
/// the first logical step the closure influences.
#[allow(clippy::too_many_arguments)]
pub fn replay_filter_from_nearest_to(
    rt: &Runtime,
    corpus: &Corpus,
    store: &CheckpointStore,
    records: &[WalRecord],
    idmap: &IdMap,
    closure: &HashSet<u64>,
    target: u32,
    stored_pins: Option<&Pins>,
    opts: &ReplayOptions,
) -> anyhow::Result<(u32, ReplayOutcome)> {
    let k = store.nearest_at_or_before(target)?.ok_or_else(|| {
        anyhow::anyhow!(
            "no checkpoint at or before step {target} — cannot satisfy \
             the exactness precondition (fail-closed)"
        )
    })?;
    let ck = store.load_full(k)?;
    let outcome = replay_filter(
        rt, corpus, &ck, records, idmap, closure, stored_pins, opts,
    )?;
    Ok((k, outcome))
}

/// Infer the accumulation length from the WAL (layout pin component).
pub fn infer_accum(records: &[WalRecord]) -> anyhow::Result<usize> {
    let mut count = 0usize;
    for rec in records {
        count += 1;
        if rec.accum_end {
            return Ok(count);
        }
    }
    anyhow::bail!("WAL contains no accumulation boundary");
}

/// Load the WAL + IdMap + pins for a finished run directory.
pub fn load_run(
    run_dir: &Path,
    hmac_key: Option<Vec<u8>>,
) -> anyhow::Result<(Vec<WalRecord>, IdMap, Pins)> {
    let records = WalReader::open(&run_dir.join("wal"))?
        .collect::<anyhow::Result<Vec<_>>>()?;
    let idmap = IdMap::load(&run_dir.join("ids.map"), hmac_key)?;
    let pins = Pins::load(&run_dir.join("pins.json"))?;
    Ok((records, idmap, pins))
}

/// WAL records at or after `from` — the length of the tail a replay
/// starting there must traverse (the planner's replay-cost input).
pub fn tail_len(records: &[WalRecord], from: u32) -> u64 {
    records.iter().filter(|r| r.opt_step >= from).count() as u64
}

/// Identify the logical steps whose microbatches intersect cl(F)
/// (Alg. A.7 line 6: the offending-step set T).
pub fn offending_steps(
    records: &[WalRecord],
    idmap: &IdMap,
    closure: &HashSet<u64>,
) -> anyhow::Result<Vec<u32>> {
    let mut steps = Vec::new();
    for rec in records {
        let ids = idmap
            .lookup(rec.hash64)
            .ok_or_else(|| anyhow::anyhow!("IdMap missing {:016x}", rec.hash64))?;
        if ids.iter().any(|id| closure.contains(id)) {
            steps.push(rec.opt_step);
        }
    }
    steps.sort_unstable();
    steps.dedup();
    Ok(steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u32, end: bool) -> WalRecord {
        WalRecord {
            hash64: 0,
            seed64: 0,
            lr_bits: 0,
            opt_step: step,
            accum_end: end,
            mb_len: 1,
        }
    }

    #[test]
    fn infer_accum_from_stream() {
        let recs = vec![rec(0, false), rec(0, false), rec(0, true)];
        assert_eq!(infer_accum(&recs).unwrap(), 3);
        assert!(infer_accum(&[rec(0, false)]).is_err());
    }

    #[test]
    fn offending_steps_finds_intersections() {
        let mut idmap = IdMap::new(None);
        let h1 = idmap.register(&[1, 2]);
        let h2 = idmap.register(&[3, 4]);
        let recs = vec![
            WalRecord { hash64: h1, seed64: 0, lr_bits: 0, opt_step: 0,
                        accum_end: true, mb_len: 2 },
            WalRecord { hash64: h2, seed64: 0, lr_bits: 0, opt_step: 1,
                        accum_end: true, mb_len: 2 },
            WalRecord { hash64: h1, seed64: 0, lr_bits: 0, opt_step: 2,
                        accum_end: true, mb_len: 2 },
        ];
        let closure: HashSet<u64> = [2u64].into_iter().collect();
        assert_eq!(offending_steps(&recs, &idmap, &closure).unwrap(),
                   vec![0, 2]);
        let none: HashSet<u64> = [99u64].into_iter().collect();
        assert!(offending_steps(&recs, &idmap, &none).unwrap().is_empty());
    }
}
