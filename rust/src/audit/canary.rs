//! Canary exposure (Carlini et al. 2019, "The Secret Sharer").
//!
//! For each canary `the secret code of user U is DDDDDD`, we score the
//! true secret against `N` random alternative secrets under the model
//! and compute
//!
//! ```text
//! exposure = log2(N + 1) − log2(rank of the true secret)
//! ```
//!
//! High exposure (≫ 0) = the model memorized the secret; after
//! unlearning the true secret should rank like a random candidate,
//! giving exposure ≈ log2(N+1) − log2(E[rank]) ≈ small / negative mean.

use crate::data::corpus::SampleKind;
use crate::util::rng::SplitMix64;

use super::{per_text_losses, AuditContext, ModelView};

/// Number of alternative candidate secrets per canary.
pub const CANDIDATES: usize = 63;

/// Mean/σ exposure in bits over all canaries in the forget closure
/// (falls back to all corpus canaries when the closure carries none).
pub fn exposure(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<(f64, f64)> {
    let mut rng = SplitMix64::new(ctx.seed ^ 0xCA9A);
    let mut exposures = Vec::new();
    let forget: std::collections::HashSet<u64> =
        ctx.forget_ids.iter().copied().collect();
    let mut canaries: Vec<_> = ctx
        .corpus
        .canaries()
        .into_iter()
        .filter(|s| forget.contains(&s.id))
        .collect();
    if canaries.is_empty() {
        canaries = ctx.corpus.canaries();
    }
    for sample in canaries {
        let SampleKind::Canary { secret } = &sample.kind else {
            continue;
        };
        // build the candidate set: true secret + CANDIDATES random ones
        let mut texts = vec![sample.text.clone()];
        for _ in 0..CANDIDATES {
            let alt = format!("{:06}", rng.below(1_000_000));
            texts.push(sample.text.replace(secret.as_str(), &alt));
        }
        let losses = per_text_losses(ctx.rt, view, &texts)?;
        let true_loss = losses[0];
        let rank = 1 + losses[1..].iter().filter(|&&l| l < true_loss).count();
        let n = (CANDIDATES + 1) as f64;
        exposures.push(n.log2() - (rank as f64).log2());
    }
    if exposures.is_empty() {
        return Ok((0.0, 0.0));
    }
    // detlint: allow(float-reduce) — sequential slice sum in push order
    // (deterministic); exposure stats, not replayed state
    let mu = exposures.iter().sum::<f64>() / exposures.len() as f64;
    let var = exposures
        .iter()
        .map(|e| (e - mu) * (e - mu))
        // detlint: allow(float-reduce) — sequential slice sum in push order
        // (deterministic); exposure stats, not replayed state
        .sum::<f64>()
        / exposures.len() as f64;
    Ok((mu, var.sqrt()))
}

#[cfg(test)]
mod tests {
    /// Exposure formula sanity (rank extremes).
    #[test]
    fn exposure_formula() {
        let n = (super::CANDIDATES + 1) as f64;
        let best = n.log2() - 1f64.log2(); // rank 1
        let worst = n.log2() - n.log2(); // rank N
        assert!((best - 6.0).abs() < 1e-9); // 64 candidates -> 6 bits
        assert_eq!(worst, 0.0);
    }
}
