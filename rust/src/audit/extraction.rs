//! Targeted extraction probes (Carlini et al. 2021).
//!
//! For each canary in the forget closure, prompt the model with the text
//! up to (and including) the secret's prefix — e.g. `the secret code of
//! user 0003 is ` — and greedy-decode as many tokens as the secret has.
//! Success = decoded string contains the secret.  After unlearning the
//! success rate must be ≤ p* (near 0%).

use crate::data::corpus::SampleKind;
use crate::data::tokenizer::ByteTokenizer;

use super::{AuditContext, ModelView};

/// Greedy-decode `n_new` tokens after each prompt (batched).
pub fn greedy_decode(
    rt: &crate::runtime::Runtime,
    view: ModelView<'_>,
    prompts: &[String],
    n_new: usize,
) -> anyhow::Result<Vec<String>> {
    let be = rt.manifest.eval_batch;
    let s = rt.manifest.seq_len;
    let v = rt.manifest.vocab;
    let tok = ByteTokenizer;
    let mut outputs = vec![String::new(); prompts.len()];
    for (chunk_idx, chunk) in prompts.chunks(be).enumerate() {
        let mut tokens = vec![0i32; be * s];
        let mut lens = vec![1i32; be];
        for (slot, p) in chunk.iter().enumerate() {
            let enc = tok.encode(p);
            let l = enc.len().min(s);
            tokens[slot * s..slot * s + l].copy_from_slice(&enc[..l]);
            lens[slot] = l as i32;
        }
        for _ in 0..n_new {
            let logits = view.next_logits(rt, &tokens, &lens)?;
            for slot in 0..chunk.len() {
                let li = &logits[slot * v..(slot + 1) * v];
                let argmax = li
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0);
                let l = lens[slot] as usize;
                if l < s {
                    tokens[slot * s + l] = argmax;
                    lens[slot] += 1;
                }
                // byte-level vocab: token id == byte value
                outputs[chunk_idx * be + slot].push(argmax as u8 as char);
            }
        }
    }
    Ok(outputs)
}

/// Extraction success rate over the closure's canaries (fallback: all).
pub fn extraction_rate(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<f64> {
    let forget: std::collections::HashSet<u64> =
        ctx.forget_ids.iter().copied().collect();
    let mut canaries: Vec<_> = ctx
        .corpus
        .canaries()
        .into_iter()
        .filter(|s| forget.contains(&s.id))
        .collect();
    if canaries.is_empty() {
        canaries = ctx.corpus.canaries();
    }
    let mut prompts = Vec::new();
    let mut secrets = Vec::new();
    for sample in &canaries {
        let SampleKind::Canary { secret } = &sample.kind else {
            continue;
        };
        if let Some(pos) = sample.text.find(secret.as_str()) {
            prompts.push(sample.text[..pos].to_string());
            secrets.push(secret.clone());
        }
    }
    if prompts.is_empty() {
        return Ok(0.0);
    }
    let decoded = greedy_decode(ctx.rt, view, &prompts, 6)?;
    let hits = decoded
        .iter()
        .zip(&secrets)
        .filter(|(d, s)| d.contains(s.as_str()))
        .count();
    Ok(hits as f64 / secrets.len() as f64)
}

#[cfg(test)]
mod tests {
    /// The prompt construction slices exactly before the secret.
    #[test]
    fn prompt_prefix_construction() {
        let text = "the secret code of user 0001 is 918273.";
        let secret = "918273";
        let pos = text.find(secret).unwrap();
        assert_eq!(&text[..pos], "the secret code of user 0001 is ");
    }
}
