//! Membership inference (Shokri et al.): loss-thresholding attack.
//!
//! Score = negative per-example loss (members of training tend to have
//! lower loss).  AUC over forget-set vs matched retain *non-member*
//! controls... in the unlearning setting the controls are the forget
//! examples' peers: after successful unlearning the forget set should
//! look like NON-members, so AUC(forget vs held-out) ≈ 0.5.  We report
//! AUC of "forget looks more member-like than held-out" — near 0.5 is
//! the acceptance target, >0.55 indicates residual leakage.
//!
//! The 95% CI is a seeded bootstrap over score pairs (the CI the paper
//! cites in §6.3).

use crate::util::rng::SplitMix64;

use super::{per_example_losses, AuditContext, ModelView, SharedEvals};

/// MIA result.
#[derive(Debug, Clone)]
pub struct MiaResult {
    pub auc: f64,
    pub ci95: (f64, f64),
    pub n_forget: usize,
    pub n_control: usize,
}

/// Mann-Whitney AUC: P(score_member > score_control) + 0.5 P(=).
pub fn auc(member_scores: &[f64], control_scores: &[f64]) -> f64 {
    if member_scores.is_empty() || control_scores.is_empty() {
        return 0.5;
    }
    let mut wins = 0.0f64;
    for &m in member_scores {
        for &c in control_scores {
            if m > c {
                wins += 1.0;
            } else if m == c {
                wins += 0.5;
            }
        }
    }
    wins / (member_scores.len() as f64 * control_scores.len() as f64)
}

/// Seeded bootstrap 95% CI for the AUC.
pub fn bootstrap_ci(
    member: &[f64],
    control: &[f64],
    iters: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = SplitMix64::new(seed);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let ms: Vec<f64> = (0..member.len())
            .map(|_| member[rng.below(member.len() as u64) as usize])
            .collect();
        let cs: Vec<f64> = (0..control.len())
            .map(|_| control[rng.below(control.len() as u64) as usize])
            .collect();
        samples.push(auc(&ms, &cs));
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = samples[(iters as f64 * 0.025) as usize];
    let hi = samples[((iters as f64 * 0.975) as usize).min(iters - 1)];
    (lo, hi)
}

/// Run the attack: forget-set losses vs control losses under `view`.
pub fn mia_auc(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<MiaResult> {
    mia_auc_with(ctx, view, None)
}

/// [`mia_auc`] reusing batch-shared precomputations: the control
/// losses (state-dependent only, evaluated once per batch) and — when
/// the coalescer batched them — the per-request forget-probe losses
/// (`SharedEvals::forget_losses`, one `eval_batch` call over the whole
/// batch's closure union).  Both must come from the same `view`;
/// results are bit-identical to the unshared path because every
/// per-example loss is a pure function of (state, sample).  A shared
/// map missing any probe id falls back to the inline evaluation — the
/// precompute is an optimization, never a correctness dependency.
pub fn mia_auc_with(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
    shared: Option<&SharedEvals>,
) -> anyhow::Result<MiaResult> {
    let precomputed: Option<Vec<f32>> = shared
        .and_then(|s| s.forget_losses.as_ref())
        .and_then(|map| {
            ctx.forget_ids
                .iter()
                .map(|id| map.get(id).copied())
                .collect()
        });
    let forget_losses = match precomputed {
        Some(l) => l,
        None => per_example_losses(ctx.rt, view, ctx.corpus, ctx.forget_ids)?,
    };
    let control_losses = match shared {
        Some(s) => s.control_losses.clone(),
        None => per_example_losses(ctx.rt, view, ctx.corpus, ctx.retain_ids)?,
    };
    // member-likeness score = -loss
    let member: Vec<f64> = forget_losses.iter().map(|&l| -(l as f64)).collect();
    let control: Vec<f64> =
        control_losses.iter().map(|&l| -(l as f64)).collect();
    let a = auc(&member, &control);
    let ci = bootstrap_ci(&member, &control, 200, ctx.seed ^ 0x41A);
    Ok(MiaResult {
        auc: a,
        ci95: ci,
        n_forget: member.len(),
        n_control: control.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_separable() {
        let members = vec![3.0, 4.0, 5.0];
        let controls = vec![0.0, 1.0, 2.0];
        assert_eq!(auc(&members, &controls), 1.0);
        assert_eq!(auc(&controls, &members), 0.0);
    }

    #[test]
    fn auc_identical_distributions_is_half() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(auc(&a, &a), 0.5);
        assert_eq!(auc(&[], &a), 0.5);
    }

    #[test]
    fn bootstrap_ci_brackets_auc_and_is_deterministic() {
        let mut rng = SplitMix64::new(1);
        let member: Vec<f64> = (0..50).map(|_| rng.normal() + 0.3).collect();
        let control: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let a = auc(&member, &control);
        let (lo, hi) = bootstrap_ci(&member, &control, 200, 7);
        assert!(lo <= a && a <= hi, "{lo} <= {a} <= {hi}");
        assert_eq!(bootstrap_ci(&member, &control, 200, 7), (lo, hi));
        assert!(hi - lo < 0.35);
    }
}
