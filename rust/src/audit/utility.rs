//! Utility audit: retain-set perplexity (paper §4.3 test v; the
//! "Retain PPL" column of Table 6).  Must stay within ±X% of baseline.

use super::{per_example_loss_counts, AuditContext, ModelView};

/// exp(mean loss per token) over the utility eval IDs.
pub fn retain_ppl(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<f64> {
    ppl_over(ctx, view, ctx.eval_ids)
}

/// PPL over an arbitrary ID list: exp(Σ loss / Σ non-PAD tokens).
pub fn ppl_over(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
    ids: &[u64],
) -> anyhow::Result<f64> {
    anyhow::ensure!(!ids.is_empty(), "empty eval set");
    let lc = per_example_loss_counts(ctx.rt, view, ctx.corpus, ids)?;
    let total: f64 = lc.iter().map(|&(l, _)| l as f64).sum();
    let count: f64 = lc.iter().map(|&(_, c)| c as f64).sum();
    anyhow::ensure!(count > 0.0, "no tokens in eval set");
    Ok((total / count).exp())
}
