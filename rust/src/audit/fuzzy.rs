//! Fuzzy span recall (paper §4.3 test iv): does the model still assign
//! suspiciously high likelihood to *near-duplicate / paraphrase*
//! variants of forgotten spans?
//!
//! For each forget sample we generate paraphrase variants (the same
//! perturbation family the corpus near-dup generator uses) and compare
//! their per-token loss against kind-matched control variants as an
//! AUC ("forget variant looks more memorized than control variant").
//! 0.5 = chance; after exact unlearning the score should sit near 0.5
//! and below the configured ceiling.

use crate::util::rng::SplitMix64;

use super::{per_text_losses, AuditContext, ModelView};

/// Paraphrase variants of a text (mirrors corpus near-dup families).
pub fn variants(text: &str, rng: &mut SplitMix64) -> Vec<String> {
    let mut out = vec![
        text.replace(" on day ", " around day "),
        format!("{} indeed.", text.trim_end_matches('.')),
        text.replace("(user", "( user"),
    ];
    // word-drop variant
    let words: Vec<&str> = text.split_whitespace().collect();
    if words.len() > 3 {
        let drop = rng.below(words.len() as u64) as usize;
        let kept: Vec<&str> = words
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != drop)
            .map(|(_, w)| *w)
            .collect();
        out.push(kept.join(" "));
    }
    out.retain(|v| v != text);
    out
}

/// Fuzzy recall rate over the forget closure.
///
/// Calibration matters: canary templates are structurally unlike normal
/// docs, so each forget variant is compared only against control
/// variants of the SAME sample kind (canary vs canary, normal vs
/// normal).  Within a kind, "recall" = variant loss below the 10th
/// percentile of that kind's control variants — chance level ≈ 10%.
pub fn fuzzy_recall(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<f64> {
    use std::mem::{discriminant, Discriminant};
    type Kind = Discriminant<crate::data::corpus::SampleKind>;

    let mut rng = SplitMix64::new(ctx.seed ^ 0xF022);
    let take = ctx.forget_ids.len().min(16);
    let mut var_texts: Vec<(Kind, String)> = Vec::new();
    for &id in ctx.forget_ids.iter().take(take) {
        let s = ctx
            .corpus
            .by_id(id)
            .ok_or_else(|| anyhow::anyhow!("unknown sample {id}"))?;
        for v in variants(&s.text, &mut rng) {
            var_texts.push((discriminant(&s.kind), v));
        }
    }
    if var_texts.is_empty() {
        return Ok(0.0);
    }
    // kind-matched control variants from the retain pool
    let mut ctrl_texts: Vec<(Kind, String)> = Vec::new();
    for _ in 0..(take.max(4) * 3) {
        let idx = rng.below(ctx.retain_ids.len() as u64) as usize;
        let Some(s) = ctx.corpus.by_id(ctx.retain_ids[idx]) else {
            continue;
        };
        for v in variants(&s.text, &mut rng) {
            ctrl_texts.push((discriminant(&s.kind), v));
        }
    }
    let var_losses = per_text_losses(
        ctx.rt,
        view,
        &var_texts.iter().map(|(_, t)| t.clone()).collect::<Vec<_>>(),
    )?;
    let ctrl_losses = per_text_losses(
        ctx.rt,
        view,
        &ctrl_texts.iter().map(|(_, t)| t.clone()).collect::<Vec<_>>(),
    )?;
    // per-kind AUC of "forget variant scores lower loss than control
    // variant" — a calibrated recall signal: 0.5 = chance, 1.0 = the
    // model systematically prefers paraphrases of forgotten spans.
    let mut by_kind: std::collections::HashMap<Kind, Vec<f64>> =
        std::collections::HashMap::new();
    for ((k, _), &l) in ctrl_texts.iter().zip(&ctrl_losses) {
        by_kind.entry(*k).or_default().push(-(l as f64));
    }
    let mut weighted = 0.0f64;
    let mut weight = 0.0f64;
    let mut var_by_kind: std::collections::HashMap<Kind, Vec<f64>> =
        std::collections::HashMap::new();
    for ((k, _), &l) in var_texts.iter().zip(&var_losses) {
        var_by_kind.entry(*k).or_default().push(-(l as f64));
    }
    for (k, vars) in &var_by_kind {
        let Some(ctrls) = by_kind.get(k) else { continue };
        if ctrls.len() < 8 {
            continue; // too few matched controls to calibrate this kind
        }
        let auc = super::mia::auc(vars, ctrls);
        weighted += auc * vars.len() as f64;
        weight += vars.len() as f64;
    }
    if weight == 0.0 {
        return Ok(0.5); // uncalibratable -> report chance
    }
    Ok(weighted / weight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_differ_from_original() {
        let mut rng = SplitMix64::new(1);
        let t = "Alice (user 0001) wrote about gardening on day 042.";
        let vs = variants(t, &mut rng);
        assert!(vs.len() >= 3);
        for v in &vs {
            assert_ne!(v, t);
        }
    }

    #[test]
    fn variants_handle_short_text() {
        let mut rng = SplitMix64::new(2);
        let vs = variants("hi there.", &mut rng);
        assert!(!vs.iter().any(|v| v == "hi there."));
    }
}
