//! Audit harness (paper §4.3): four leakage tests + one utility test,
//! gating every unlearning path.
//!
//! - [`mia`]: membership-inference AUC on cl(F) vs matched controls,
//!   with a bootstrap 95% CI (Shokri et al.; the Table 6 "MIA AUC").
//! - [`canary`]: secret-sharer canary exposure in bits (Carlini'19).
//! - [`extraction`]: targeted-extraction probes via greedy decoding
//!   (Carlini'21).
//! - [`fuzzy`]: fuzzy span recall on near-dup/paraphrase variants.
//! - [`utility`]: retain-set perplexity within ±X% of baseline.

pub mod canary;
pub mod extraction;
pub mod fuzzy;
pub mod mia;
pub mod utility;

use crate::data::corpus::Corpus;
use crate::runtime::Runtime;
use crate::util::json::Json;

/// The model under audit: base weights or base+adapter (never merged).
#[derive(Clone, Copy)]
pub enum ModelView<'a> {
    Base(&'a [f32]),
    Adapter { base: &'a [f32], lora: &'a [f32] },
}

impl<'a> ModelView<'a> {
    pub fn eval_loss(
        &self,
        rt: &Runtime,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        match self {
            ModelView::Base(p) => rt.eval_loss(p, tokens),
            ModelView::Adapter { base, lora } => rt.lora_eval(base, lora, tokens),
        }
    }

    /// Batched eval over N concatenated eval chunks — one executor
    /// call; bit-identical to per-chunk [`ModelView::eval_loss`] (see
    /// `Executor::eval_batch`).
    pub fn eval_batch(
        &self,
        rt: &Runtime,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        match self {
            ModelView::Base(p) => rt.eval_batch(p, None, tokens),
            ModelView::Adapter { base, lora } => {
                rt.eval_batch(base, Some(lora), tokens)
            }
        }
    }

    pub fn next_logits(
        &self,
        rt: &Runtime,
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        match self {
            ModelView::Base(p) => rt.next_logits(p, tokens, lens),
            ModelView::Adapter { base, lora } => {
                rt.lora_next_logits(base, lora, tokens, lens)
            }
        }
    }
}

/// Upper bound on examples per `eval_batch` executor call: batching
/// wins come from amortizing the call overhead across dozens of
/// chunks, not from unbounded buffers — a closure-union probe over a
/// huge burst must not materialize memory proportional to its size.
/// (512 examples × seq_len 64 ≈ 128 KiB of i32 tokens per call.)
const MAX_EXAMPLES_PER_EVAL_CALL: usize = 512;

/// Per-example (sum-loss, non-PAD token count) over an ID list,
/// batched: ONE `eval_batch` executor call per
/// [`MAX_EXAMPLES_PER_EVAL_CALL`]-example super-chunk (slots beyond
/// the list stay PAD and are discarded; the token buffer is reused
/// across super-chunks).  Bit-identical to the per-chunk `eval_loss`
/// loop it replaced — per-slot losses are pure functions of their own
/// tokens, so chunk composition cannot move a bit.
pub fn per_example_loss_counts(
    rt: &Runtime,
    view: ModelView<'_>,
    corpus: &Corpus,
    ids: &[u64],
) -> anyhow::Result<Vec<(f32, f32)>> {
    let be = rt.manifest.eval_batch;
    let s = rt.manifest.seq_len;
    let mut out = Vec::with_capacity(ids.len());
    let mut tokens: Vec<i32> = Vec::new();
    for group in ids.chunks(MAX_EXAMPLES_PER_EVAL_CALL) {
        let chunks = group.len().div_ceil(be);
        tokens.clear();
        tokens.resize(chunks * be * s, 0);
        for (i, &id) in group.iter().enumerate() {
            let sample = corpus
                .by_id(id)
                .ok_or_else(|| anyhow::anyhow!("unknown sample {id}"))?;
            tokens[i * s..(i + 1) * s].copy_from_slice(&sample.tokens);
        }
        let (losses, counts) = view.eval_batch(rt, &tokens)?;
        for i in 0..group.len() {
            out.push((losses[i], counts[i]));
        }
    }
    Ok(out)
}

/// Per-example *per-token* loss (length-normalized — canaries are short,
/// so raw sums would confound membership with document length).
pub fn per_example_losses(
    rt: &Runtime,
    view: ModelView<'_>,
    corpus: &Corpus,
    ids: &[u64],
) -> anyhow::Result<Vec<f32>> {
    Ok(per_example_loss_counts(rt, view, corpus, ids)?
        .into_iter()
        .map(|(l, c)| l / c.max(1.0))
        .collect())
}

/// Per-text per-token loss for raw strings (canary variants etc.) —
/// batched through `eval_batch` in bounded super-chunks, like
/// [`per_example_loss_counts`].
pub fn per_text_losses(
    rt: &Runtime,
    view: ModelView<'_>,
    texts: &[String],
) -> anyhow::Result<Vec<f32>> {
    let be = rt.manifest.eval_batch;
    let s = rt.manifest.seq_len;
    let tok = crate::data::tokenizer::ByteTokenizer;
    let mut out = Vec::with_capacity(texts.len());
    let mut tokens: Vec<i32> = Vec::new();
    for group in texts.chunks(MAX_EXAMPLES_PER_EVAL_CALL) {
        let chunks = group.len().div_ceil(be);
        tokens.clear();
        tokens.resize(chunks * be * s, 0);
        for (i, text) in group.iter().enumerate() {
            tokens[i * s..(i + 1) * s]
                .copy_from_slice(&tok.encode_fixed(text, s));
        }
        let (losses, counts) = view.eval_batch(rt, &tokens)?;
        for i in 0..group.len() {
            out.push(losses[i] / counts[i].max(1.0));
        }
    }
    Ok(out)
}

/// Acceptance thresholds (E*, p*, X of §3.1; set on held-out validation).
#[derive(Debug, Clone)]
pub struct AuditThresholds {
    /// MIA AUC acceptance band around 0.5.
    pub mia_band: (f64, f64),
    /// Canary exposure ceiling E* (bits).
    pub exposure_max: f64,
    /// Targeted extraction ceiling p* (fraction).
    pub extraction_max: f64,
    /// Fuzzy-recall AUC ceiling (0.5 = chance).
    pub fuzzy_max: f64,
    /// Utility drift band ±X (relative).
    pub utility_drift: f64,
}

impl Default for AuditThresholds {
    fn default() -> Self {
        // Calibrated for the TOY regime (tens of forget samples): at
        // chance, canary exposure has mean ~1.4 bits (log2(64) - E[log2
        // rank]) and MIA/fuzzy AUCs over a handful of samples carry
        // +-0.15 noise.  Production deployments tighten these (the
        // paper's §6.3 toy run likewise fails its production band).
        AuditThresholds {
            mia_band: (0.35, 0.65),
            exposure_max: 3.0,
            extraction_max: 0.05,
            fuzzy_max: 0.75,
            utility_drift: 0.10,
        }
    }
}

/// The Table 6 report (one row).
#[derive(Debug, Clone)]
pub struct AuditReport {
    pub retain_ppl: f64,
    pub mia_auc: f64,
    pub mia_ci: (f64, f64),
    pub canary_mu_bits: f64,
    pub canary_sigma_bits: f64,
    pub extraction_rate: f64,
    pub fuzzy_recall: f64,
    pub gates: Vec<(String, bool)>,
}

impl AuditReport {
    pub fn pass(&self) -> bool {
        self.gates.iter().all(|(_, ok)| *ok)
    }

    pub fn to_json(&self) -> Json {
        let mut g = Json::obj();
        for (name, ok) in &self.gates {
            g.set(name, *ok);
        }
        let mut j = Json::obj();
        j.set("retain_ppl", self.retain_ppl)
            .set("mia_auc", self.mia_auc)
            .set(
                "mia_ci95",
                Json::Arr(vec![self.mia_ci.0.into(), self.mia_ci.1.into()]),
            )
            .set("canary_exposure_mu_bits", self.canary_mu_bits)
            .set("canary_exposure_sigma_bits", self.canary_sigma_bits)
            .set("targeted_extraction_rate", self.extraction_rate)
            .set("fuzzy_recall", self.fuzzy_recall)
            .set("gates", g)
            .set("pass", self.pass());
        j
    }
}

/// Inputs shared by all audits.
pub struct AuditContext<'a> {
    pub rt: &'a Runtime,
    pub corpus: &'a Corpus,
    /// The forget closure under audit.
    pub forget_ids: &'a [u64],
    /// Matched member controls (retain samples seen in training).
    pub retain_ids: &'a [u64],
    /// Held-out utility eval IDs.
    pub eval_ids: &'a [u64],
    /// Baseline retain PPL (e.g. from the oracle or pre-unlearn model).
    pub baseline_ppl: Option<f64>,
    pub thresholds: AuditThresholds,
    /// Deterministic seed for bootstrap / variant generation.
    pub seed: u64,
}

/// The request-*independent* evaluations of the audit harness over one
/// fixed model state: the MIA control losses (retain member controls)
/// and the retain-set utility perplexity.  A coalesced batch audits N
/// requests against the same post-rebuild state — these chunks are
/// evaluated once per batch and reused, while the per-request forget
/// probes (MIA forget losses, canary exposure, extraction, fuzzy
/// recall) still run individually.  Reusing them is bit-transparent:
/// both are pure functions of (state, id list), so a report built from
/// shared evals is identical to an unshared one.
#[derive(Debug, Clone)]
pub struct SharedEvals {
    /// Per-example per-token losses over `retain_ids` (MIA controls).
    pub control_losses: Vec<f32>,
    /// `exp(mean loss/token)` over `eval_ids` (utility gate input).
    pub retain_ppl: f64,
    /// Per-example per-token losses for the *forget-probe* ids of every
    /// request in the batch, precomputed by ONE `eval_batch` call over
    /// their union (see [`batch_forget_losses`]).  `None` → each
    /// request's MIA probe evaluates inline.  Bit-transparent either
    /// way: per-slot losses are pure functions of (state, sample).
    pub forget_losses: Option<std::collections::HashMap<u64, f32>>,
}

/// Evaluate the shared chunks once (the per-batch precomputation).
pub fn shared_evals(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<SharedEvals> {
    Ok(SharedEvals {
        control_losses: per_example_losses(
            ctx.rt, view, ctx.corpus, ctx.retain_ids,
        )?,
        retain_ppl: utility::retain_ppl(ctx, view)?,
        forget_losses: None,
    })
}

/// The per-request forget probes of a coalesced batch, batched: dedup
/// the union of the member closures and evaluate it in ONE `eval_batch`
/// executor call, returning id → per-token loss.  Each member's MIA
/// probe then reads its own closure's losses out of the map — N
/// requests' probes for the price of one graph round-trip, bit-
/// identical to N per-request `eval_loss` loops.
pub fn batch_forget_losses(
    rt: &Runtime,
    view: ModelView<'_>,
    corpus: &Corpus,
    closures: &[&[u64]],
) -> anyhow::Result<std::collections::HashMap<u64, f32>> {
    let mut ids: Vec<u64> =
        closures.iter().flat_map(|c| c.iter().copied()).collect();
    ids.sort_unstable();
    ids.dedup();
    let losses = per_example_losses(rt, view, corpus, &ids)?;
    Ok(ids.into_iter().zip(losses).collect())
}

/// Run all five audits against a model view (Alg. A.4 line 11).
pub fn run_audits(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
) -> anyhow::Result<AuditReport> {
    run_audits_with(ctx, view, None)
}

/// [`run_audits`] with optionally precomputed shared chunks (see
/// [`SharedEvals`]); `None` evaluates everything inline.
pub fn run_audits_with(
    ctx: &AuditContext<'_>,
    view: ModelView<'_>,
    shared: Option<&SharedEvals>,
) -> anyhow::Result<AuditReport> {
    let mia = mia::mia_auc_with(ctx, view, shared)?;
    let (mu, sigma) = canary::exposure(ctx, view)?;
    let extraction_rate = extraction::extraction_rate(ctx, view)?;
    let fuzzy_recall = fuzzy::fuzzy_recall(ctx, view)?;
    let retain_ppl = match shared {
        Some(s) => s.retain_ppl,
        None => utility::retain_ppl(ctx, view)?,
    };

    let th = &ctx.thresholds;
    let mut gates = vec![
        (
            "mia_in_band".to_string(),
            mia.auc >= th.mia_band.0 && mia.auc <= th.mia_band.1,
        ),
        ("exposure_below_max".to_string(), mu <= th.exposure_max),
        (
            "extraction_below_max".to_string(),
            extraction_rate <= th.extraction_max,
        ),
        ("fuzzy_below_max".to_string(), fuzzy_recall <= th.fuzzy_max),
    ];
    if let Some(base) = ctx.baseline_ppl {
        let drift = (retain_ppl - base).abs() / base;
        gates.push(("utility_within_band".to_string(), drift <= th.utility_drift));
    }
    Ok(AuditReport {
        retain_ppl,
        mia_auc: mia.auc,
        mia_ci: mia.ci95,
        canary_mu_bits: mu,
        canary_sigma_bits: sigma,
        extraction_rate,
        fuzzy_recall,
        gates,
    })
}
