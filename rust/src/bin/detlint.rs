//! `detlint` — determinism & durability conformance analyzer CLI.
//!
//! Run locally from the workspace root (or from `rust/`):
//!
//! ```text
//! cargo run --release --bin detlint            # human output, gate vs baseline
//! cargo run --release --bin detlint -- --json  # machine output + gate
//! ```
//!
//! Exit code 0 = no new findings vs the committed baseline; 1 = new
//! findings (CI fails).  See `--help` for the full flag set and the
//! allow-annotation policy, DESIGN.md §"Determinism conformance" for
//! the rule inventory.

use std::path::PathBuf;
use std::process::ExitCode;

use unlearn::cigate::lint as gate;
use unlearn::lint::{self, Finding, RULES};
use unlearn::util::cli::Args;
use unlearn::util::json::Json;

const HELP: &str = "\
detlint — static determinism & durability conformance check

USAGE:
    cargo run --release --bin detlint [-- OPTIONS]

OPTIONS:
    --root <dir>            source root to scan (default: auto-detect
                            rust/src or src relative to the cwd)
    --baseline <file>       baseline to gate against (default:
                            <root>/../detlint-baseline.json); a missing
                            file is an empty baseline
    --json                  print the full report as JSON
    --all                   print baselined findings too, not just new
    --bench-json <file>     also write finding/allow counts in the
                            BENCH_*.json shape for trend tracking
    --write-baseline <f|->  rewrite the baseline from this scan and exit
                            0 (`-` = the default path). Ratchet only:
                            use after FIXING findings, never to absorb
                            new ones
    --list-rules            print the rule registry and exit
    --help                  this text

EXIT CODE:
    0  scan matched the baseline (new findings = 0)
    1  new findings, or an operational error

SUPPRESSION:
    // detlint: allow(<rule>) — <reason>
    on the finding's line or on its own line directly above (blank
    lines, attributes and other comments in between are skipped). The
    reason is mandatory: an empty reason or an unknown rule name is
    itself a finding (allow-hygiene) and suppresses nothing.
    `#[cfg(test)]` items are not scanned.

BASELINE FORMAT (schema 1):
    { \"schema\": 1, \"tool\": \"detlint\", \"findings\": [
        { \"rule\", \"file\", \"snippet\", \"snippet_sha256\", \"count\" } ] }
    Findings match by (rule, file, snippet hash) with multiplicity, so
    line drift never breaks the gate but new occurrences do.
";

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("detlint: error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> anyhow::Result<ExitCode> {
    let args = Args::from_env();
    if args.flag("help") || args.subcommand.as_deref() == Some("help") {
        print!("{HELP}");
        return Ok(ExitCode::SUCCESS);
    }
    if args.flag("list-rules") {
        for r in RULES {
            println!("{:16} {}", r.id, r.desc);
            println!("{:16}   scope: {}", "", r.scope);
        }
        return Ok(ExitCode::SUCCESS);
    }

    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => autodetect_root()?,
    };
    let default_baseline = root
        .parent()
        .map(|p| p.join("detlint-baseline.json"))
        .unwrap_or_else(|| PathBuf::from("detlint-baseline.json"));
    let baseline_path = args
        .get("baseline")
        .map(PathBuf::from)
        .unwrap_or(default_baseline);

    let report = lint::scan_dir(&root)?;

    if let Some(target) = args.get("write-baseline") {
        let path = if target == "-" {
            baseline_path
        } else {
            PathBuf::from(target)
        };
        gate::write_baseline(&path, &report.findings)?;
        println!(
            "detlint: baseline {} <- {} finding(s) from {} file(s)",
            path.display(),
            report.findings.len(),
            report.files_scanned
        );
        return Ok(ExitCode::SUCCESS);
    }
    if args.flag("write-baseline") {
        gate::write_baseline(&baseline_path, &report.findings)?;
        println!(
            "detlint: baseline {} <- {} finding(s)",
            baseline_path.display(),
            report.findings.len()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let verdict = gate::gate_against_file(&report.findings, &baseline_path)?;

    if let Some(bench) = args.get("bench-json") {
        std::fs::write(bench, bench_json(&report, &verdict).pretty() + "\n")?;
    }
    if args.flag("json") {
        println!("{}", report_json(&report, &verdict).pretty());
    } else {
        let shown: Vec<&Finding> = if args.flag("all") {
            report.findings.iter().collect()
        } else {
            verdict.new.iter().collect()
        };
        for f in &shown {
            println!("{}:{}:{} {} — {}", f.file, f.line, f.col, f.rule, f.message);
            println!("    {}", f.snippet);
        }
        println!(
            "detlint: {} file(s), {} finding(s) ({} baselined, {} new), \
             {} allow(s); baseline {}",
            report.files_scanned,
            report.findings.len(),
            verdict.baselined,
            verdict.new.len(),
            report.suppressed,
            baseline_path.display(),
        );
        if verdict.fixed > 0 {
            println!(
                "detlint: {} baselined finding(s) no longer fire — ratchet \
                 with --write-baseline",
                verdict.fixed
            );
        }
    }
    Ok(if verdict.pass() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    })
}

/// `rust/src` from the workspace root, `src` from inside `rust/`.
fn autodetect_root() -> anyhow::Result<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").exists() {
            return Ok(p);
        }
    }
    anyhow::bail!(
        "cannot find a source root (tried rust/src, src); pass --root <dir>"
    )
}

fn finding_json(f: &Finding) -> Json {
    let mut o = Json::obj();
    o.set("rule", f.rule)
        .set("file", f.file.as_str())
        .set("line", f.line as u64)
        .set("col", f.col as u64)
        .set("message", f.message.as_str())
        .set("snippet", f.snippet.as_str())
        .set("key", gate::baseline_key(f).as_str());
    o
}

fn report_json(report: &lint::ScanReport, verdict: &gate::LintGate) -> Json {
    let mut o = Json::obj();
    o.set("tool", "detlint")
        .set("files_scanned", report.files_scanned as u64)
        .set("allows", report.suppressed as u64)
        .set("baselined", verdict.baselined as u64)
        .set("fixed_vs_baseline", verdict.fixed)
        .set(
            "findings",
            Json::Arr(report.findings.iter().map(finding_json).collect()),
        )
        .set(
            "new_findings",
            Json::Arr(verdict.new.iter().map(finding_json).collect()),
        )
        .set("pass", verdict.pass());
    o
}

/// The BENCH_*.json shape `cigate::perf` trends consume: counts only,
/// no wall-clock anywhere (finding counts are machine-independent).
fn bench_json(report: &lint::ScanReport, verdict: &gate::LintGate) -> Json {
    let mut per_rule = Json::obj();
    for r in RULES {
        let n = report.findings.iter().filter(|f| f.rule == r.id).count();
        per_rule.set(r.id, n as u64);
    }
    let mut o = Json::obj();
    o.set("bench", "detlint")
        .set("schema", 1u64)
        .set("files_scanned", report.files_scanned as u64)
        .set("findings_total", report.findings.len() as u64)
        .set("findings_new", verdict.new.len() as u64)
        .set("allows", report.suppressed as u64)
        .set("per_rule", per_rule);
    o
}
