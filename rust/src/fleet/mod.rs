//! The sharded unlearning fleet: N independent [`UnlearnSystem`]s, one
//! per [`crate::shard::ShardSpec`] shard, orchestrated so that
//! forgetting user `u` touches **only** `shard(u)` (plus any shard
//! owning a near-duplicate of `u`'s documents) and every other shard's
//! serving state and store bytes are provably untouched — the
//! SISA-style `1/N` cost scaling on top of the source paper's per-shard
//! bit-identity guarantee.
//!
//! ## Isolation invariants
//!
//! - Every shard owns a full run directory (WAL, IdMap, pins,
//!   checkpoint CAS, delta ring, signed manifest, forgotten/laundered
//!   sets) under `<root>/shard-NNNN/`.  No file is shared between
//!   shards; the shared CAS dedup happens *within* a shard's store.
//! - The user→shard assignment is a pure function pinned into every
//!   shard's `Pins.shard` — reopening the fleet under a different
//!   `n_shards`/salt fails closed before any replay runs (and
//!   `fleet.json` at the root refuses the reopen even earlier).
//! - Routing expands the forget closure on the **global** near-dup
//!   index first, then scatters members to their owning shards via the
//!   closure's ownership attribution ([`crate::neardup::ClosureResult::
//!   by_owner`]) — a paraphrase of `u`'s document owned by user `v`
//!   is erased from `shard(v)`, not silently dropped.
//! - A shard that receives no part of a request's closure executes
//!   nothing: not planned, not audited, not written to.  The
//!   `tests/fleet_equality.rs` proof checks its run-dir bytes.
//!
//! ## Cost model
//!
//! Multi-shard work runs on scoped threads (one per touched shard), so
//! fleet latency is the **max** over touched shards while total work is
//! the sum — [`FleetPlan`] reports both, rolled up from the per-shard
//! typed [`UnlearnPlan`]s.  Within a shard, requests coalesce through
//! the existing [`crate::controller::execute_batch`] (one
//! union-filtered rebuild per shard per burst).  Each shard launders
//! independently under its own [`LaunderPolicy`].

pub mod server;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::audit::{per_example_loss_counts, ModelView};
use crate::config::RunConfig;
use crate::controller::{
    execute_batch, ControllerOutcome, ForgetRequest, LaunderOutcome,
    LaunderPolicy, UnlearnPlan, UnlearnSystem,
};
use crate::data::corpus::Corpus;
use crate::harness;
use crate::ingest::{self, IngestDoc};
use crate::neardup::closure::build_index;
use crate::neardup::{expand_closure, ClosureParams, HammingIndex};
use crate::replica::{Replica, SyncStats};
use crate::runtime::Runtime;
use crate::shard::{split_corpus, ShardSpec, ShardSplit};
use crate::util::json::Json;
use crate::util::rng::philox_u64;

/// Fleet construction parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Fleet root: `fleet.json` (the pinned topology) plus one
    /// `shard-NNNN/` run directory per shard live here.
    pub root: PathBuf,
    pub spec: ShardSpec,
    /// Per-shard run-config template (`run_dir` is ignored — each shard
    /// derives its own under `root`; `shard_pin` is overwritten with
    /// the shard's topology pin).
    pub base: RunConfig,
    /// Scale each shard's step budget by its corpus share (constant
    /// epochs over a `1/N` slice ⇒ `~steps/N` per shard — the SISA cost
    /// model).  Off = every shard trains the full `base.steps`.
    pub scale_steps: bool,
    /// Laundering trigger, instantiated per shard (each shard's
    /// forgotten-set inflation is tracked — and compacted —
    /// independently).
    pub launder_policy: LaunderPolicy,
    /// Run a per-shard laundering pass from the drain loop whenever a
    /// burst flips that shard's own `launder_recommended`.
    pub auto_launder: bool,
}

/// One live shard: its system plus its private laundering policy.
pub struct ShardState<'rt> {
    pub system: UnlearnSystem<'rt>,
    pub policy: LaunderPolicy,
}

/// Per-shard serving health: the degraded-mode isolation state.  A
/// shard whose erasure work (`execute_batch` / launder) errors is
/// quarantined — its queued work gets a typed `quarantined` outcome
/// instead of an execution attempt — while every healthy shard keeps
/// serving and erasing.  Backoff is counted in DRAIN CYCLES, not wall
/// clock, so recovery behavior is deterministic and testable: each
/// drain that routes work to a quarantined shard ticks its cooldown
/// down by one; at zero the next drain is a half-open probe (success
/// restores `Healthy`, failure re-quarantines with doubled backoff).
#[derive(Debug, Clone, PartialEq)]
pub enum ShardHealth {
    Healthy,
    Quarantined {
        /// The error that tripped the quarantine (operator-visible via
        /// `fleet_status`).
        reason: String,
        /// Consecutive failed attempts (drives the exponential backoff).
        failures: u32,
        /// Drains remaining before the half-open retry.
        cooldown_drains: u32,
    },
}

impl ShardHealth {
    pub fn is_quarantined(&self) -> bool {
        matches!(self, ShardHealth::Quarantined { .. })
    }
}

/// Deterministic drain-counted backoff: 1, 2, 4, 8, 8, ... drains.
fn backoff_drains(failures: u32) -> u32 {
    1u32 << failures.saturating_sub(1).min(3)
}

/// The orchestrator over N shard systems.
pub struct Fleet<'rt> {
    pub spec: ShardSpec,
    pub root: PathBuf,
    /// Global corpus (the ingest view routing expands closures over).
    corpus: Corpus,
    /// Global near-dup index — closures must reach across shards.
    ndindex: HammingIndex,
    closure_params: ClosureParams,
    split: ShardSplit,
    /// `None` = the shard's user set was empty at ingest (nothing to
    /// train, nothing routable to it).
    shards: Vec<Option<ShardState<'rt>>>,
    /// Degraded-mode isolation state, one slot per shard (empty shards
    /// stay `Healthy` forever — nothing routes to them).
    health: Vec<ShardHealth>,
    pub auto_launder: bool,
    /// Attached read replicas (the serving data plane).  Re-synced
    /// from [`Fleet::launder_due`] after every committed lineage swap.
    replicas: Vec<ReplicaAttachment>,
    /// Erasure-propagation SLA of the most recent launder pass that
    /// touched attached replicas: wall ms from the launder trigger to
    /// the last replica adopting the clean lineage.
    pub last_propagation_ms: Option<f64>,
}

/// One attached read replica and the shard it mirrors.
pub struct ReplicaAttachment {
    pub shard: u32,
    pub replica: Replica,
}

/// One shard's share of a fleet request's outcome.
pub struct ShardOutcome {
    pub shard: u32,
    pub outcome: anyhow::Result<ControllerOutcome>,
    /// True when the shard did not attempt the work because it is
    /// quarantined (cooldown still running) — distinguishes "skipped by
    /// the isolation layer" from "attempted and failed" so partial
    /// failure is attributable per shard.
    pub quarantined: bool,
}

/// Per-request fleet outcome: which shards executed and what each did.
pub struct FleetOutcome {
    pub request_id: String,
    pub shards: Vec<ShardOutcome>,
}

impl FleetOutcome {
    /// True when every routed shard committed an executed action.
    pub fn executed(&self) -> bool {
        !self.shards.is_empty()
            && self.shards.iter().all(|s| {
                s.outcome.as_ref().map(|o| o.executed).unwrap_or(false)
            })
    }

    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for s in &self.shards {
            let mut j = Json::obj();
            j.set("shard", s.shard);
            match &s.outcome {
                Ok(o) => {
                    j.set("ok", true)
                        .set("status", "ok")
                        .set("action", o.action.as_str())
                        .set("executed", o.executed)
                        .set("closure_size", o.closure_size);
                }
                Err(e) => {
                    j.set("ok", false)
                        .set(
                            "status",
                            if s.quarantined { "quarantined" } else { "failed" },
                        )
                        .set("error", format!("{e:#}"));
                }
            }
            arr.push(j);
        }
        let mut out = Json::obj();
        out.set("request_id", self.request_id.as_str())
            .set("executed", self.executed())
            .set("shards", Json::Arr(arr));
        out
    }
}

/// What one fleet batch did across all shards.
pub struct FleetBatchOutcome {
    /// Per input request, in submission order.
    pub outcomes: Vec<FleetOutcome>,
    /// Shards that received any work.
    pub shards_touched: usize,
    /// Shared rebuilds executed (≤ 1 per touched shard — intra-shard
    /// coalescing via `execute_batch`).
    pub replays_run: usize,
    /// Replay/revert-resume microbatch updates applied fleet-wide: the
    /// bench's replay-work-per-request numerator.
    pub applied_steps_total: u64,
}

/// Fleet-level rollup of per-shard typed plans: total work (bytes,
/// replay steps) plus the parallel-latency bound (max over shards).
pub struct FleetPlan {
    pub request_id: String,
    pub shard_plans: Vec<(u32, UnlearnPlan)>,
    pub total_replay_steps: u64,
    pub total_bytes: u64,
    /// Shards execute concurrently: predicted fleet latency is the max
    /// of the per-shard terminal-step estimates.
    pub max_est_wall_secs: f64,
    pub sum_est_wall_secs: f64,
}

impl FleetPlan {
    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for (shard, p) in &self.shard_plans {
            let mut j = Json::obj();
            j.set("shard", *shard).set("plan", p.to_json());
            arr.push(j);
        }
        let mut out = Json::obj();
        out.set("request_id", self.request_id.as_str())
            .set("shards", Json::Arr(arr))
            .set("total_replay_steps", self.total_replay_steps)
            .set("total_bytes", self.total_bytes)
            .set("max_est_wall_secs", self.max_est_wall_secs)
            .set("sum_est_wall_secs", self.sum_est_wall_secs);
        out
    }
}

/// Uniform-ensemble fleet utility: each shard model evaluated on its
/// own held-out split, shard perplexities averaged with equal weight
/// (the ensemble the fleet would serve with).
pub struct FleetUtility {
    pub fleet_ppl: f64,
    pub per_shard: Vec<(u32, f64)>,
}

impl<'rt> Fleet<'rt> {
    /// Train a fresh fleet: split the corpus by ownership, train every
    /// non-empty shard (in parallel on scoped threads) and assemble the
    /// per-shard systems.  Existing shard run dirs are wiped.
    pub fn train(
        rt: &'rt Runtime,
        cfg: FleetConfig,
        corpus: Corpus,
    ) -> anyhow::Result<Fleet<'rt>> {
        Self::build(rt, cfg, corpus, false).map(|(f, _)| f)
    }

    /// Reopen an existing fleet root (resuming every shard's run dir —
    /// WAL, lineages, manifests and forgotten sets all survive) or
    /// train from scratch when none exists.  A shard whose run dir was
    /// lost is retrained alone — the others are untouched.  Returns
    /// whether any shard resumed.
    pub fn open_or_train(
        rt: &'rt Runtime,
        cfg: FleetConfig,
        corpus: Corpus,
    ) -> anyhow::Result<(Fleet<'rt>, bool)> {
        Self::build(rt, cfg, corpus, true)
    }

    fn build(
        rt: &'rt Runtime,
        cfg: FleetConfig,
        corpus: Corpus,
        resume: bool,
    ) -> anyhow::Result<(Fleet<'rt>, bool)> {
        anyhow::ensure!(cfg.spec.n_shards > 0, "fleet needs n_shards > 0");
        std::fs::create_dir_all(&cfg.root)?;
        let spec_path = cfg.root.join("fleet.json");
        if spec_path.exists() {
            let stored = ShardSpec::load(&spec_path)?;
            anyhow::ensure!(
                stored == cfg.spec,
                "fleet topology drift at {}: stored n_shards={} \
                 salt={:#x} vs requested n_shards={} salt={:#x} — the \
                 user→shard assignment is pinned; refusing (fail-closed)",
                spec_path.display(),
                stored.n_shards,
                stored.salt,
                cfg.spec.n_shards,
                cfg.spec.salt
            );
        } else {
            cfg.spec.save(&spec_path)?;
        }

        let mut split = split_corpus(&cfg.spec, &corpus);
        // Move the shard sub-corpora out of the split: each shard
        // system owns its copy and the fleet keeps the global corpus —
        // retaining a third set in `split.corpora` would hold the whole
        // corpus in memory once more for nothing (only the id maps are
        // consulted after build).
        let corpora = std::mem::take(&mut split.corpora);
        let mut ndindex = build_index(&corpus);
        let total_len = corpus.len();
        let n = cfg.spec.n_shards as usize;

        // Train/open every non-empty shard concurrently: shards are
        // fully independent (disjoint run dirs, shared read-only
        // runtime), so fleet build time is max-over-shards.  Each slot
        // carries the shard's committed online-ingest docs (local base
        // id + docs, commit order) so the global routing view below can
        // re-grow to match what the shard WALs reference.
        type ShardBuilt<'rt> =
            (harness::TrainedSystem<'rt>, bool, Vec<(u64, Vec<IngestDoc>)>);
        let mut results: Vec<Option<anyhow::Result<ShardBuilt<'rt>>>> =
            (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for ((i, res), shard_corpus) in
                results.iter_mut().enumerate().zip(corpora)
            {
                if shard_corpus.is_empty() {
                    continue;
                }
                let scfg =
                    shard_run_config(&cfg, i as u32, shard_corpus.len(), total_len);
                handles.push((res, s.spawn(move || {
                    if resume {
                        // same resumability predicate as
                        // `harness::open_or_build_system` (reopen
                        // falls back to a fresh build itself, but the
                        // fleet still reports whether anything resumed)
                        let resumed = scfg.run_dir.join("wal").exists()
                            && scfg.run_dir.join("pins.json").exists()
                            && scfg.run_dir.join("ids.map").exists();
                        // the ingest-aware reopen: recovers torn ingest
                        // rounds and re-enters committed docs before
                        // the WAL tail is replayed
                        let (t, log, _report) =
                            ingest::reopen(rt, scfg, shard_corpus, false)?;
                        let docs = log.committed_docs()?;
                        Ok((t, resumed, docs))
                    } else {
                        harness::build_system(rt, scfg, shard_corpus, false)
                            .map(|t| (t, false, Vec::new()))
                    }
                })));
            }
            for (res, h) in handles {
                *res = Some(h.join().unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("shard build thread panicked"))
                }));
            }
        });

        let mut shards: Vec<Option<ShardState<'rt>>> = Vec::with_capacity(n);
        let mut resumed_any = false;
        let mut corpus = corpus;
        for (i, res) in results.into_iter().enumerate() {
            match res {
                None => shards.push(None),
                Some(Err(e)) => {
                    return Err(e.context(format!("shard {i} failed to build")))
                }
                Some(Ok((trained, resumed, ingested))) => {
                    let system = trained.system;
                    // Re-grow the global routing view with the shard's
                    // committed ingest docs.  Global ids are
                    // process-local routing handles (only shard-LOCAL
                    // ids are durable in WALs), so assigning them here
                    // in shard-then-commit order is sound — the locate
                    // map re-links them to the durable local ids.
                    for (local_base, docs) in ingested {
                        let gbase = corpus.len() as u64;
                        for k in 0..docs.len() as u64 {
                            split
                                .locate
                                .insert(gbase + k, (i as u32, local_base + k));
                        }
                        ingest::grow_corpus(
                            &mut corpus,
                            &mut ndindex,
                            gbase,
                            &docs,
                        )?;
                    }
                    // topology pin sanity: the run dir must have been
                    // trained as THIS shard of THIS topology
                    let expect = cfg.spec.pin_for(i as u32);
                    anyhow::ensure!(
                        system.pins.shard == expect,
                        "shard {i} pins carry topology {:?}, fleet \
                         expects {:?} — refusing (fail-closed)",
                        system.pins.shard,
                        expect
                    );
                    resumed_any |= resumed;
                    shards.push(Some(ShardState {
                        system,
                        policy: cfg.launder_policy.clone(),
                    }));
                }
            }
        }
        Ok((
            Fleet {
                spec: cfg.spec,
                root: cfg.root,
                corpus,
                ndindex,
                closure_params: ClosureParams::default(),
                split,
                shards,
                health: vec![ShardHealth::Healthy; n],
                auto_launder: cfg.auto_launder,
                replicas: Vec::new(),
                last_propagation_ms: None,
            },
            resumed_any,
        ))
    }

    pub fn n_shards(&self) -> u32 {
        self.spec.n_shards
    }

    /// The global ingest corpus the router expands closures over.
    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    /// The global↔local id mapping of the ownership partition.  NOTE:
    /// `split.corpora` is empty here — the sub-corpora were moved into
    /// their shard systems at build (see [`Fleet::build`]); use
    /// [`Fleet::shard`]`.corpus` for a shard's corpus.
    pub fn split(&self) -> &ShardSplit {
        &self.split
    }

    pub fn shard(&self, shard: u32) -> Option<&UnlearnSystem<'rt>> {
        self.shards
            .get(shard as usize)
            .and_then(|s| s.as_ref())
            .map(|s| &s.system)
    }

    pub fn shard_mut(&mut self, shard: u32) -> Option<&mut UnlearnSystem<'rt>> {
        self.shards
            .get_mut(shard as usize)
            .and_then(|s| s.as_mut())
            .map(|s| &mut s.system)
    }

    /// Attach a read replica mirroring `shard`'s CAS at `local_root`
    /// and run its cold sync (a replica never serves before its first
    /// completed sync — fail closed).  Returns the attachment index
    /// and the cold sync's transfer accounting.
    pub fn attach_replica(
        &mut self,
        shard: u32,
        local_root: &Path,
    ) -> anyhow::Result<(usize, SyncStats)> {
        anyhow::ensure!(
            self.shard(shard).is_some(),
            "cannot attach a replica to empty or out-of-range shard \
             {shard}"
        );
        let source = self.root.join(format!("shard-{shard:04}")).join("ckpt");
        let mut replica = Replica::open(&source, local_root)?;
        let stats = replica.sync()?;
        self.replicas.push(ReplicaAttachment { shard, replica });
        Ok((self.replicas.len() - 1, stats))
    }

    /// The attached replicas (`fleet_status` embeds their rows).
    pub fn replicas(&self) -> &[ReplicaAttachment] {
        &self.replicas
    }

    /// Re-sync every replica mirroring `shard` — the lineage-swap
    /// invalidation fan-out.  Returns (attachment index, result); a
    /// failed sync leaves that replica on its old generation, which
    /// its query plane reports as stale rather than hiding.
    pub fn sync_replicas(
        &mut self,
        shard: u32,
    ) -> Vec<(usize, anyhow::Result<SyncStats>)> {
        let mut out = Vec::new();
        for (i, att) in self.replicas.iter_mut().enumerate() {
            if att.shard == shard {
                out.push((i, att.replica.sync()));
            }
        }
        out
    }

    /// The isolation state of one shard (None = shard index out of
    /// range).
    pub fn shard_health(&self, shard: u32) -> Option<&ShardHealth> {
        self.health.get(shard as usize)
    }

    /// Number of currently quarantined shards.
    pub fn quarantined_count(&self) -> usize {
        self.health.iter().filter(|h| h.is_quarantined()).count()
    }

    /// Record a shard-level infrastructure failure: first failure
    /// quarantines with a 1-drain cooldown; each subsequent failed
    /// (half-open) probe doubles the backoff up to 8 drains.
    fn note_shard_failure(&mut self, shard: usize, reason: String) {
        let failures = match &self.health[shard] {
            ShardHealth::Quarantined { failures, .. } => failures + 1,
            ShardHealth::Healthy => 1,
        };
        self.health[shard] = ShardHealth::Quarantined {
            reason,
            failures,
            cooldown_drains: backoff_drains(failures),
        };
    }

    /// Route a fleet request to its owning shards: expand the closure on
    /// the GLOBAL near-dup index (user samples + explicit global sample
    /// ids), then scatter members by document ownership.  Each returned
    /// request carries shard-local sample IDs; a request whose closure
    /// is empty routes nowhere.
    pub fn route(
        &self,
        req: &ForgetRequest,
    ) -> anyhow::Result<Vec<(u32, ForgetRequest)>> {
        let mut ids: Vec<u64> = req.sample_ids.clone();
        if let Some(u) = req.user {
            ids.extend(self.corpus.user_samples(u));
        }
        ids.sort_unstable();
        ids.dedup();
        let cl = expand_closure(
            &self.corpus,
            &self.ndindex,
            &ids,
            self.closure_params,
        );
        // scatter by ownership (the closure carries it — no re-derive)
        let mut per_shard: HashMap<u32, Vec<u64>> = HashMap::new();
        for (user, member_ids) in cl.by_owner() {
            let shard = self.spec.assign(user);
            let bucket = per_shard.entry(shard).or_default();
            for gid in member_ids {
                let (s, local) = self.split.local_of(gid).ok_or_else(|| {
                    anyhow::anyhow!("closure member {gid} has no shard")
                })?;
                debug_assert_eq!(s, shard);
                bucket.push(local);
            }
        }
        let mut parts: Vec<(u32, ForgetRequest)> = per_shard
            .into_iter()
            .map(|(shard, mut locals)| {
                locals.sort_unstable();
                locals.dedup();
                (
                    shard,
                    ForgetRequest {
                        id: req.id.clone(),
                        user: None,
                        sample_ids: locals,
                        urgency: req.urgency,
                    },
                )
            })
            .collect();
        parts.sort_by_key(|&(s, _)| s);
        for (shard, _) in &parts {
            anyhow::ensure!(
                self.shard(*shard).is_some(),
                "request routes to shard {shard}, which holds no system"
            );
        }
        Ok(parts)
    }

    /// Route restricted to ONE shard (the admin plane's shard-addressed
    /// submit): closure members owned by other shards are dropped — an
    /// explicit operator override of the cross-shard scatter.
    pub fn route_to_shard(
        &self,
        req: &ForgetRequest,
        shard: u32,
    ) -> anyhow::Result<Vec<(u32, ForgetRequest)>> {
        anyhow::ensure!(
            shard < self.spec.n_shards,
            "shard {shard} out of range (fleet has {})",
            self.spec.n_shards
        );
        Ok(self
            .route(req)?
            .into_iter()
            .filter(|&(s, _)| s == shard)
            .collect())
    }

    /// Fleet-level dry-run: per-shard typed plans rolled into one cost
    /// object (total replay steps / bytes, max-latency under parallel
    /// shard execution).  Pure — nothing is mutated.
    pub fn plan(&self, req: &ForgetRequest) -> anyhow::Result<FleetPlan> {
        let parts = self.route(req)?;
        let mut shard_plans = Vec::new();
        let mut total_replay_steps = 0u64;
        let mut total_bytes = 0u64;
        let mut max_wall = 0.0f64;
        let mut sum_wall = 0.0f64;
        for (shard, sreq) in parts {
            let sys = self
                .shard(shard)
                .ok_or_else(|| anyhow::anyhow!("shard {shard} empty"))?;
            let plan = sys
                .plan(&sreq)
                .map_err(|e| anyhow::anyhow!("shard {shard}: {e}"))?;
            if let Some(terminal) = plan.steps.last() {
                total_replay_steps += terminal.cost.replay_steps as u64;
                total_bytes += terminal.cost.bytes_touched;
                max_wall = max_wall.max(terminal.cost.est_wall_secs);
                sum_wall += terminal.cost.est_wall_secs;
            }
            shard_plans.push((shard, plan));
        }
        Ok(FleetPlan {
            request_id: req.id.clone(),
            shard_plans,
            total_replay_steps,
            total_bytes,
            max_est_wall_secs: max_wall,
            sum_est_wall_secs: sum_wall,
        })
    }

    /// Handle one fleet forget request end to end.
    pub fn forget(
        &mut self,
        req: &ForgetRequest,
    ) -> anyhow::Result<FleetBatchOutcome> {
        self.execute_batch(std::slice::from_ref(req))
    }

    /// Execute a batch of fleet requests: route everything, then run
    /// every touched shard's share concurrently — each shard receives
    /// its requests as ONE [`execute_batch`] call (intra-shard
    /// coalescing), shards proceed in parallel (inter-shard scaling).
    pub fn execute_batch(
        &mut self,
        reqs: &[ForgetRequest],
    ) -> anyhow::Result<FleetBatchOutcome> {
        let routed: Vec<Vec<(u32, ForgetRequest)>> = reqs
            .iter()
            .map(|r| self.route(r))
            .collect::<anyhow::Result<_>>()?;
        self.execute_routed(reqs, routed)
    }

    /// The execution half of [`Fleet::execute_batch`] over caller-built
    /// routing (the admin plane injects shard-addressed overrides).
    pub fn execute_routed(
        &mut self,
        reqs: &[ForgetRequest],
        routed: Vec<Vec<(u32, ForgetRequest)>>,
    ) -> anyhow::Result<FleetBatchOutcome> {
        anyhow::ensure!(routed.len() == reqs.len(), "routing shape mismatch");
        let n = self.shards.len();
        // group per shard, remembering which input each part belongs to
        let mut per_shard: Vec<Vec<(usize, ForgetRequest)>> =
            vec![Vec::new(); n];
        for (input, parts) in routed.iter().enumerate() {
            for (shard, sreq) in parts {
                per_shard[*shard as usize].push((input, sreq.clone()));
            }
        }

        // Degraded-mode isolation, BEFORE any thread spawns: a shard
        // whose quarantine cooldown is still running gets no execution
        // attempt this drain — its inputs receive a typed quarantined
        // outcome and the cooldown ticks down one drain.  A shard whose
        // cooldown reached zero runs this drain as a half-open probe.
        // Healthy shards are entirely unaffected: the skip decision is
        // per shard, so one sick shard never blocks the others' drains.
        let mut skipped: Vec<Option<String>> = (0..n).map(|_| None).collect();
        for shard in 0..n {
            if per_shard[shard].is_empty() {
                continue;
            }
            if let ShardHealth::Quarantined {
                reason,
                cooldown_drains,
                ..
            } = &mut self.health[shard]
            {
                if *cooldown_drains > 0 {
                    *cooldown_drains -= 1;
                    skipped[shard] = Some(format!(
                        "shard {shard} quarantined ({reason}); retry in \
                         {cooldown_drains} drain(s)"
                    ));
                }
            }
        }

        // one scoped thread per touched shard; disjoint &mut borrows
        // via iter_mut, so no locking is needed
        let mut shard_results: Vec<
            Option<anyhow::Result<crate::controller::BatchOutcome>>,
        > = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (((slot, work), res), skip) in self
                .shards
                .iter_mut()
                .zip(&per_shard)
                .zip(shard_results.iter_mut())
                .zip(&skipped)
            {
                if work.is_empty() || skip.is_some() {
                    continue;
                }
                let Some(st) = slot.as_mut() else { continue };
                let sreqs: Vec<ForgetRequest> =
                    work.iter().map(|(_, r)| r.clone()).collect();
                handles.push((res, s.spawn(move || {
                    execute_batch(&mut st.system, &sreqs)
                })));
            }
            for (res, h) in handles {
                *res = Some(h.join().unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("shard batch thread panicked"))
                }));
            }
        });

        // fan per-shard slot results back to the input requests
        let mut outcomes: Vec<FleetOutcome> = reqs
            .iter()
            .map(|r| FleetOutcome {
                request_id: r.id.clone(),
                shards: Vec::new(),
            })
            .collect();
        let mut shards_touched = 0usize;
        let mut replays_run = 0usize;
        let mut applied_steps_total = 0u64;
        for (shard, msg) in skipped.iter().enumerate() {
            let Some(msg) = msg else { continue };
            for (input, _) in &per_shard[shard] {
                outcomes[*input].shards.push(ShardOutcome {
                    shard: shard as u32,
                    outcome: Err(anyhow::anyhow!("{msg}")),
                    quarantined: true,
                });
            }
        }
        for (shard, res) in shard_results.into_iter().enumerate() {
            let Some(res) = res else { continue };
            shards_touched += 1;
            match res {
                Err(e) => {
                    let msg = format!("{e:#}");
                    // quarantine the shard (or double an expired
                    // quarantine's backoff after a failed probe)
                    self.note_shard_failure(shard, msg.clone());
                    for (input, _) in &per_shard[shard] {
                        outcomes[*input].shards.push(ShardOutcome {
                            shard: shard as u32,
                            outcome: Err(anyhow::anyhow!(
                                "shard {shard} batch failed: {msg}"
                            )),
                            quarantined: false,
                        });
                    }
                }
                Ok(batch) => {
                    // a successful drain (including a half-open probe)
                    // restores the shard to full health
                    self.health[shard] = ShardHealth::Healthy;
                    replays_run += batch.replays_run;
                    applied_steps_total += batch.applied_steps as u64;
                    for ((input, _), out) in
                        per_shard[shard].iter().zip(batch.outcomes)
                    {
                        outcomes[*input].shards.push(ShardOutcome {
                            shard: shard as u32,
                            outcome: out,
                            quarantined: false,
                        });
                    }
                }
            }
        }
        Ok(FleetBatchOutcome {
            outcomes,
            shards_touched,
            replays_run,
            applied_steps_total,
        })
    }

    /// Online ingest into the fleet: documents are user-owned, so the
    /// whole batch routes to `assign(user)` and exactly ONE shard runs
    /// a scheduler round — durable doc append + bounded
    /// train-increment — while every other shard's bytes stay
    /// untouched (the `1/N` cost mirror of the forget path).  The
    /// GLOBAL routing view (corpus, near-dup index, locate map) grows
    /// alongside, so subsequent forget closures reach the new docs.
    /// The round key derives from `req_id`, making a retry after a
    /// torn round idempotent per request.
    pub fn ingest(
        &mut self,
        req_id: &str,
        user: u32,
        texts: &[String],
        train_steps: u32,
    ) -> anyhow::Result<(u32, ingest::IncrementOutcome)> {
        anyhow::ensure!(!texts.is_empty(), "ingest batch is empty");
        let shard = self.spec.assign(user);
        let i = shard as usize;
        if let ShardHealth::Quarantined {
            reason,
            cooldown_drains,
            ..
        } = &self.health[i]
        {
            anyhow::ensure!(
                *cooldown_drains == 0,
                "shard {shard} is quarantined ({reason}) — ingest \
                 refused until the cooldown expires"
            );
        }
        let Some(Some(st)) = self.shards.get_mut(i) else {
            anyhow::bail!(
                "user {user} routes to shard {shard}, which holds no \
                 system (its user set was empty at fleet build) — \
                 rebuild the fleet with the user's corpus to bootstrap \
                 it, then ingest"
            );
        };
        let docs: Vec<IngestDoc> = texts
            .iter()
            .map(|t| IngestDoc {
                user,
                text: t.clone(),
            })
            .collect();
        let round = ingest::round_of(req_id);
        let sys = &mut st.system;
        let mut log =
            ingest::IngestLog::attach(&sys.cfg.run_dir, sys.corpus.len())?;
        // captured before the round so the global view can mirror the
        // local ids the shard assigns; a round whose ingest half
        // already committed (idempotent retry) must NOT re-grow the
        // global view — build/the first attempt already did
        let fresh_docs = !log.has_ingest_round(round);
        let local_base = sys.corpus.len() as u64;
        let sched = ingest::IngestScheduler::new(train_steps);
        let res = sched.run_round(sys, &mut log, round, &docs);
        match &res {
            Err(e) => {
                // ingest shares the shard-infrastructure failure
                // posture of the forget drain: quarantine the shard so
                // erasure work stops routing at a sick WAL/log
                self.note_shard_failure(i, format!("ingest: {e:#}"));
            }
            Ok(_) => self.health[i] = ShardHealth::Healthy,
        }
        // The global view must grow whenever the ingest half COMMITTED
        // this round — even if the train-increment errored afterwards.
        // The docs are durable and the shard's local corpus has grown;
        // an idempotent retry would see `has_ingest_round` true and
        // skip this block, leaving forget closures and routing blind
        // to committed docs.
        if fresh_docs && log.has_ingest_round(round) {
            let gbase = self.corpus.len() as u64;
            for k in 0..docs.len() as u64 {
                self.split
                    .locate
                    .insert(gbase + k, (shard, local_base + k));
            }
            ingest::grow_corpus(
                &mut self.corpus,
                &mut self.ndindex,
                gbase,
                &docs,
            )?;
        }
        res.map(|out| (shard, out))
    }

    /// Run a laundering pass on every shard whose OWN policy says it is
    /// due, concurrently.  The per-shard manifest key is
    /// `<id_prefix>-s<shard>-g<generation>`: the active lineage
    /// generation makes a RETRY of the same invocation idempotent
    /// (same generation → duplicate-suppressed) while a later pass —
    /// after a committed launder bumped the generation — always gets a
    /// fresh key, even when the caller reuses its prefix (default
    /// admin-op ids, restarted in-memory job counters).  Returns the
    /// outcomes of the shards that ran.
    pub fn launder_due(
        &mut self,
        id_prefix: &str,
    ) -> Vec<(u32, anyhow::Result<LaunderOutcome>)> {
        // propagation clock starts at the launder trigger: the SLA in
        // `last_propagation_ms` covers replay + swap + replica re-sync
        let t0 = crate::metrics::monotonic_now();
        // quarantined shards sit laundering out until their cooldown
        // expires (the drain path owns the tick-down; here we only
        // observe) — a shard that cannot execute safely should not be
        // rewriting its checkpoint lineage either
        let cooling: Vec<bool> = self
            .health
            .iter()
            .map(|h| {
                matches!(
                    h,
                    ShardHealth::Quarantined { cooldown_drains, .. }
                        if *cooldown_drains > 0
                )
            })
            .collect();
        let mut results: Vec<Option<anyhow::Result<LaunderOutcome>>> =
            (0..self.shards.len()).map(|_| None).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for ((i, slot), res) in self
                .shards
                .iter_mut()
                .enumerate()
                .zip(results.iter_mut())
            {
                let Some(st) = slot.as_mut() else { continue };
                if cooling[i] {
                    continue;
                }
                // each shard consults ITS policy — due shards launder,
                // quiet shards are skipped without taking any lock
                let due = matches!(
                    st.system.plan_launder(&st.policy),
                    Ok(Some(_))
                );
                if !due {
                    continue;
                }
                let gen =
                    st.system.store().active_generation().unwrap_or(0);
                let key = format!("{id_prefix}-s{i}-g{gen}");
                handles.push((res, s.spawn(move || {
                    st.system.launder(&key, &st.policy, false)
                })));
            }
            for (res, h) in handles {
                *res = Some(h.join().unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("shard launder thread panicked"))
                }));
            }
        });
        // a failed launder is a shard-level infrastructure failure too:
        // quarantine it so the drain path stops routing erasure work at
        // a shard whose lineage machinery is misbehaving
        for (i, r) in results.iter().enumerate() {
            match r {
                Some(Err(e)) => {
                    self.note_shard_failure(i, format!("launder: {e:#}"));
                }
                Some(Ok(_)) => self.health[i] = ShardHealth::Healthy,
                None => {}
            }
        }
        // Invalidation fan-out: a committed launder swapped those
        // shards' lineage generations, so every replica mirroring one
        // must re-sync before the erasure is visible on the read path.
        // A failed re-sync is reported (the replica keeps serving its
        // old generation, watermarked stale) but never blocks the
        // shards' own outcomes.
        let swapped: Vec<u32> = results
            .iter()
            .enumerate()
            .filter_map(|(i, r)| match r {
                Some(Ok(o)) if o.executed => Some(i as u32),
                _ => None,
            })
            .collect();
        if !swapped.is_empty() && !self.replicas.is_empty() {
            let mut adopted = false;
            for shard in swapped {
                for (i, res) in self.sync_replicas(shard) {
                    match res {
                        Ok(_) => adopted = true,
                        Err(e) => eprintln!(
                            "replica {i} (shard {shard}) re-sync failed — \
                             it keeps serving its previous generation, \
                             watermarked stale: {e:#}"
                        ),
                    }
                }
            }
            if adopted {
                self.last_propagation_ms = Some(
                    crate::metrics::monotonic_now()
                        .saturating_duration_since(t0)
                        .as_secs_f64()
                        * 1e3,
                );
            }
        }
        results
            .into_iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|r| (i as u32, r)))
            .collect()
    }

    /// Uniform-ensemble utility: each shard model's held-out perplexity,
    /// averaged with equal weight across non-empty shards.
    pub fn utility_ensemble(&self) -> anyhow::Result<FleetUtility> {
        let mut per_shard = Vec::new();
        for (i, slot) in self.shards.iter().enumerate() {
            let Some(st) = slot else { continue };
            let sys = &st.system;
            if sys.eval_ids.is_empty() {
                continue;
            }
            let lc = per_example_loss_counts(
                sys.rt,
                ModelView::Base(&sys.state.params),
                &sys.corpus,
                &sys.eval_ids,
            )?;
            let (mut loss, mut count) = (0.0f64, 0.0f64);
            for (l, c) in lc {
                loss += l as f64;
                count += c as f64;
            }
            per_shard.push((i as u32, (loss / count.max(1.0)).exp()));
        }
        anyhow::ensure!(!per_shard.is_empty(), "fleet has no evaluable shard");
        // detlint: allow(float-reduce) — mean over a Vec in shard-index
        // order (deterministic); reported utility, not replayed state
        let fleet_ppl = per_shard.iter().map(|&(_, p)| p).sum::<f64>()
            / per_shard.len() as f64;
        Ok(FleetUtility {
            fleet_ppl,
            per_shard,
        })
    }

    /// Fleet status: topology + one row per shard (hashes, step
    /// counters, forgotten/laundered accounting, launder
    /// recommendation, lineage generation).
    pub fn status_json(&self) -> Json {
        let mut rows = Vec::new();
        for (i, slot) in self.shards.iter().enumerate() {
            let mut j = Json::obj();
            j.set("shard", i as u64);
            match &self.health[i] {
                ShardHealth::Healthy => {
                    j.set("health", "healthy");
                }
                ShardHealth::Quarantined {
                    reason,
                    failures,
                    cooldown_drains,
                } => {
                    j.set("health", "quarantined")
                        .set("quarantine_reason", reason.as_str())
                        .set("quarantine_failures", *failures as u64)
                        .set("retry_in_drains", *cooldown_drains as u64);
                }
            }
            match slot {
                None => {
                    j.set("empty", true);
                }
                Some(st) => {
                    let sys = &st.system;
                    let mut users: Vec<u32> =
                        sys.corpus.samples.iter().map(|s| s.user).collect();
                    users.sort_unstable();
                    users.dedup();
                    j.set("samples", sys.corpus.len())
                        .set("users", users.len())
                        .set("model_hash", sys.state.model_hash())
                        .set("optimizer_hash", sys.state.optimizer_hash())
                        .set("logical_step", sys.state.logical_step)
                        // online-ingest watermarks (per shard): the
                        // step the serving state covers, docs accepted
                        // through the interleave log, and how far the
                        // uncovered tail lags in optimizer steps
                        .set("trained_step", sys.state.logical_step)
                        .set("ingested_docs", sys.ingest.ingested_docs)
                        .set("tail_lag_steps", sys.tail_lag_steps())
                        .set("forgotten_pending", sys.forgotten.len())
                        .set("laundered_ids", sys.laundered_total())
                        .set(
                            "launder_recommended",
                            matches!(
                                sys.plan_launder(&st.policy),
                                Ok(Some(_))
                            ),
                        )
                        .set(
                            "generation",
                            sys.store().active_generation().unwrap_or(0),
                        );
                }
            }
            rows.push(j);
        }
        let mut reps = Vec::new();
        for (i, att) in self.replicas.iter().enumerate() {
            let mut j = att.replica.status_json();
            j.set("replica", i as u64).set("shard", att.shard);
            reps.push(j);
        }
        let mut out = Json::obj();
        out.set("n_shards", self.spec.n_shards)
            .set("salt_hex", format!("{:016x}", self.spec.salt))
            .set("total_samples", self.corpus.len())
            .set("quarantined_shards", self.quarantined_count() as u64)
            .set("shards", Json::Arr(rows))
            .set("replicas", Json::Arr(reps));
        match self.last_propagation_ms {
            Some(ms) => out.set("erasure_propagation_ms", ms),
            None => out.set("erasure_propagation_ms", Json::Null),
        };
        out
    }
}

/// Derive shard `shard`'s run config from the fleet template: its own
/// run dir, its topology pin, a decorrelated dataloader seed, and
/// (optionally) a step budget scaled to its corpus share.
fn shard_run_config(
    cfg: &FleetConfig,
    shard: u32,
    shard_len: usize,
    total_len: usize,
) -> RunConfig {
    let mut c = cfg.base.clone();
    c.run_dir = cfg.root.join(format!("shard-{shard:04}"));
    c.shard_pin = cfg.spec.pin_for(shard);
    c.auto_launder = false; // the fleet drain loop owns auto-laundering
    // decorrelate shard dataloader orders (pure function of the base
    // seed + shard index — reopening re-derives the same seed)
    c.run_seed = philox_u64(cfg.base.run_seed, 0xF1EE7 ^ shard as u64);
    if cfg.scale_steps && total_len > 0 {
        let share = shard_len as f64 / total_len as f64;
        c.steps = ((cfg.base.steps as f64 * share).ceil() as u32).max(2);
        c.warmup = c.warmup.min(c.steps / 2).max(1);
    }
    c
}
