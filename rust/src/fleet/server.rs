//! Fleet admin plane: the multi-shard analogue of [`crate::server`] —
//! line-delimited JSON over TCP, an async job queue with a coalescing
//! window, and per-shard laundering triggered from the drain loop.
//!
//! ## Protocol (one JSON object per line)
//!
//!   {"op":"fleet_status"}                         → topology + one row per shard
//!   {"op":"submit","id":"req-1","user":3}         → job id (routed to owning shards)
//!   {"op":"submit","id":"req-2","user":3,"shard":1} → shard-addressed override
//!   {"op":"poll","job":"job-1"}
//!   {"op":"jobs"}
//!   {"op":"plan","id":"req-3","user":4}           → fleet-plan dry run (max/total cost)
//!   {"op":"launder"}                              → launder every shard whose own
//!                                                   policy says it is due
//!   {"op":"utility"}                              → uniform-ensemble fleet ppl
//!   {"op":"shutdown"}
//!
//! A shard-addressed submit bypasses cross-shard scattering (closure
//! members owned by other shards are dropped) — an explicit operator
//! override; the default routed submit erases the full closure.
//!
//! The queue is in-memory (a fleet restart re-submits from the caller;
//! per-shard durability — WAL, manifests, forgotten sets — lives in the
//! shard run dirs themselves).

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::controller::ForgetRequest;
use crate::server::JobStatus;
use crate::util::json::{parse, Json};

use super::Fleet;

struct FleetJob {
    job_id: String,
    req: ForgetRequest,
    /// Shard-addressed override (None = route by ownership).
    shard: Option<u32>,
    status: JobStatus,
    result: Option<Json>,
}

/// Shared fleet-server state: protocol core + worker run against this.
pub struct FleetCtx<'a, 'rt> {
    pub fleet: &'a Mutex<Fleet<'rt>>,
    jobs: Mutex<Vec<FleetJob>>,
    cv: Condvar,
    seq: AtomicU64,
    pub shutdown: AtomicBool,
    pub coalesce_window: Duration,
}

fn recover<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|p| p.into_inner())
}

impl<'a, 'rt> FleetCtx<'a, 'rt> {
    pub fn new(fleet: &'a Mutex<Fleet<'rt>>) -> FleetCtx<'a, 'rt> {
        FleetCtx {
            fleet,
            jobs: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            seq: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            coalesce_window: Duration::from_millis(15),
        }
    }

    fn submit(&self, req: ForgetRequest, shard: Option<u32>) -> String {
        let job_id = format!("job-{}", self.seq.fetch_add(1, Ordering::SeqCst));
        recover(self.jobs.lock()).push(FleetJob {
            job_id: job_id.clone(),
            req,
            shard,
            status: JobStatus::Queued,
            result: None,
        });
        self.cv.notify_all();
        job_id
    }

    pub fn queued_len(&self) -> usize {
        recover(self.jobs.lock())
            .iter()
            .filter(|j| j.status == JobStatus::Queued)
            .count()
    }

    /// Jobs not yet completed (queued + running) — the backlog number,
    /// mirroring the single-system `JobQueue::pending_len`.
    pub fn pending_len(&self) -> usize {
        recover(self.jobs.lock())
            .iter()
            .filter(|j| {
                matches!(j.status, JobStatus::Queued | JobStatus::Running)
            })
            .count()
    }

    fn poll(&self, job_id: &str) -> Option<Json> {
        recover(self.jobs.lock())
            .iter()
            .find(|j| j.job_id == job_id)
            .map(job_json)
    }

    fn publish(&self, job_id: &str, status: JobStatus, result: Json) {
        let mut g = recover(self.jobs.lock());
        if let Some(j) = g.iter_mut().find(|j| j.job_id == job_id) {
            j.status = status;
            j.result = Some(result);
        }
    }

    fn take_queued(&self) -> Vec<(String, ForgetRequest, Option<u32>)> {
        let mut g = recover(self.jobs.lock());
        let mut out = Vec::new();
        for j in g.iter_mut() {
            if j.status == JobStatus::Queued {
                j.status = JobStatus::Running;
                out.push((j.job_id.clone(), j.req.clone(), j.shard));
            }
        }
        out
    }

    fn wait_for_work(&self) -> bool {
        let mut g = recover(self.jobs.lock());
        loop {
            if g.iter().any(|j| j.status == JobStatus::Queued) {
                return true;
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return false;
            }
            let (g2, _) =
                recover(self.cv.wait_timeout(g, Duration::from_millis(50)));
            g = g2;
        }
    }
}

fn job_json(j: &FleetJob) -> Json {
    let mut o = Json::obj();
    o.set("job", j.job_id.as_str())
        .set("request_id", j.req.id.as_str())
        .set(
            "shard",
            j.shard.map(Json::from).unwrap_or(Json::Null),
        )
        .set("status", j.status.as_str())
        .set("result", j.result.clone().unwrap_or(Json::Null));
    o
}

/// Drain every queued job as ONE fleet batch: routed jobs scatter by
/// ownership, shard-addressed jobs go only to their shard; every
/// touched shard receives its share as one coalesced `execute_batch`
/// call and shards run concurrently.  After the burst, shards whose own
/// `LaunderPolicy` flipped `launder_recommended` are laundered
/// (fleet-level auto-laundering, keyed off the burst's first job id).
/// Returns the number of jobs processed.
pub fn drain_fleet_once(ctx: &FleetCtx<'_, '_>) -> usize {
    let batch = ctx.take_queued();
    if batch.is_empty() {
        return 0;
    }
    match ctx.fleet.lock() {
        Err(_) => {
            for (job_id, _, _) in &batch {
                let mut r = Json::obj();
                r.set("ok", false).set("error", "fleet lock poisoned");
                ctx.publish(job_id, JobStatus::Failed, r);
            }
        }
        Ok(mut fleet) => {
            let reqs: Vec<ForgetRequest> =
                batch.iter().map(|(_, r, _)| r.clone()).collect();
            let routed: Result<Vec<_>, _> = batch
                .iter()
                .map(|(_, r, shard)| match shard {
                    Some(s) => fleet.route_to_shard(r, *s),
                    None => fleet.route(r),
                })
                .collect();
            let outcome = routed
                .and_then(|routed| fleet.execute_routed(&reqs, routed));
            match outcome {
                Err(e) => {
                    for (job_id, _, _) in &batch {
                        let mut r = Json::obj();
                        r.set("ok", false).set("error", format!("{e:#}"));
                        ctx.publish(job_id, JobStatus::Failed, r);
                    }
                }
                Ok(out) => {
                    for ((job_id, _, _), fo) in
                        batch.iter().zip(out.outcomes.into_iter())
                    {
                        // ok = no shard errored.  A duplicate-suppressed
                        // retry (every shard Ok with executed:false) is
                        // a SUCCESS — the erasure is committed — exactly
                        // like the single-system server's outcome_json;
                        // the per-shard/overall `executed` fields carry
                        // the suppression detail.
                        let ok =
                            fo.shards.iter().all(|s| s.outcome.is_ok());
                        let mut r = fo.to_json();
                        r.set("ok", ok);
                        if fo.shards.is_empty() {
                            r.set(
                                "note",
                                "empty closure — no owning shard",
                            );
                        }
                        let status = if fo
                            .shards
                            .iter()
                            .any(|s| s.outcome.is_err())
                        {
                            JobStatus::Failed
                        } else {
                            JobStatus::Done
                        };
                        ctx.publish(job_id, status, r);
                    }
                    // per-shard auto-laundering: each shard's OWN policy
                    // decides.  launder_due appends the shard's lineage
                    // generation to the key, so the burst-derived prefix
                    // is retry-idempotent yet never aliases across a
                    // restart of this in-memory job counter (a committed
                    // pass bumps the generation; an uncommitted one left
                    // no manifest key to collide with).
                    if fleet.auto_launder {
                        let prefix =
                            format!("auto-launder-{}", batch[0].0);
                        for (shard, res) in fleet.launder_due(&prefix) {
                            match res {
                                Ok(o) if o.executed => eprintln!(
                                    "fleet auto-launder: shard {shard} \
                                     gen {} ({} ids)",
                                    o.generation, o.laundered_now
                                ),
                                Ok(_) => {}
                                Err(e) => eprintln!(
                                    "fleet auto-launder shard {shard} \
                                     failed (state unchanged): {e:#}"
                                ),
                            }
                        }
                    }
                }
            }
        }
    }
    batch.len()
}

/// The fleet queue worker (mirrors [`crate::server::run_worker`]).
pub fn run_fleet_worker(ctx: &FleetCtx<'_, '_>) {
    while ctx.wait_for_work() {
        std::thread::sleep(ctx.coalesce_window);
        drain_fleet_once(ctx);
    }
}

/// Execute one fleet op (exposed for tests without sockets).
pub fn dispatch_fleet(line: &str, ctx: &FleetCtx<'_, '_>) -> Json {
    match dispatch_inner(line, ctx) {
        Ok(j) => j,
        Err(e) => {
            let mut j = Json::obj();
            j.set("ok", false).set("error", format!("{e:#}"));
            j
        }
    }
}

fn parse_request(req: &Json) -> anyhow::Result<ForgetRequest> {
    let id = req
        .get("id")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("request needs id"))?
        .to_string();
    Ok(ForgetRequest {
        id,
        user: req.get("user").and_then(|v| v.as_u64()).map(|u| u as u32),
        sample_ids: req
            .get("sample_ids")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
            .unwrap_or_default(),
        urgency: match req.get("urgency").and_then(|v| v.as_str()) {
            Some("high") => crate::controller::Urgency::High,
            _ => crate::controller::Urgency::Normal,
        },
    })
}

fn dispatch_inner(
    line: &str,
    ctx: &FleetCtx<'_, '_>,
) -> anyhow::Result<Json> {
    let req = parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let op = req
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing op"))?;
    let mut out = Json::obj();
    match op {
        "fleet_status" => {
            let fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::anyhow!("fleet lock poisoned"))?;
            out = fleet.status_json();
            out.set("ok", true)
                .set("queued_jobs", ctx.queued_len())
                // backlog incl. in-flight work: a job the worker marked
                // Running must not read as an empty queue
                .set("pending_jobs", ctx.pending_len());
        }
        "submit" => {
            if ctx.shutdown.load(Ordering::SeqCst) {
                anyhow::bail!("server is shutting down — submission refused");
            }
            let freq = parse_request(&req)?;
            let shard =
                req.get("shard").and_then(|v| v.as_u64()).map(|s| s as u32);
            if let Some(s) = shard {
                let fleet = ctx
                    .fleet
                    .lock()
                    .map_err(|_| anyhow::anyhow!("fleet lock poisoned"))?;
                anyhow::ensure!(
                    s < fleet.n_shards(),
                    "shard {s} out of range (fleet has {})",
                    fleet.n_shards()
                );
            }
            let job = ctx.submit(freq, shard);
            out.set("ok", true)
                .set("job", job.as_str())
                .set("status", "queued");
        }
        "poll" => {
            let job = req
                .get("job")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("poll needs job"))?;
            match ctx.poll(job) {
                Some(j) => {
                    out.set("ok", true);
                    if let Json::Obj(m) = &j {
                        for (k, v) in m {
                            out.set(k, v.clone());
                        }
                    }
                }
                None => anyhow::bail!("unknown job {job:?}"),
            }
        }
        "jobs" => {
            let g = recover(ctx.jobs.lock());
            out.set("ok", true)
                .set("jobs", Json::Arr(g.iter().map(job_json).collect()));
        }
        "plan" => {
            let freq = parse_request(&req)?;
            let fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::anyhow!("fleet lock poisoned"))?;
            out = fleet.plan(&freq)?.to_json();
            out.set("ok", true);
        }
        "launder" => {
            let id = req
                .get("id")
                .and_then(|v| v.as_str())
                .unwrap_or("fleet-launder")
                .to_string();
            let mut fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::anyhow!("fleet lock poisoned"))?;
            let mut rows = Vec::new();
            for (shard, res) in fleet.launder_due(&id) {
                let mut j = Json::obj();
                j.set("shard", shard);
                match res {
                    Ok(o) => {
                        j.set("ok", true)
                            .set("executed", o.executed)
                            .set("generation", o.generation)
                            .set("laundered_now", o.laundered_now);
                    }
                    Err(e) => {
                        j.set("ok", false).set("error", format!("{e:#}"));
                    }
                }
                rows.push(j);
            }
            out.set("ok", true).set("shards", Json::Arr(rows));
        }
        "utility" => {
            let fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::anyhow!("fleet lock poisoned"))?;
            let u = fleet.utility_ensemble()?;
            let mut rows = Vec::new();
            for (shard, ppl) in u.per_shard {
                let mut j = Json::obj();
                j.set("shard", shard).set("ppl", ppl);
                rows.push(j);
            }
            out.set("ok", true)
                .set("fleet_ppl", u.fleet_ppl)
                .set("per_shard", Json::Arr(rows));
        }
        "shutdown" => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            ctx.cv.notify_all();
            out.set("ok", true).set("shutting_down", true);
        }
        other => anyhow::bail!("unknown fleet op {other:?}"),
    }
    Ok(out)
}

/// Serve a fleet on `addr` until a shutdown op arrives.
pub fn serve_fleet(
    fleet: Arc<Mutex<Fleet<'_>>>,
    addr: &str,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("unlearn fleet admin server listening on {local}");
    let ctx = FleetCtx::new(&fleet);
    std::thread::scope(|s| {
        s.spawn(|| run_fleet_worker(&ctx));
        for stream in listener.incoming() {
            if ctx.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let ctx = &ctx;
                    s.spawn(move || {
                        if let Err(e) = handle_conn(stream, ctx, local) {
                            eprintln!("fleet connection error: {e:#}");
                        }
                    });
                }
                Err(e) => eprintln!("fleet accept error: {e:#}"),
            }
        }
    });
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    ctx: &FleetCtx<'_, '_>,
    local: std::net::SocketAddr,
) -> anyhow::Result<()> {
    // the transport loop (timeouts, line cap, shutdown poke) is shared
    // with the single-system server so hardening cannot drift
    crate::server::serve_line_conn(stream, local, &ctx.shutdown, |line| {
        dispatch_fleet(line, ctx)
    })
}
