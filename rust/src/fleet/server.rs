//! Fleet admin plane: the multi-shard analogue of [`crate::server`] —
//! line-delimited JSON over TCP, an async job queue with a coalescing
//! window, and per-shard laundering triggered from the drain loop.
//!
//! ## Protocol (one JSON object per line)
//!
//!   {"op":"fleet_status"}                         → topology + one row per shard
//!   {"op":"submit","id":"req-1","user":3}         → job id (routed to owning shards)
//!   {"op":"submit","id":"req-2","user":3,"shard":1} → shard-addressed override
//!   {"op":"poll","job":"job-1"}
//!   {"op":"jobs"}
//!   {"op":"plan","id":"req-3","user":4}           → fleet-plan dry run (max/total cost)
//!   {"op":"launder"}                              → launder every shard whose own
//!                                                   policy says it is due
//!   {"op":"ingest","id":"d1","user":9,"texts":["…"],"train_steps":2}
//!                                                 → docs + train-increment on the
//!                                                   owning shard alone
//!   {"op":"utility"}                              → uniform-ensemble fleet ppl
//!   {"op":"shutdown"}
//!
//! A shard-addressed submit bypasses cross-shard scattering (closure
//! members owned by other shards are dropped) — an explicit operator
//! override; the default routed submit erases the full closure.
//!
//! ## Durability
//!
//! The queue is the shared [`crate::server::JobQueue`], instantiated
//! over the fleet's shard-addressable payload — the durability
//! machinery (fsync-before-ack, torn-final-line tolerance, seq
//! high-water compaction) exists exactly once for both servers.
//! [`serve_fleet`] puts the WAL at `<fleet root>/jobs.wal`: an acked
//! fleet submit survives a crash and is re-queued under its original
//! job id on restart, exactly like the single-system server.
//!
//! ## Degraded mode
//!
//! Shard isolation lives in [`super::Fleet`]: a shard whose batch (or
//! launder) errors is quarantined with drain-counted backoff, its jobs
//! get typed `quarantined` outcomes, and healthy shards keep draining.
//! `fleet_status` carries per-shard `health` rows.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::controller::{ForgetRequest, UnlearnError};
use crate::server::{scan_err, JobPayload, JobQueue, JobStatus};
use crate::util::json::{parse, Json};
use crate::util::json_scan;

use super::Fleet;

/// The fleet queue payload: a forget request plus the optional
/// shard-addressed routing override.
#[derive(Debug, Clone)]
pub struct FleetJob {
    pub req: ForgetRequest,
    /// Shard-addressed override (None = route by ownership).
    pub shard: Option<u32>,
}

impl JobPayload for FleetJob {
    fn request_id(&self) -> &str {
        &self.req.id
    }

    fn kind(&self) -> &'static str {
        "forget"
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", "forget")
            .set("id", self.req.id.as_str())
            .set(
                "user",
                self.req.user.map(Json::from).unwrap_or(Json::Null),
            )
            .set(
                "sample_ids",
                Json::Arr(
                    self.req.sample_ids.iter().map(|&s| s.into()).collect(),
                ),
            )
            .set(
                "urgency",
                match self.req.urgency {
                    crate::controller::Urgency::High => "high",
                    crate::controller::Urgency::Normal => "normal",
                },
            )
            .set(
                "shard",
                self.shard.map(Json::from).unwrap_or(Json::Null),
            );
        j
    }

    fn from_json(j: &Json) -> anyhow::Result<FleetJob> {
        Ok(FleetJob {
            req: crate::server::parse_request(j)?,
            shard: j.get("shard").and_then(|v| v.as_u64()).map(|s| s as u32),
        })
    }

    /// Lazy-scan mirror of [`JobPayload::from_json`] — recovery of a
    /// large fleet backlog never builds a tree per WAL record.
    fn from_raw(raw: &[u8]) -> anyhow::Result<FleetJob> {
        Ok(FleetJob {
            req: crate::server::parse_request_scan(raw)?,
            shard: json_scan::scan_u64(raw, "shard")
                .map_err(scan_err)?
                .map(|s| s as u32),
        })
    }
}

/// Shared fleet-server state: protocol core + worker run against this.
pub struct FleetCtx<'a, 'rt> {
    pub fleet: &'a Mutex<Fleet<'rt>>,
    pub jobs: JobQueue<FleetJob>,
    pub shutdown: AtomicBool,
    pub coalesce_window: Duration,
}

impl<'a, 'rt> FleetCtx<'a, 'rt> {
    /// In-memory queue (tests; callers that re-submit after a restart).
    pub fn new(fleet: &'a Mutex<Fleet<'rt>>) -> FleetCtx<'a, 'rt> {
        Self::build(fleet, JobQueue::new())
    }

    /// Durable queue: accepted jobs are WAL-persisted before the ack
    /// and re-queued — original ids preserved — when the fleet root is
    /// reopened.
    pub fn with_jobs_wal(
        fleet: &'a Mutex<Fleet<'rt>>,
        wal_path: &std::path::Path,
    ) -> anyhow::Result<FleetCtx<'a, 'rt>> {
        Ok(Self::build(fleet, JobQueue::with_wal(wal_path)?))
    }

    fn build(
        fleet: &'a Mutex<Fleet<'rt>>,
        jobs: JobQueue<FleetJob>,
    ) -> FleetCtx<'a, 'rt> {
        FleetCtx {
            fleet,
            jobs,
            shutdown: AtomicBool::new(false),
            coalesce_window: Duration::from_millis(15),
        }
    }

    pub fn queued_len(&self) -> usize {
        self.jobs.queued_len()
    }

    /// Jobs not yet completed (queued + running) — the backlog number,
    /// mirroring the single-system `JobQueue::pending_len`.
    pub fn pending_len(&self) -> usize {
        self.jobs.pending_len()
    }
}

/// Drain every queued job as ONE fleet batch: routed jobs scatter by
/// ownership, shard-addressed jobs go only to their shard; every
/// touched shard receives its share as one coalesced `execute_batch`
/// call and shards run concurrently.  After the burst, shards whose own
/// `LaunderPolicy` flipped `launder_recommended` are laundered
/// (fleet-level auto-laundering, keyed off the burst's first job id).
/// Returns the number of jobs processed.
pub fn drain_fleet_once(ctx: &FleetCtx<'_, '_>) -> usize {
    let batch = ctx.jobs.take_queued();
    if batch.is_empty() {
        return 0;
    }
    match ctx.fleet.lock() {
        Err(_) => {
            // typed poison containment, same taxonomy as the
            // single-system server: the fleet write plane fails closed
            // with a machine-readable kind, not a stringly error
            let err = UnlearnError::LockPoisoned;
            for (job_id, _) in &batch {
                let mut r = Json::obj();
                r.set("ok", false)
                    .set("error", err.to_string())
                    .set("error_kind", err.kind());
                ctx.jobs.publish(job_id, JobStatus::Failed, r);
            }
        }
        Ok(mut fleet) => {
            let reqs: Vec<ForgetRequest> =
                batch.iter().map(|(_, j)| j.req.clone()).collect();
            let routed: Result<Vec<_>, _> = batch
                .iter()
                .map(|(_, j)| match j.shard {
                    Some(s) => fleet.route_to_shard(&j.req, s),
                    None => fleet.route(&j.req),
                })
                .collect();
            let outcome = routed
                .and_then(|routed| fleet.execute_routed(&reqs, routed));
            match outcome {
                Err(e) => {
                    for (job_id, _) in &batch {
                        let mut r = Json::obj();
                        r.set("ok", false).set("error", format!("{e:#}"));
                        ctx.jobs.publish(job_id, JobStatus::Failed, r);
                    }
                }
                Ok(out) => {
                    for ((job_id, _), fo) in
                        batch.iter().zip(out.outcomes.into_iter())
                    {
                        // ok = no shard errored.  A duplicate-suppressed
                        // retry (every shard Ok with executed:false) is
                        // a SUCCESS — the erasure is committed — exactly
                        // like the single-system server's outcome_json;
                        // the per-shard/overall `executed` fields carry
                        // the suppression detail.  A quarantined shard's
                        // share fails with "status":"quarantined" so the
                        // caller can tell "skipped by isolation" from
                        // "attempted and failed".
                        let ok =
                            fo.shards.iter().all(|s| s.outcome.is_ok());
                        let mut r = fo.to_json();
                        r.set("ok", ok);
                        if fo.shards.is_empty() {
                            r.set(
                                "note",
                                "empty closure — no owning shard",
                            );
                        }
                        let status = if fo
                            .shards
                            .iter()
                            .any(|s| s.outcome.is_err())
                        {
                            JobStatus::Failed
                        } else {
                            JobStatus::Done
                        };
                        ctx.jobs.publish(job_id, status, r);
                    }
                    // per-shard auto-laundering: each shard's OWN policy
                    // decides.  launder_due appends the shard's lineage
                    // generation to the key, so the burst-derived prefix
                    // is retry-idempotent yet never aliases across a
                    // restart of the job counter (a committed pass bumps
                    // the generation; an uncommitted one left no
                    // manifest key to collide with).
                    if fleet.auto_launder {
                        let prefix =
                            format!("auto-launder-{}", batch[0].0);
                        for (shard, res) in fleet.launder_due(&prefix) {
                            match res {
                                Ok(o) if o.executed => eprintln!(
                                    "fleet auto-launder: shard {shard} \
                                     gen {} ({} ids)",
                                    o.generation, o.laundered_now
                                ),
                                Ok(_) => {}
                                Err(e) => eprintln!(
                                    "fleet auto-launder shard {shard} \
                                     failed (state unchanged): {e:#}"
                                ),
                            }
                        }
                    }
                }
            }
        }
    }
    batch.len()
}

/// The fleet queue worker (mirrors [`crate::server::run_worker`]): a
/// panic inside a drain fails the claimed jobs loudly instead of
/// stranding them as running-forever while the queue keeps acking.
pub fn run_fleet_worker(ctx: &FleetCtx<'_, '_>) {
    while ctx.jobs.wait_for_burst(ctx.coalesce_window) {
        let drained = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| drain_fleet_once(ctx)),
        );
        if drained.is_err() {
            ctx.jobs.fail_running(
                "worker panicked during drain (fleet lock poisoned — \
                 fleet write plane fails closed)",
            );
        }
    }
}

/// Execute one fleet op (exposed for tests without sockets).
pub fn dispatch_fleet(line: &str, ctx: &FleetCtx<'_, '_>) -> Json {
    match dispatch_inner(line, ctx) {
        Ok(j) => j,
        Err(e) => {
            let mut j = Json::obj();
            j.set("ok", false).set("error", format!("{e:#}"));
            if let Some(ue) = e.downcast_ref::<UnlearnError>() {
                j.set("error_kind", ue.kind());
            }
            j
        }
    }
}

fn dispatch_inner(
    line: &str,
    ctx: &FleetCtx<'_, '_>,
) -> anyhow::Result<Json> {
    // Hot path: lazy scans over the raw bytes, like the single-system
    // server — `fleet_status`/`submit`/`poll`/`jobs`/`launder`/
    // `utility`/`shutdown` never build a tree; `plan` and `ingest`
    // (cold, take the fleet lock) re-parse the validated line.
    let b = line.as_bytes();
    let op = json_scan::scan_str(b, "op")
        .map_err(scan_err)?
        .ok_or_else(|| anyhow::anyhow!("missing op"))?;
    let mut out = Json::obj();
    match op.as_ref() {
        "fleet_status" => {
            let fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            out = fleet.status_json();
            out.set("ok", true)
                .set("queued_jobs", ctx.queued_len())
                // backlog incl. in-flight work: a job the worker marked
                // Running must not read as an empty queue
                .set("pending_jobs", ctx.pending_len())
                .set(
                    "jobs_wal_bytes",
                    ctx.jobs
                        .wal_bytes()
                        .map(Json::from)
                        .unwrap_or(Json::Null),
                );
        }
        "submit" => {
            let freq = crate::server::parse_request_scan(b)?;
            let shard = json_scan::scan_u64(b, "shard")
                .map_err(scan_err)?
                .map(|s| s as u32);
            if let Some(s) = shard {
                let fleet = ctx.fleet.lock().map_err(|_| {
                    anyhow::Error::new(UnlearnError::LockPoisoned)
                })?;
                anyhow::ensure!(
                    s < fleet.n_shards(),
                    "shard {s} out of range (fleet has {})",
                    fleet.n_shards()
                );
            }
            // the queue refuses after close() (shutdown) and errors when
            // the durability promise cannot be made (WAL write failed)
            let job = ctx
                .jobs
                .submit(FleetJob { req: freq, shard })?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "server is shutting down — submission refused"
                    )
                })?;
            out.set("ok", true)
                .set("job", job.as_str())
                .set("status", "queued");
        }
        "poll" => {
            let job = json_scan::scan_str(b, "job")
                .map_err(scan_err)?
                .ok_or_else(|| anyhow::anyhow!("poll needs job"))?;
            match ctx.jobs.poll(&job) {
                Some(j) => {
                    out.set("ok", true);
                    if let Json::Obj(m) = &j {
                        for (k, v) in m {
                            out.set(k, v.clone());
                        }
                    }
                }
                None => anyhow::bail!("unknown job {job:?}"),
            }
        }
        "jobs" => {
            out.set("ok", true).set("jobs", ctx.jobs.jobs_json());
        }
        "plan" => {
            let req =
                parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
            let freq = crate::server::parse_request(&req)?;
            let fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            out = fleet.plan(&freq)?.to_json();
            out.set("ok", true);
        }
        "launder" => {
            let id = json_scan::scan_str(b, "id")
                .map_err(scan_err)?
                .map(|s| s.into_owned())
                .unwrap_or_else(|| "fleet-launder".to_string());
            let mut fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            let mut rows = Vec::new();
            for (shard, res) in fleet.launder_due(&id) {
                let mut j = Json::obj();
                j.set("shard", shard);
                match res {
                    Ok(o) => {
                        j.set("ok", true)
                            .set("executed", o.executed)
                            .set("generation", o.generation)
                            .set("laundered_now", o.laundered_now);
                    }
                    Err(e) => {
                        j.set("ok", false).set("error", format!("{e:#}"));
                    }
                }
                rows.push(j);
            }
            out.set("ok", true).set("shards", Json::Arr(rows));
        }
        "ingest" => {
            // Online ingest: docs + bounded train-increment on the
            // owning shard, inline under the fleet lock (cold,
            // low-rate op — the fleet job payload stays forget-only).
            // Tree-parse: texts[] has no lazy scan.
            let req =
                parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
            let id = req
                .get("id")
                .and_then(|v| v.as_str())
                .unwrap_or("fleet-ingest")
                .to_string();
            let user = req
                .get("user")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow::anyhow!("ingest needs user"))?
                as u32;
            let texts: Vec<String> = req
                .get("texts")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("ingest needs texts[]"))?
                .iter()
                .map(|t| {
                    t.as_str().map(str::to_string).ok_or_else(|| {
                        anyhow::anyhow!("ingest texts[] non-string")
                    })
                })
                .collect::<anyhow::Result<_>>()?;
            let train_steps = req
                .get("train_steps")
                .and_then(|v| v.as_u64())
                .unwrap_or(1) as u32;
            let mut fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            let (shard, o) = fleet.ingest(&id, user, &texts, train_steps)?;
            out.set("ok", true)
                .set("shard", shard)
                .set("executed", o.executed)
                .set("docs", texts.len() as u64)
                .set("from_step", o.step.from_step as u64)
                .set("n_steps", o.step.n_steps as u64)
                .set("updates_applied", o.updates_applied as u64);
        }
        "utility" => {
            let fleet = ctx
                .fleet
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            let u = fleet.utility_ensemble()?;
            let mut rows = Vec::new();
            for (shard, ppl) in u.per_shard {
                let mut j = Json::obj();
                j.set("shard", shard).set("ppl", ppl);
                rows.push(j);
            }
            out.set("ok", true)
                .set("fleet_ppl", u.fleet_ppl)
                .set("per_shard", Json::Arr(rows));
        }
        "shutdown" => {
            ctx.jobs.close(); // refuse new submissions, wake the worker
            ctx.shutdown.store(true, Ordering::SeqCst);
            out.set("ok", true).set("shutting_down", true);
        }
        other => anyhow::bail!("unknown fleet op {other:?}"),
    }
    Ok(out)
}

/// Serve a fleet on `addr` until a shutdown op arrives.  The jobs WAL
/// lives at `<fleet root>/jobs.wal`: reopening the fleet root recovers
/// every accepted-but-incomplete job under its original id, so a crash
/// between ack and drain loses nothing.
pub fn serve_fleet(
    fleet: Arc<Mutex<Fleet<'_>>>,
    addr: &str,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("unlearn fleet admin server listening on {local}");
    let wal_path = {
        let f = fleet
            .lock()
            .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
        f.root.join("jobs.wal")
    };
    let ctx = FleetCtx::with_jobs_wal(&fleet, &wal_path)?;
    let recovered = ctx.jobs.queued_len();
    if recovered > 0 {
        eprintln!(
            "recovered {recovered} pending fleet job(s) from {}",
            wal_path.display()
        );
    }
    // the connection layer (poll loop, line cap, buffer ownership,
    // shutdown flush) is shared with the single-system server so the
    // transport hardening cannot drift between the two planes
    let result = std::thread::scope(|s| {
        s.spawn(|| run_fleet_worker(&ctx));
        let r = crate::server::serve_event_loop(
            listener,
            &ctx.shutdown,
            |line| dispatch_fleet(line, &ctx),
        );
        // release the worker for its final drain even if the loop
        // returned on a setup error rather than a shutdown op
        ctx.jobs.close();
        ctx.shutdown.store(true, Ordering::SeqCst);
        r
    });
    result
}
