//! Curvature-guided audited hot path (paper §4.2(iii), Eq. 5, Alg. A.4).
//!
//! Maintains a **diagonal Fisher cache** `F̂[i] = E[g_i²]` accumulated
//! from per-microbatch gradients, and applies damped curvature-
//! preconditioned **anti-updates**
//!
//! ```text
//! δθ = +η (F̂ + λI)^{-1} Σ_{(x,y)∈cl(F)} ∇θ ℓ(θ; x, y)
//! ```
//!
//! with a trust region ‖δθ‖_F̂ ≤ τ and backtracking (halve η until the
//! step fits and the forget loss increases), followed by a short
//! retain-tune (reduction=sum).  Always audit-gated; the controller
//! escalates to exact replay on failure.

use std::collections::HashSet;

use crate::checkpoint::TrainState;
use crate::data::corpus::Corpus;
use crate::runtime::Runtime;
use crate::trainer::{accumulate, build_microbatch_tensors};

/// Diagonal Fisher approximation over the flat parameter vector.
#[derive(Debug, Clone)]
pub struct FisherCache {
    /// Running mean of squared gradients.
    pub diag: Vec<f32>,
    samples: u64,
}

impl FisherCache {
    pub fn new(param_count: usize) -> FisherCache {
        FisherCache {
            diag: vec![0.0; param_count],
            samples: 0,
        }
    }

    /// Accumulate one gradient sample (running mean of g²).
    pub fn update(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.diag.len());
        self.samples += 1;
        let w = 1.0 / self.samples as f32;
        for (d, g) in self.diag.iter_mut().zip(grad) {
            *d += w * (g * g - *d);
        }
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Estimate the cache from the current model over a sample of IDs.
    pub fn estimate(
        rt: &Runtime,
        corpus: &Corpus,
        params: &[f32],
        ids: &[u64],
        seed: u64,
    ) -> anyhow::Result<FisherCache> {
        let man = &rt.manifest;
        let mut cache = FisherCache::new(man.param_count);
        for (i, chunk) in ids.chunks(man.batch).enumerate() {
            let (tokens, mask, retained) = build_microbatch_tensors(
                corpus,
                chunk,
                man.batch,
                man.seq_len,
                |_| false,
                false,
            )?;
            if retained == 0 {
                continue;
            }
            let out = rt.train_step(
                params,
                &tokens,
                &mask,
                (seed as i32).wrapping_add(i as i32),
            )?;
            cache.update(&out.grad);
        }
        Ok(cache)
    }
}

/// Anti-update hyperparameters (Alg. A.4 inputs).
#[derive(Debug, Clone)]
pub struct HotPathParams {
    /// Anti-update step size η.
    pub eta: f32,
    /// Damping λ.
    pub damping: f32,
    /// Trust-region radius τ in the F̂-norm.
    pub trust_radius: f32,
    /// Max anti-update steps S.
    pub max_steps: usize,
    /// Retain-tune steps T_R.
    pub retain_steps: usize,
    /// Retain-tune LR η_R.
    pub retain_lr: f32,
    /// Max backtracking halvings per anti-step.
    pub max_backtracks: usize,
}

impl Default for HotPathParams {
    fn default() -> Self {
        HotPathParams {
            eta: 0.5,
            damping: 1e-4,
            trust_radius: 1.0,
            max_steps: 4,
            retain_steps: 8,
            retain_lr: 1e-4,
            max_backtracks: 6,
        }
    }
}

/// What the hot path did (manifest details + EXPERIMENTS.md rows).
#[derive(Debug, Clone)]
pub struct HotPathOutcome {
    pub anti_steps_applied: usize,
    pub backtracks: usize,
    pub forget_loss_before: f32,
    pub forget_loss_after: f32,
    pub retain_steps: usize,
}

/// Sum loss over the closure under current params.
fn forget_loss(
    rt: &Runtime,
    corpus: &Corpus,
    params: &[f32],
    ids: &[u64],
    seed: i32,
) -> anyhow::Result<f32> {
    let man = &rt.manifest;
    let mut total = 0.0f32;
    for chunk in ids.chunks(man.batch) {
        let (tokens, mask, retained) = build_microbatch_tensors(
            corpus, chunk, man.batch, man.seq_len, |_| false, false,
        )?;
        if retained == 0 {
            continue;
        }
        let out = rt.train_step(params, &tokens, &mask, seed)?;
        total += out.loss_sum;
    }
    Ok(total)
}

/// Gradient of the forget loss (summed over cl(F)).
fn forget_grad(
    rt: &Runtime,
    corpus: &Corpus,
    params: &[f32],
    ids: &[u64],
    seed: i32,
) -> anyhow::Result<Vec<f32>> {
    let man = &rt.manifest;
    let mut acc = vec![0.0f32; man.param_count];
    for chunk in ids.chunks(man.batch) {
        let (tokens, mask, retained) = build_microbatch_tensors(
            corpus, chunk, man.batch, man.seq_len, |_| false, false,
        )?;
        if retained == 0 {
            continue;
        }
        let out = rt.train_step(params, &tokens, &mask, seed)?;
        accumulate(&mut acc, &out.grad);
    }
    Ok(acc)
}

/// ‖δ‖_F̂ = sqrt(Σ F̂_i δ_i²)
fn fisher_norm(fisher: &FisherCache, delta: &[f32], damping: f32) -> f32 {
    delta
        .iter()
        .zip(&fisher.diag)
        .map(|(d, f)| (f + damping) * d * d)
        // detlint: allow(float-reduce) — sequential slice iteration IS the
        // pinned left-fold order (index order, Lemma A.3); operands come
        // from a slice, never from hash iteration
        .sum::<f32>()
        .sqrt()
}

/// HOTPATHUNLEARN (Alg. A.4): curvature anti-update + retain-tune.
/// Mutates `state.params` (optimizer moments untouched — this is a
/// temporary audit-equivalent model, not a training continuation).
pub fn hot_path_unlearn(
    rt: &Runtime,
    corpus: &Corpus,
    state: &mut TrainState,
    fisher: &FisherCache,
    closure: &HashSet<u64>,
    retain_ids: &[u64],
    hp: &HotPathParams,
    seed: u64,
) -> anyhow::Result<HotPathOutcome> {
    let ids: Vec<u64> = {
        let mut v: Vec<u64> = closure.iter().copied().collect();
        v.sort_unstable();
        v
    };
    anyhow::ensure!(!ids.is_empty(), "empty forget closure");
    let seed32 = seed as i32;
    let before = forget_loss(rt, corpus, &state.params, &ids, seed32)?;
    let mut current = before;
    let mut applied = 0usize;
    let mut backtracks = 0usize;

    for s in 0..hp.max_steps {
        let g = forget_grad(rt, corpus, &state.params, &ids, seed32 + s as i32)?;
        // δθ = +η (F̂+λI)^{-1} g  (ascent on the forget loss)
        let mut eta = hp.eta;
        let mut accepted = false;
        for _ in 0..=hp.max_backtracks {
            let delta: Vec<f32> = g
                .iter()
                .zip(&fisher.diag)
                .map(|(gi, fi)| eta * gi / (fi + hp.damping))
                .collect();
            if fisher_norm(fisher, &delta, hp.damping) > hp.trust_radius {
                eta *= 0.5;
                backtracks += 1;
                continue;
            }
            let cand: Vec<f32> = state
                .params
                .iter()
                .zip(&delta)
                .map(|(p, d)| p + d)
                .collect();
            let cand_loss = forget_loss(rt, corpus, &cand, &ids, seed32)?;
            if cand_loss.is_finite() && cand_loss > current {
                state.params = cand;
                current = cand_loss;
                accepted = true;
                applied += 1;
                break;
            }
            eta *= 0.5;
            backtracks += 1;
        }
        if !accepted {
            break; // trust region exhausted
        }
    }

    // short retain-tune (reduction=sum), optimizer-stateless SGD-like
    // pass through AdamW with fresh moments at low LR
    let mut m = vec![0.0f32; state.params.len()];
    let mut v = vec![0.0f32; state.params.len()];
    let mut rng = crate::util::rng::SplitMix64::new(seed ^ 0x9E7A);
    for t in 0..hp.retain_steps {
        let take = rt.manifest.batch.min(retain_ids.len());
        let chunk: Vec<u64> = (0..take)
            .map(|_| retain_ids[rng.below(retain_ids.len() as u64) as usize])
            .collect();
        let (tokens, mask, retained) = build_microbatch_tensors(
            corpus, &chunk, rt.manifest.batch, rt.manifest.seq_len,
            |_| false, false,
        )?;
        if retained == 0 {
            continue;
        }
        let out = rt.train_step(&state.params, &tokens, &mask,
                                seed32 + 1000 + t as i32)?;
        let (p, m2, v2) = rt.adamw_update(
            &state.params,
            &out.grad,
            &m,
            &v,
            t as i32 + 1,
            hp.retain_lr,
        )?;
        state.params = p;
        m = m2;
        v = v2;
    }

    Ok(HotPathOutcome {
        anti_steps_applied: applied,
        backtracks,
        forget_loss_before: before,
        forget_loss_after: current,
        retain_steps: hp.retain_steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn fisher_running_mean() {
        let mut f = FisherCache::new(3);
        f.update(&[1.0, 2.0, 0.0]);
        f.update(&[3.0, 0.0, 0.0]);
        assert_eq!(f.samples(), 2);
        assert!((f.diag[0] - 5.0).abs() < 1e-6); // (1+9)/2
        assert!((f.diag[1] - 2.0).abs() < 1e-6); // (4+0)/2
        assert_eq!(f.diag[2], 0.0);
    }

    #[test]
    fn fisher_norm_weights_by_curvature() {
        let mut f = FisherCache::new(2);
        f.update(&[2.0, 0.0]);
        let d = vec![1.0, 1.0];
        let n = fisher_norm(&f, &d, 0.0);
        assert!((n - 2.0).abs() < 1e-6); // sqrt(4*1 + 0*1)
    }

    #[test]
    fn prop_fisher_diag_nonnegative() {
        for_all("fisher diag >= 0", |rng| {
            let n = rng.below(100) as usize + 1;
            let mut f = FisherCache::new(n);
            for _ in 0..rng.below(10) + 1 {
                let g = crate::util::prop::f32_vec(rng, n, 3.0);
                f.update(&g);
            }
            assert!(f.diag.iter().all(|&x| x >= 0.0));
        });
    }
}
