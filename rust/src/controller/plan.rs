//! Pure planning layer (paper Alg. A.7 as a *decision*, not an action).
//!
//! [`Planner::plan`] maps a read-only [`SystemView`] plus a
//! [`ForgetRequest`](super::ForgetRequest) to an [`UnlearnPlan`]: an
//! ordered fallback chain of typed [`PlanStep`]s, each carrying a
//! [`CostEstimate`] derived from the ring budget, the WAL tail length
//! and measured graph timings — the paper's Table 3/8 storage/latency
//! budgets as queryable API objects.  Planning performs no side effects
//! and mutates nothing; the audit-gated state transitions live in
//! [`super::execute`].
//!
//! Failures are a typed taxonomy ([`UnlearnError`]) instead of strings:
//! fatal ones abort planning (`Err`), non-fatal ones are recorded as
//! `notes` — the escalation edges of Alg. A.7 surfaced at plan time.

use std::collections::HashSet;
use std::fmt;

use crate::adapters::AdapterRegistry;
use crate::curvature::HotPathParams;
use crate::data::corpus::Corpus;
use crate::deltas::RingBudget;
use crate::manifest::{ActionKind, ForgetManifest};
use crate::neardup::{expand_closure, ClosureParams, HammingIndex};
use crate::replay::{offending_steps, tail_len};
use crate::util::json::Json;
use crate::wal::{IdMap, WalRecord};

use super::{ForgetRequest, Urgency};

/// Typed failure/escalation taxonomy (replaces the stringly
/// `escalations: Vec<String>` of the monolithic controller).
#[derive(Debug, Clone, PartialEq)]
pub enum UnlearnError {
    /// Idempotency key already executed (duplicate suppression).
    DuplicateRequest { id: String },
    /// The request expands to an empty forget closure.
    EmptyClosure,
    /// A cohort adapter refused deletion (e.g. it was merged).
    AdapterDeleteFailed { cohort: u32, reason: String },
    /// A path executed but its audit gate failed — escalate.
    AuditFailed { path: ActionKind },
    /// The offending tail is longer than the delta ring's reach.
    RingWindowMiss { needed: usize, available: usize },
    /// The serving state has diverged from the logged trajectory
    /// (a prior revert/hot-path/replay) — ring patches no longer apply.
    RingDiverged,
    /// Urgent request but no Fisher cache — hot path unavailable.
    NoFisherCache,
    /// No stored checkpoint at or before the rebuild target.
    NoCheckpoint { target: u32 },
    /// Laundering requested but the cumulative forgotten set is empty
    /// (or never influenced the base) — nothing to compact.
    NothingToLaunder,
    /// Laundering requested while a train-increment is in flight: the
    /// WAL tail beyond the interleave log's last commit is provisional
    /// (a crash truncates it), so a lineage rewritten against it could
    /// adopt steps that are later rolled back.  Retry after the
    /// increment commits.
    IngestInFlight,
    /// The admin-plane lock was poisoned by a panicked holder.
    LockPoisoned,
    /// Every planned step was attempted and failed its gate.
    PlanExhausted,
    Internal(String),
}

impl UnlearnError {
    /// Stable machine-readable discriminator (wire format + tests).
    pub fn kind(&self) -> &'static str {
        match self {
            UnlearnError::DuplicateRequest { .. } => "duplicate_request",
            UnlearnError::EmptyClosure => "empty_closure",
            UnlearnError::AdapterDeleteFailed { .. } => "adapter_delete_failed",
            UnlearnError::AuditFailed { .. } => "audit_failed",
            UnlearnError::RingWindowMiss { .. } => "ring_window_miss",
            UnlearnError::RingDiverged => "ring_diverged",
            UnlearnError::NoFisherCache => "no_fisher_cache",
            UnlearnError::NoCheckpoint { .. } => "no_checkpoint",
            UnlearnError::NothingToLaunder => "nothing_to_launder",
            UnlearnError::IngestInFlight => "ingest_in_flight",
            UnlearnError::LockPoisoned => "lock_poisoned",
            UnlearnError::PlanExhausted => "plan_exhausted",
            UnlearnError::Internal(_) => "internal",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", self.kind()).set("detail", self.to_string());
        j
    }
}

impl fmt::Display for UnlearnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            UnlearnError::DuplicateRequest { id } => {
                write!(f, "duplicate idempotency key {id:?}")
            }
            UnlearnError::EmptyClosure => write!(f, "empty forget closure"),
            UnlearnError::AdapterDeleteFailed { cohort, reason } => {
                write!(f, "adapter delete failed for cohort {cohort}: {reason}")
            }
            UnlearnError::AuditFailed { path } => {
                write!(f, "{} audit failed — escalating", path.as_str())
            }
            UnlearnError::RingWindowMiss { needed, available } => write!(
                f,
                "ring window miss: need {needed} steps, {available} available"
            ),
            UnlearnError::RingDiverged => write!(
                f,
                "serving state diverged from the logged trajectory — \
                 ring patches inapplicable"
            ),
            UnlearnError::NoFisherCache => {
                write!(f, "no fisher cache — hot path unavailable")
            }
            UnlearnError::NoCheckpoint { target } => write!(
                f,
                "no checkpoint at or before step {target} — cannot satisfy \
                 the exactness precondition (fail-closed)"
            ),
            UnlearnError::NothingToLaunder => write!(
                f,
                "cumulative forgotten set is empty or never influenced \
                 the base — nothing to launder"
            ),
            UnlearnError::IngestInFlight => write!(
                f,
                "a train-increment is in flight — its WAL tail is \
                 provisional until the interleave log commits; retry \
                 laundering after the increment completes"
            ),
            UnlearnError::LockPoisoned => {
                write!(f, "system lock poisoned by a panicked holder")
            }
            UnlearnError::PlanExhausted => {
                write!(f, "every planned path failed its audit gate")
            }
            UnlearnError::Internal(s) => write!(f, "internal: {s}"),
        }
    }
}

impl std::error::Error for UnlearnError {}

/// Predicted cost of one plan step (Table 3/8 budgets, queryable).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostEstimate {
    /// Microbatch executions predicted to be re-run.
    pub replay_steps: u32,
    /// Bytes predicted to be read/written (patches, checkpoints, params).
    pub bytes_touched: u64,
    /// Predicted wall-time, from measured per-call means (0.0 when no
    /// measurement exists yet — estimates never fabricate numbers).
    pub est_wall_secs: f64,
}

impl CostEstimate {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("replay_steps", self.replay_steps as u64)
            .set("bytes_touched", self.bytes_touched)
            .set("est_wall_secs", self.est_wall_secs);
        j
    }
}

/// One typed action of the fallback chain.
#[derive(Debug, Clone)]
pub enum PlanStep {
    /// Delete the cohort adapters covering the closure (G2).
    AdapterDelete { cohorts: Vec<u32> },
    /// Revert `steps` dense deltas, optionally replaying the reverted
    /// tail (filtered) to restore retain-only progress (G3).
    RingRevert { steps: usize, resume_tail: bool },
    /// Curvature anti-update + retain-tune, audit-gated (Alg. A.4).
    HotPathAntiUpdate { params: HotPathParams },
    /// Filtered tail replay from the nearest checkpoint (Thm. A.1).
    ExactReplay { from_checkpoint: u32, target_step: u32 },
    /// Checkpoint laundering: replay the tail from `from_checkpoint`
    /// filtering the cumulative forgotten closure, rewrite every
    /// contaminated checkpoint into a staged lineage, swap lineages and
    /// reset the forgotten set.  Request-independent maintenance — the
    /// amortization that keeps steady-state plan cost flat.
    Launder { from_checkpoint: u32, target_step: u32 },
    /// Nothing in the base was influenced — audited no-op.
    NoOp,
}

impl PlanStep {
    pub fn kind(&self) -> &'static str {
        match self {
            PlanStep::AdapterDelete { .. } => "adapter_delete",
            PlanStep::RingRevert { .. } => "ring_revert",
            PlanStep::HotPathAntiUpdate { .. } => "hot_path_anti_update",
            PlanStep::ExactReplay { .. } => "exact_replay",
            PlanStep::Launder { .. } => "launder",
            PlanStep::NoOp => "no_op",
        }
    }

    /// The manifest action this step records when it completes.
    pub fn action_kind(&self) -> ActionKind {
        match self {
            PlanStep::AdapterDelete { .. } => ActionKind::AdapterDelete,
            PlanStep::RingRevert { .. } => ActionKind::RecentRevert,
            PlanStep::HotPathAntiUpdate { .. } => ActionKind::HotPathAntiUpdate,
            PlanStep::ExactReplay { .. } => ActionKind::ExactReplay,
            PlanStep::Launder { .. } => ActionKind::Launder,
            PlanStep::NoOp => ActionKind::Refused,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", self.kind());
        match self {
            PlanStep::AdapterDelete { cohorts } => {
                j.set(
                    "cohorts",
                    Json::Arr(cohorts.iter().map(|&c| c.into()).collect()),
                );
            }
            PlanStep::RingRevert { steps, resume_tail } => {
                j.set("steps", *steps).set("resume_tail", *resume_tail);
            }
            PlanStep::HotPathAntiUpdate { params } => {
                j.set("max_anti_steps", params.max_steps)
                    .set("retain_steps", params.retain_steps);
            }
            PlanStep::ExactReplay { from_checkpoint, target_step }
            | PlanStep::Launder { from_checkpoint, target_step } => {
                j.set("from_checkpoint", *from_checkpoint)
                    .set("target_step", *target_step);
            }
            PlanStep::NoOp => {}
        }
        j
    }
}

/// A step plus its predicted cost.
#[derive(Debug, Clone)]
pub struct PlannedStep {
    pub step: PlanStep,
    pub cost: CostEstimate,
}

impl PlannedStep {
    pub fn to_json(&self) -> Json {
        let mut j = self.step.to_json();
        j.set("cost", self.cost.to_json());
        j
    }
}

/// The planner's output: an ordered fallback chain (Alg. A.7 decision
/// order — cheapest audit-passing path first) plus plan-time notes.
#[derive(Debug, Clone)]
pub struct UnlearnPlan {
    pub request_id: String,
    /// cl(F): the expanded forget closure, sorted.
    pub closure: Vec<u64>,
    /// IDs admitted by near-dup expansion beyond the request.
    pub closure_expanded: usize,
    /// Logical steps influenced by THIS request's closure.
    pub offending: Vec<u32>,
    /// Earliest step the serving state must be rebuilt from — the first
    /// offending step of closure ∪ already-forgotten (original-run
    /// checkpoints still contain previously forgotten influence, so the
    /// rebuild must filter the cumulative union to stay exact).
    pub effective_target: Option<u32>,
    /// Fallback chain, tried in order by the executor.
    pub steps: Vec<PlannedStep>,
    /// Paths ruled out at plan time and why (escalation edges).
    pub notes: Vec<UnlearnError>,
}

impl UnlearnPlan {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("request_id", self.request_id.as_str())
            .set("closure_size", self.closure.len())
            .set("closure_expanded", self.closure_expanded)
            .set(
                "offending_steps",
                Json::Arr(self.offending.iter().map(|&s| s.into()).collect()),
            )
            .set(
                "effective_target",
                self.effective_target.map(Json::from).unwrap_or(Json::Null),
            )
            .set(
                "steps",
                Json::Arr(self.steps.iter().map(|s| s.to_json()).collect()),
            )
            .set(
                "notes",
                Json::Arr(self.notes.iter().map(|n| n.to_json()).collect()),
            );
        j
    }

    /// The step with the smallest predicted wall-time (the chain is
    /// already ordered by Alg. A.7; this is the queryable-budget view).
    pub fn cheapest(&self) -> Option<&PlannedStep> {
        self.steps.iter().min_by(|a, b| {
            a.cost
                .est_wall_secs
                .partial_cmp(&b.cost.est_wall_secs)
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

/// Read-only snapshot of everything the planner consults.  Borrowing
/// (not owning) keeps construction free; tests fabricate views from
/// synthetic WALs/rings without any training.
pub struct SystemView<'a> {
    pub corpus: &'a Corpus,
    pub ndindex: &'a HammingIndex,
    pub closure_params: ClosureParams,
    pub adapters: &'a AdapterRegistry,
    pub records: &'a [WalRecord],
    pub idmap: &'a IdMap,
    pub manifest: &'a ForgetManifest,
    /// Cumulative closure of every previously executed forget action.
    pub forgotten: &'a HashSet<u64>,
    /// Earliest step still revertible from the delta ring.
    pub ring_earliest: Option<u32>,
    pub ring_available: usize,
    pub ring_budget: RingBudget,
    /// Compressed size of each stored ring patch, oldest → newest.
    pub ring_patch_sizes: Vec<usize>,
    /// Current serving logical step.
    pub logical_step: u32,
    /// True once any state-mutating path has run — ring patches (logged
    /// against the original trajectory) then no longer apply.
    pub diverged: bool,
    /// Ring reverts restore bits exactly (XOR patches covering the
    /// optimizer).  Arithmetic patches revert only up to rounding
    /// (Thm. A.11(b)) — still plannable, but never terminal-committable
    /// after a failed audit.
    pub ring_bit_exact: bool,
    pub fisher_available: bool,
    pub hot_path: HotPathParams,
    pub resume_after_revert: bool,
    /// Full-checkpoint steps, ascending.
    pub checkpoints: Vec<u32>,
    /// On-disk bytes of one full checkpoint (0 when unknown).
    pub checkpoint_bytes: u64,
    pub param_count: usize,
    pub lora_param_count: usize,
    /// Measured mean seconds per `train_step` graph call (0 when none
    /// has been observed yet).
    pub step_secs_mean: f64,
}

/// Expand a request to cl(F) (Alg. A.7 line 1) — standalone so the
/// planner and the legacy `closure_of` share one implementation.
pub fn expand_request_closure(
    corpus: &Corpus,
    ndindex: &HammingIndex,
    params: ClosureParams,
    req: &ForgetRequest,
) -> (Vec<u64>, usize) {
    let mut ids = req.sample_ids.clone();
    if let Some(u) = req.user {
        ids.extend(corpus.user_samples(u));
    }
    ids.sort_unstable();
    ids.dedup();
    let cl = expand_closure(corpus, ndindex, &ids, params);
    (cl.ids, cl.expanded.len())
}

/// When to compact the cumulative forgotten set into rewritten base
/// checkpoints (checkpoint laundering).  The trigger metric is the
/// *replay-tail inflation*: how many more WAL records a rebuild must
/// traverse because old-lineage checkpoints still contain forgotten
/// influence, versus the tail a fresh request would replay from the
/// latest checkpoint.  That inflation grows monotonically with the
/// total number of forgotten users; laundering resets it to zero.
#[derive(Debug, Clone, PartialEq)]
pub struct LaunderPolicy {
    /// Plan laundering once the forgotten set inflates rebuild tails by
    /// at least this many WAL records (0 = launder whenever anything
    /// was forgotten).
    pub min_extra_replay_records: u64,
}

impl Default for LaunderPolicy {
    fn default() -> Self {
        // half a default checkpoint interval of extra records: cheap
        // enough to absorb, expensive enough not to churn lineages on
        // every single forget
        LaunderPolicy {
            min_extra_replay_records: 64,
        }
    }
}

/// The pure planner.  No side effects, no state mutation: every public
/// behavior of Alg. A.7's decision layer is a function of the view.
pub struct Planner;

impl Planner {
    pub fn plan(
        view: &SystemView<'_>,
        req: &ForgetRequest,
    ) -> Result<UnlearnPlan, UnlearnError> {
        if view.manifest.was_executed(&req.id) {
            return Err(UnlearnError::DuplicateRequest { id: req.id.clone() });
        }
        let (closure, expanded) = expand_request_closure(
            view.corpus,
            view.ndindex,
            view.closure_params,
            req,
        );
        if closure.is_empty() {
            return Err(UnlearnError::EmptyClosure);
        }
        let closure_set: HashSet<u64> = closure.iter().copied().collect();
        let mut steps: Vec<PlannedStep> = Vec::new();
        let mut notes: Vec<UnlearnError> = Vec::new();

        // ---- path 1: adapter deletion (Alg. A.7 line 2) --------------
        if let Some(cohorts) = view.adapters.covering_cohorts(&closure) {
            if !cohorts.is_empty() {
                let cost = Self::adapter_cost(view, &cohorts);
                steps.push(PlannedStep {
                    step: PlanStep::AdapterDelete { cohorts },
                    cost,
                });
            }
        }

        // ---- offending steps (Alg. A.7 line 6) -----------------------
        let offending =
            offending_steps(view.records, view.idmap, &closure_set)
                .map_err(|e| UnlearnError::Internal(format!("{e:#}")))?;

        if offending.is_empty() {
            // the base never saw the data: adapter deletion (if planned)
            // fully serves the request; otherwise an audited no-op.
            if steps.is_empty() {
                steps.push(PlannedStep {
                    step: PlanStep::NoOp,
                    cost: Self::audit_only_cost(view),
                });
            }
            return Ok(UnlearnPlan {
                request_id: req.id.clone(),
                closure,
                closure_expanded: expanded,
                offending,
                effective_target: None,
                steps,
                notes,
            });
        }

        // The rebuild target must cover the cumulative union: original
        // checkpoints still contain previously forgotten influence.
        let target = if view.forgotten.is_empty() {
            offending[0]
        } else {
            let mut effective = closure_set.clone();
            effective.extend(view.forgotten.iter().copied());
            let union_off =
                offending_steps(view.records, view.idmap, &effective)
                    .map_err(|e| UnlearnError::Internal(format!("{e:#}")))?;
            // non-empty: `offending` is a subset of the union's steps
            union_off[0]
        };

        // ---- path 2: recent exact revert (G3) ------------------------
        let needed = (view.logical_step.saturating_sub(target)) as usize;
        let has_ckpt_fallback =
            view.checkpoints.iter().any(|&s| s <= target);
        // Plannable only when a failed audit has somewhere safe to land:
        // either the revert+resume state is itself terminal-committable
        // (bitwise-exact reverts with the resumed tail) or a checkpoint
        // replay fallback exists.  Otherwise a failed gate would strand
        // a mutated state with no manifest entry.
        let ring_committable =
            view.resume_after_revert && view.ring_bit_exact;
        let in_window = matches!(
            view.ring_earliest,
            Some(earliest)
                if target >= earliest && needed <= view.ring_available
        );
        if view.diverged {
            notes.push(UnlearnError::RingDiverged);
        } else if !in_window {
            notes.push(UnlearnError::RingWindowMiss {
                needed,
                available: view.ring_available,
            });
        } else if ring_committable || has_ckpt_fallback {
            let cost = Self::ring_cost(view, needed, target);
            steps.push(PlannedStep {
                step: PlanStep::RingRevert {
                    steps: needed,
                    resume_tail: view.resume_after_revert,
                },
                cost,
            });
        } else {
            // the window covers it, but with neither a committable
            // terminal state nor a replay to escalate to, the true
            // blocker is the missing checkpoint
            notes.push(UnlearnError::NoCheckpoint { target });
        }

        // ---- path 3: urgent hot path (Alg. A.4) ----------------------
        if req.urgency == Urgency::High {
            if view.fisher_available {
                let cost = Self::hot_path_cost(view);
                steps.push(PlannedStep {
                    step: PlanStep::HotPathAntiUpdate {
                        params: view.hot_path.clone(),
                    },
                    cost,
                });
            } else {
                notes.push(UnlearnError::NoFisherCache);
            }
        }

        // ---- path 4: exact replay (default, Thm. A.1) ----------------
        match view.checkpoints.iter().filter(|&&s| s <= target).max() {
            Some(&k) => {
                let cost = Self::replay_cost(view, k);
                steps.push(PlannedStep {
                    step: PlanStep::ExactReplay {
                        from_checkpoint: k,
                        target_step: target,
                    },
                    cost,
                });
            }
            None if steps.is_empty() => {
                return Err(UnlearnError::NoCheckpoint { target });
            }
            None => {
                let note = UnlearnError::NoCheckpoint { target };
                if !notes.contains(&note) {
                    notes.push(note);
                }
            }
        }

        Ok(UnlearnPlan {
            request_id: req.id.clone(),
            closure,
            closure_expanded: expanded,
            offending,
            effective_target: Some(target),
            steps,
            notes,
        })
    }

    /// Plan a laundering pass (request-independent maintenance).
    ///
    /// Returns `Ok(None)` when the policy threshold is not met,
    /// `Ok(Some(step))` with a cost estimate when laundering is due, and
    /// a typed error when it is impossible (nothing forgotten, or no
    /// clean checkpoint precedes the forgotten influence).  Pure over
    /// the view, like `plan`.
    pub fn plan_launder(
        view: &SystemView<'_>,
        policy: &LaunderPolicy,
    ) -> Result<Option<PlannedStep>, UnlearnError> {
        if view.forgotten.is_empty() {
            return Err(UnlearnError::NothingToLaunder);
        }
        let off = offending_steps(view.records, view.idmap, view.forgotten)
            .map_err(|e| UnlearnError::Internal(format!("{e:#}")))?;
        let target = match off.first() {
            // forgotten but never in the base: resetting is free, there
            // is no contamination to rewrite
            None => return Err(UnlearnError::NothingToLaunder),
            Some(&t) => t,
        };
        let from_checkpoint = view
            .checkpoints
            .iter()
            .filter(|&&s| s <= target)
            .max()
            .copied()
            .ok_or(UnlearnError::NoCheckpoint { target })?;
        let extra = Self::forgotten_tail_inflation(view, from_checkpoint);
        if extra < policy.min_extra_replay_records {
            return Ok(None);
        }
        let records = tail_len(view.records, from_checkpoint);
        let contaminated = view
            .checkpoints
            .iter()
            .filter(|&&s| s > target)
            .count() as u64;
        Ok(Some(PlannedStep {
            step: PlanStep::Launder {
                from_checkpoint,
                target_step: target,
            },
            cost: CostEstimate {
                replay_steps: records as u32,
                // read one checkpoint, write every contaminated one
                bytes_touched: view.checkpoint_bytes
                    + contaminated * view.param_count as u64 * 4 * 3,
                est_wall_secs: view.step_secs_mean * records as f64,
            },
        }))
    }

    /// Replay-tail records attributable to the forgotten set: the tail
    /// from the rebuild start the forgotten influence forces, minus the
    /// tail from the latest checkpoint (what a fresh request with no
    /// history would replay).
    pub fn forgotten_tail_inflation(
        view: &SystemView<'_>,
        forced_from: u32,
    ) -> u64 {
        let baseline = view
            .checkpoints
            .iter()
            .max()
            .map(|&latest| tail_len(view.records, latest))
            .unwrap_or(0);
        tail_len(view.records, forced_from).saturating_sub(baseline)
    }

    /// Audit harness cost (runs after every path): a handful of eval
    /// graph calls — approximated as a few train-step-equivalents.
    fn audit_only_cost(view: &SystemView<'_>) -> CostEstimate {
        CostEstimate {
            replay_steps: 0,
            bytes_touched: view.param_count as u64 * 4,
            est_wall_secs: view.step_secs_mean * 4.0,
        }
    }

    fn adapter_cost(view: &SystemView<'_>, cohorts: &[u32]) -> CostEstimate {
        CostEstimate {
            replay_steps: 0,
            bytes_touched: cohorts.len() as u64
                * view.lora_param_count as u64
                * 4
                + view.param_count as u64 * 4,
            est_wall_secs: view.step_secs_mean * 4.0,
        }
    }

    fn ring_cost(view: &SystemView<'_>, u: usize, target: u32) -> CostEstimate {
        let b = &view.ring_budget;
        let patch_bytes: u64 = view
            .ring_patch_sizes
            .iter()
            .rev()
            .take(u)
            .map(|&s| s as u64)
            .sum();
        let resume_records = if view.resume_after_revert {
            tail_len(view.records, target)
        } else {
            0
        };
        CostEstimate {
            replay_steps: resume_records as u32,
            bytes_touched: patch_bytes + view.param_count as u64 * 4 * 3,
            est_wall_secs: b.revert_secs_mean * u as f64
                + view.step_secs_mean * resume_records as f64,
        }
    }

    fn hot_path_cost(view: &SystemView<'_>) -> CostEstimate {
        let hp = &view.hot_path;
        // each anti step is ~1 forget-grad pass; retain-tune adds T_R
        let graph_calls = (hp.max_steps + hp.retain_steps) as u64;
        CostEstimate {
            replay_steps: graph_calls as u32,
            bytes_touched: view.param_count as u64 * 4 * 2,
            est_wall_secs: view.step_secs_mean * graph_calls as f64,
        }
    }

    fn replay_cost(view: &SystemView<'_>, from_checkpoint: u32) -> CostEstimate {
        let records = tail_len(view.records, from_checkpoint);
        CostEstimate {
            replay_steps: records as u32,
            bytes_touched: view.checkpoint_bytes
                + view.param_count as u64 * 4 * 3,
            est_wall_secs: view.step_secs_mean * records as f64,
        }
    }
}
