//! Checkpoint laundering (the compaction path): fold the cumulative
//! forgotten closure into a rewritten checkpoint lineage so steady-state
//! unlearning cost stops growing with the total number of forgotten
//! users.
//!
//! One filtered tail replay from the nearest clean checkpoint (Thm. A.1
//! — the same primitive every exact path uses) simultaneously rebuilds
//! the serving state and, via the snapshot sink of
//! [`crate::replay::replay_filter_with_snapshots`], emits the
//! retain-only replacement for every contaminated checkpoint.  Clean
//! checkpoints (those preceding all forgotten influence) are *adopted*
//! into the staged lineage — a manifest copy, zero tensor bytes, full
//! CAS sharing.  The swap is audit-gated: the candidate state is
//! audited before `LINEAGE.json` flips, and a failed audit on a
//! state-*changing* swap refuses it, leaving store and serving state
//! untouched (a bit-unchanged candidate commits with the report
//! attached — see the gate comment in `execute_launder`).
//!
//! After a committed swap:
//! - every checkpoint in the active lineage is retain-only w.r.t. the
//!   laundered closure, so rebuild targets are computed from the *new*
//!   request alone — plans cost as if no one had ever been forgotten;
//! - the laundered closure persists in the lineage (`laundered.json`)
//!   and keeps being filtered out of tail replays (WAL records still
//!   reference those sample IDs — exactness needs the filter, the
//!   *cost* win comes from the later rebuild start);
//! - the delta ring is cleared: a laundered base diverges from the
//!   logged trajectory its patches describe;
//! - the in-memory `forgotten` set resets to empty.

use std::time::Instant;

use crate::audit::{run_audits, AuditReport, ModelView};
use crate::manifest::ActionKind;
use crate::replay::{offending_steps, replay_filter_with_snapshots};
use crate::util::json::Json;

use super::plan::{LaunderPolicy, Planner, UnlearnError};
use super::{ForgetRequest, UnlearnSystem, Urgency};

/// What a laundering pass did.
#[derive(Debug, Clone)]
pub struct LaunderOutcome {
    /// False when the idempotency key had already been executed.
    pub executed: bool,
    /// Active lineage generation after the pass.
    pub generation: u64,
    /// Checkpoint the filtered rebuild started from.
    pub from_checkpoint: u32,
    /// First logical step the forgotten closure influenced.
    pub target_step: u32,
    /// IDs moved from the in-memory forgotten set into the lineage.
    pub laundered_now: usize,
    /// Total IDs the active lineage has laundered (cumulative).
    pub laundered_total: usize,
    /// Contaminated checkpoints rewritten from filtered snapshots.
    pub checkpoints_written: usize,
    /// Clean checkpoints adopted by manifest copy (zero tensor bytes).
    pub checkpoints_adopted: usize,
    /// Optimizer updates the filtered rebuild applied.
    pub applied_steps: u32,
    /// Audit of the candidate state (gates the swap).
    pub audit: Option<AuditReport>,
    pub wall_secs: f64,
    pub details: Json,
}

/// Execute a laundering pass against the live system.
///
/// `policy` thresholds whether the pass runs at all (`force` bypasses
/// the threshold but never the audit gate or the exactness
/// preconditions).  `id` is the manifest idempotency key.
pub fn execute_launder(
    sys: &mut UnlearnSystem<'_>,
    id: &str,
    policy: &LaunderPolicy,
    force: bool,
) -> anyhow::Result<LaunderOutcome> {
    // detlint: allow(wall-clock) — wall_secs is operator observability in
    // the outcome report; replay equality never reads it
    let t0 = Instant::now();
    // Moving-tail rule: never launder against a provisional WAL tail.
    // An in-flight train-increment's records are truncated if it
    // crashes; a lineage staged over them would survive the rollback
    // and desynchronize checkpoints from the (shorter) replayable
    // history.  Checked before duplicate suppression so a retry under
    // the same key still succeeds once the increment commits.
    if sys.ingest.in_flight {
        return Err(UnlearnError::IngestInFlight.into());
    }
    if sys.manifest.was_executed(id) {
        return Ok(LaunderOutcome {
            executed: false,
            // report the REAL lineage state — a duplicate suppression
            // must not read as a generation regression to pollers
            generation: sys.store().active_generation().unwrap_or(0),
            from_checkpoint: 0,
            target_step: 0,
            laundered_now: 0,
            laundered_total: sys.laundered_total(),
            checkpoints_written: 0,
            checkpoints_adopted: 0,
            applied_steps: 0,
            audit: None,
            wall_secs: t0.elapsed().as_secs_f64(),
            details: Json::obj(),
        });
    }
    let mut forgotten: Vec<u64> = sys.forgotten.iter().copied().collect();
    forgotten.sort_unstable();
    if forgotten.is_empty() {
        return Err(UnlearnError::NothingToLaunder.into());
    }

    let off = offending_steps(&sys.records, &sys.idmap, &sys.forgotten)?;
    let target = match off.first() {
        Some(&t) => t,
        None => {
            // forgotten data never influenced the base: nothing is
            // contaminated, resetting the set is exact and free.  Still
            // a manifest-recorded action — the reset must be auditable.
            return commit_reset_only(sys, id, &forgotten, t0);
        }
    };

    let effective_policy = if force {
        LaunderPolicy {
            min_extra_replay_records: 0,
        }
    } else {
        policy.clone()
    };
    let planned = {
        let view = sys.view()?;
        Planner::plan_launder(&view, &effective_policy)
            .map_err(anyhow::Error::new)?
    };
    let planned = match planned {
        Some(p) => p,
        None => {
            return Err(anyhow::anyhow!(
                "laundering below policy threshold (< {} extra replay \
                 records) — pass force to override",
                policy.min_extra_replay_records
            ))
        }
    };
    let from_checkpoint = match planned.step {
        super::plan::PlanStep::Launder { from_checkpoint, .. } => {
            from_checkpoint
        }
        ref other => {
            return Err(anyhow::anyhow!(
                "plan_launder returned a non-launder step {other:?}"
            ))
        }
    };

    // the rebuild filter needs the previous lineage's laundered closure
    // too: the WAL tail still references those samples
    let mut filter = sys.forgotten.clone();
    filter.extend(sys.laundered.iter().copied());

    let checkpoints = sys.store().list_full()?;
    let clean: Vec<u32> =
        checkpoints.iter().copied().filter(|&s| s <= target).collect();
    let contaminated: Vec<u32> =
        checkpoints.iter().copied().filter(|&s| s > target).collect();

    // ---- stage the successor lineage --------------------------------
    // (the stage borrows the cached store handle; every borrow below is
    // shared — the first &mut use of `sys` comes after commit/abort)
    let stage = sys.store().begin_lineage()?;
    let generation = stage.generation;
    let staged = (|| -> anyhow::Result<crate::checkpoint::TrainState> {
        for &s in &clean {
            stage.adopt_full(s)?;
        }
        sys.store().load_full(from_checkpoint)
    })();
    let start = match staged {
        Ok(s) => s,
        Err(e) => {
            stage.abort()?;
            return Err(e.context(
                "laundering staging failed — staged lineage discarded",
            ));
        }
    };
    let mut written = 0usize;
    let replay_res = replay_filter_with_snapshots(
        sys.rt,
        &sys.corpus,
        &start,
        &sys.records,
        &sys.idmap,
        &filter,
        Some(&sys.pins),
        &sys.replay_options(),
        &contaminated,
        |snap| {
            stage.save_full(snap)?;
            written += 1;
            Ok(())
        },
    );
    let outcome = match replay_res {
        Ok(o) => o,
        Err(e) => {
            stage.abort()?;
            return Err(e.context("laundering replay failed — staged \
                                  lineage discarded"));
        }
    };

    // ---- audit gate -------------------------------------------------
    // The candidate is audited against the forgotten closure before the
    // swap.  When laundering leaves the serving state bit-unchanged —
    // the steady state: every forget action already rebuilt it to the
    // exact retain-only state and committed it with its own audit — the
    // verdict carries no new information and a (toy-noise-prone) failed
    // gate must not strand the cost inflation forever; the swap commits
    // with the report attached, mirroring the exact-replay last resort.
    // When the candidate DIFFERS from the serving state (a prior
    // approximate hot-path state being replaced by the exact one), the
    // audit hard-gates the swap: refusal discards the staged lineage
    // and leaves state and store untouched.
    let state_changed = !sys.state.bits_equal(&outcome.state);
    let audit = match run_audits(
        &sys.audit_ctx(&forgotten),
        ModelView::Base(&outcome.state.params),
    ) {
        Ok(a) => a,
        Err(e) => {
            // an audit that cannot even run must not leak the staged
            // lineage (its manifests would pin blobs through every GC)
            stage.abort()?;
            return Err(e.context(
                "laundering audit errored — staged lineage discarded",
            ));
        }
    };
    if !audit.pass() && state_changed {
        stage.abort()?;
        return Err(anyhow::Error::new(UnlearnError::AuditFailed {
            path: ActionKind::Launder,
        })
        .context(format!("laundering audit failed on a state-changing \
                          swap: {}",
                         audit.to_json().encode())));
    }

    // ---- atomic swap + system-state transition ----------------------
    let mut new_laundered: Vec<u64> = sys
        .laundered
        .iter()
        .copied()
        .chain(forgotten.iter().copied())
        .collect();
    new_laundered.sort_unstable();
    new_laundered.dedup();
    let retired_before = sys.idmap.retired_len() as u64;
    stage.commit(&new_laundered, target, retired_before)?;

    sys.state = outcome.state;
    // the laundered base is off the logged trajectory: ring patches can
    // never apply again
    sys.diverged = true;
    sys.ring.clear();
    sys.laundered = new_laundered.iter().copied().collect();
    sys.reset_forgotten()?;

    // ---- laundered-set compaction (memory scope) --------------------
    // Fold the freshly committed closure into the WAL IdMap's retired
    // set and compact the lineage's laundered.json to an empty residue:
    // replays mask retired ids automatically, so neither the in-memory
    // set nor the file keeps growing with service lifetime (the retired
    // set is bounded by the corpus — an id retires at most once).
    // Ordering: the commit above already persisted the FULL closure
    // durably; retire-then-compact can only ever leave double coverage
    // behind a crash, never a gap.  Best-effort from here: the swap is
    // committed and a compaction hiccup must not fail the pass.
    let compacted = (|| -> anyhow::Result<()> {
        sys.idmap.retire_ids(new_laundered.iter().copied());
        sys.idmap.save(&sys.cfg.run_dir.join("ids.map"))?;
        sys.store()
            .compact_laundered(sys.idmap.retired_len() as u64)?;
        sys.laundered.clear();
        Ok(())
    })();
    if let Err(e) = &compacted {
        eprintln!(
            "laundered-set compaction failed (swap unaffected; the \
             residue keeps being filtered and the next pass retries): \
             {e:#}"
        );
    }

    // The swap restructured the store: re-run open's fail-closed
    // validation on the cached handle (safe here — commit consumed the
    // stage, no staged dir is live).  Best-effort AFTER the in-memory
    // transition: the swap is durable, so nothing may now prevent the
    // system state and the signed-manifest record from following it —
    // and the stale handle stays correct anyway (every query re-reads
    // LINEAGE.json; revalidation is belt-and-braces, not correctness).
    let reopen_err = sys.reopen_store().err();
    if let Some(e) = &reopen_err {
        eprintln!(
            "post-swap store revalidation failed (continuing on the \
             root-based handle): {e:#}"
        );
    }

    // best-effort accounting: the swap is already committed, so a
    // stats hiccup must not fail the pass (and must not widen the
    // window in which the manifest lacks the launder record)
    let cas = sys.cas_stats().ok();
    let mut details = Json::obj();
    details
        .set("generation", generation)
        .set("from_checkpoint", from_checkpoint)
        .set("target_step", target)
        .set("laundered_now", forgotten.len())
        .set("laundered_total", sys.laundered_total())
        .set("checkpoints_written", written)
        .set("checkpoints_adopted", clean.len())
        .set("applied_steps", outcome.invariants.applied_steps)
        .set("state_changed", state_changed);
    if let Some(e) = &reopen_err {
        details.set("store_revalidation_error", format!("{e:#}"));
    }
    if let Some(c) = &cas {
        details
            .set("cas_objects", c.objects)
            .set("cas_object_bytes", c.object_bytes)
            .set("cas_dedup_ratio", c.dedup_ratio);
    }
    let req = launder_request(id);
    sys.append_manifest(
        &req,
        &forgotten,
        0,
        ActionKind::Launder,
        details.clone(),
        Some(&audit),
    )?;

    Ok(LaunderOutcome {
        executed: true,
        generation,
        from_checkpoint,
        target_step: target,
        laundered_now: forgotten.len(),
        laundered_total: sys.laundered_total(),
        checkpoints_written: written,
        checkpoints_adopted: clean.len(),
        applied_steps: outcome.invariants.applied_steps,
        audit: Some(audit),
        wall_secs: t0.elapsed().as_secs_f64(),
        details,
    })
}

/// The forgotten set never touched the base: clear it without any
/// rebuild, recording the reset in the signed manifest.
fn commit_reset_only(
    sys: &mut UnlearnSystem<'_>,
    id: &str,
    forgotten: &[u64],
    t0: Instant,
) -> anyhow::Result<LaunderOutcome> {
    sys.reset_forgotten()?;
    let mut details = Json::obj();
    details
        .set("note", "forgotten set had no offending steps — reset only")
        .set("laundered_now", forgotten.len());
    let req = launder_request(id);
    sys.append_manifest(
        &req,
        forgotten,
        0,
        ActionKind::Launder,
        details.clone(),
        None,
    )?;
    Ok(LaunderOutcome {
        executed: true,
        generation: sys.store().active_generation()?,
        from_checkpoint: 0,
        target_step: 0,
        laundered_now: forgotten.len(),
        laundered_total: sys.laundered_total(),
        checkpoints_written: 0,
        checkpoints_adopted: 0,
        applied_steps: 0,
        audit: None,
        wall_secs: t0.elapsed().as_secs_f64(),
        details,
    })
}

fn launder_request(id: &str) -> ForgetRequest {
    ForgetRequest {
        id: id.to_string(),
        user: None,
        sample_ids: Vec::new(),
        urgency: Urgency::Normal,
    }
}

impl LaunderOutcome {
    /// Wire/CLI encoding.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("executed", self.executed)
            .set("generation", self.generation)
            .set("from_checkpoint", self.from_checkpoint)
            .set("target_step", self.target_step)
            .set("laundered_now", self.laundered_now)
            .set("laundered_total", self.laundered_total)
            .set("checkpoints_written", self.checkpoints_written)
            .set("checkpoints_adopted", self.checkpoints_adopted)
            .set("applied_steps", self.applied_steps)
            .set(
                "audit_pass",
                self.audit
                    .as_ref()
                    .map(|a| Json::Bool(a.pass()))
                    .unwrap_or(Json::Null),
            )
            .set("wall_secs", self.wall_secs);
        j
    }
}
