//! UNLEARNCONTROLLER (paper Alg. A.7, §4.4), split into a pure
//! **planner** and an audit-gated **executor** behind a typed API:
//!
//! - [`plan::Planner::plan`]`(&SystemView, &ForgetRequest) ->
//!   UnlearnPlan` — a side-effect-free decision: an ordered fallback
//!   chain of typed [`plan::PlanStep`]s, each carrying a
//!   [`plan::CostEstimate`] (the Table 3/8 budgets as queryable
//!   objects).  Failures are the typed [`plan::UnlearnError`] taxonomy.
//! - [`execute::Executor::execute`] — walks the chain, gating each step
//!   on the audit harness and appending every action to the signed
//!   manifest.
//! - [`batch::execute_batch`] — coalesces N pending requests into one
//!   union-filtered tail replay (exact by Thm. A.1), amortizing replay
//!   cost across a request stream.
//!
//! Decision order (Alg. A.7):
//!   1. **Adapter deletion** when cl(F) is confined to cohort adapters.
//!   2. **Recent exact revert** when every offending step is inside the
//!      dense-delta ring window (revert + filtered tail replay compose
//!      into a bounded-work exact path).
//!   3. **Urgent hot path**: curvature anti-update + retain-tune.
//!   4. **Exact replay** (default): nearest checkpoint preceding all
//!      forget influence + `ReplayFilter`.

pub mod batch;
pub mod execute;
pub mod launder;
pub mod plan;

pub use batch::{
    execute_batch, BatchOutcome, BatchPlanner, SharedMode, SharedReplayPlan,
};
pub use execute::Executor;
pub use launder::{execute_launder, LaunderOutcome};
pub use plan::{
    CostEstimate, LaunderPolicy, PlanStep, PlannedStep, Planner, SystemView,
    UnlearnError, UnlearnPlan,
};

use std::collections::HashSet;

use crate::adapters::AdapterRegistry;
use crate::audit::{AuditContext, AuditReport, AuditThresholds};
use crate::checkpoint::{CheckpointStore, TrainState};
use crate::config::{Pins, RunConfig};
use crate::curvature::{FisherCache, HotPathParams};
use crate::data::corpus::Corpus;
use crate::deltas::DeltaRing;
use crate::manifest::{ActionKind, ForgetManifest, ManifestEntry};
use crate::neardup::{ClosureParams, HammingIndex};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::wal::{IdMap, WalRecord};

/// Urgency of a forget request (drives the hot-path branch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Urgency {
    Normal,
    High,
}

/// A forget request (user-scoped and/or explicit sample IDs).
#[derive(Debug, Clone)]
pub struct ForgetRequest {
    /// Idempotency key.
    pub id: String,
    pub user: Option<u32>,
    pub sample_ids: Vec<u64>,
    pub urgency: Urgency,
}

/// What the controller did.
#[derive(Debug, Clone)]
pub struct ControllerOutcome {
    pub action: ActionKind,
    pub closure_size: usize,
    pub closure_expanded: usize,
    pub audit: Option<AuditReport>,
    /// Typed escalation trail: plan-time skips + runtime audit failures.
    pub escalations: Vec<UnlearnError>,
    pub details: Json,
    /// False when the idempotency key had already been executed.
    pub executed: bool,
}

impl ControllerOutcome {
    /// The duplicate-suppression disposition (shared by the sync and
    /// batch paths so they cannot drift).
    pub fn duplicate(id: &str) -> ControllerOutcome {
        ControllerOutcome {
            action: ActionKind::Refused,
            closure_size: 0,
            closure_expanded: 0,
            audit: None,
            escalations: vec![UnlearnError::DuplicateRequest {
                id: id.into(),
            }],
            details: Json::obj(),
            executed: false,
        }
    }
}

/// Online-ingest watermark: how far the trained tail lags the corpus.
/// Lives on [`UnlearnSystem`] (not in `ingest/`) so the admin plane can
/// report it without a controller↔ingest dependency cycle; the ingest
/// subsystem is the only writer.
#[derive(Debug, Clone, Default)]
pub struct IngestStatus {
    /// Documents appended through the ingest log (this process).
    pub ingested_docs: u64,
    /// Corpus length the latest committed train-increment's schedule
    /// was drawn from — every sample below this bound has had at least
    /// one chance to enter the microbatch graph.
    pub covered_len: usize,
    /// True while a train-increment is running (or died mid-run and has
    /// not been recovered): the WAL tail beyond the interleave log's
    /// last commit is provisional, so laundering must refuse to race it
    /// (see [`plan::UnlearnError::IngestInFlight`]).
    pub in_flight: bool,
}

impl IngestStatus {
    /// Steps of tail advance needed to cover every uncovered sample
    /// once (one epoch pass at `batch × accum` samples per step) — the
    /// operator-facing `tail_lag_steps` watermark.
    pub fn tail_lag_steps(
        &self,
        corpus_len: usize,
        batch: usize,
        accum: usize,
    ) -> u64 {
        let uncovered = corpus_len.saturating_sub(self.covered_len);
        let per_step = (batch * accum).max(1);
        (uncovered as u64).div_ceil(per_step as u64)
    }
}

/// The live system a controller instance manages.
pub struct UnlearnSystem<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub corpus: Corpus,
    /// Current serving state (θ, Ω).
    pub state: TrainState,
    /// The run's content-addressed checkpoint store, validated ONCE at
    /// open and cached — `CheckpointStore::open` re-runs a fail-closed
    /// sweep (manifest parses + object stats + lineage dirs) that is
    /// redundant I/O on the admin hot path when repeated per call.
    /// Queries still re-read `LINEAGE.json`, so the handle observes a
    /// committed swap; [`UnlearnSystem::reopen_store`] re-validates
    /// after one (the only path that restructures the store).
    pub store: CheckpointStore,
    pub ring: DeltaRing,
    pub adapters: AdapterRegistry,
    pub fisher: Option<FisherCache>,
    pub manifest: ForgetManifest,
    pub records: Vec<WalRecord>,
    pub idmap: IdMap,
    pub pins: Pins,
    pub ndindex: HammingIndex,
    /// Matched member controls + held-out utility IDs for audits.
    pub retain_ids: Vec<u64>,
    pub eval_ids: Vec<u64>,
    pub thresholds: AuditThresholds,
    pub baseline_ppl: Option<f64>,
    pub closure_params: ClosureParams,
    pub hot_path: HotPathParams,
    /// After a ring revert, replay the reverted tail (filtered) to
    /// restore retain-only progress.
    pub resume_after_revert: bool,
    pub audit_seed: u64,
    /// Cumulative closure of every executed forget action since the
    /// last laundering pass.  Rebuilds (replay / revert-resume) filter
    /// `closure ∪ forgotten ∪ laundered`: the active lineage's
    /// checkpoints still contain this influence, so a replay filtering
    /// only the new request would resurrect it.  Laundering compacts
    /// this set into a rewritten lineage and resets it — the rebuild
    /// *target* (hence replay-tail length) depends only on `closure ∪
    /// forgotten`, which is what keeps steady-state cost flat.
    pub forgotten: HashSet<u64>,
    /// Closure already laundered into the active checkpoint lineage.
    /// Every checkpoint is retain-only w.r.t. this set, so it never
    /// moves rebuild targets earlier; it is still filtered out of tail
    /// replays because the WAL records reference those sample IDs.
    pub laundered: HashSet<u64>,
    /// True once any state-mutating path has run — the serving state no
    /// longer lies on the logged trajectory, so ring patches (recorded
    /// against it) are no longer applicable.
    pub diverged: bool,
    /// Online-ingest watermark (see [`IngestStatus`]); the `ingest`
    /// subsystem is the only writer.
    pub ingest: IngestStatus,
}

impl<'rt> UnlearnSystem<'rt> {
    pub(crate) fn audit_ctx<'a>(&'a self, closure: &'a [u64]) -> AuditContext<'a> {
        AuditContext {
            rt: self.rt,
            corpus: &self.corpus,
            forget_ids: closure,
            retain_ids: &self.retain_ids,
            eval_ids: &self.eval_ids,
            baseline_ppl: self.baseline_ppl,
            thresholds: self.thresholds.clone(),
            seed: self.audit_seed,
        }
    }

    pub(crate) fn append_manifest(
        &mut self,
        req: &ForgetRequest,
        closure: &[u64],
        expanded: usize,
        action: ActionKind,
        details: Json,
        audit: Option<&AuditReport>,
    ) -> anyhow::Result<()> {
        let mut request = Json::obj();
        request
            .set("id", req.id.as_str())
            .set(
                "user",
                req.user.map(Json::from).unwrap_or(Json::Null),
            )
            .set("requested_ids", req.sample_ids.len())
            .set(
                "urgency",
                match req.urgency {
                    Urgency::Normal => "normal",
                    Urgency::High => "high",
                },
            );
        let mut cl = Json::obj();
        cl.set("size", closure.len()).set("expanded", expanded);
        let mut artifacts = Json::obj();
        artifacts
            .set("model_hash", self.state.model_hash())
            .set("optimizer_hash", self.state.optimizer_hash());
        self.manifest.append(&ManifestEntry {
            idempotency_key: req.id.clone(),
            request,
            closure_summary: cl,
            action,
            details,
            audits: audit.map(|a| a.to_json()),
            artifacts,
        })?;
        Ok(())
    }

    /// Persist the cumulative forgotten set next to the run's WAL
    /// (atomic tmp+rename).  Exactness must survive a process restart:
    /// the active lineage's checkpoints still contain this influence,
    /// so a system rebuilt from the run dir has to keep filtering it
    /// (and rebuilding its serving state) until laundering compacts it.
    pub(crate) fn persist_forgotten(&self) -> anyhow::Result<()> {
        let mut ids: Vec<u64> = self.forgotten.iter().copied().collect();
        ids.sort_unstable();
        crate::checkpoint::write_atomic(
            &self.cfg.run_dir.join("forgotten.json"),
            &crate::checkpoint::ids_json(&ids).encode(),
        )
    }

    /// Extend the cumulative forgotten closure and persist it — the one
    /// entry point every commit that erased base influence goes
    /// through, so the on-disk set can never lag an executed action.
    pub(crate) fn commit_forgotten<I: IntoIterator<Item = u64>>(
        &mut self,
        ids: I,
    ) -> anyhow::Result<()> {
        self.forgotten.extend(ids);
        self.persist_forgotten()
    }

    /// Reset after laundering (the closure moved into the lineage's
    /// `laundered.json`) and persist the now-empty set.
    pub(crate) fn reset_forgotten(&mut self) -> anyhow::Result<()> {
        self.forgotten.clear();
        self.persist_forgotten()
    }

    /// Replay options carrying this run's configured fleet topology pin
    /// — every state rebuild inside the controller uses these, so a
    /// fleet shard's replays present the topology they were trained
    /// under (and fail closed if the run dir's stored pins disagree).
    pub fn replay_options(&self) -> crate::replay::ReplayOptions {
        crate::replay::ReplayOptions {
            shard_pin: self.cfg.shard_pin.clone(),
            ..crate::replay::ReplayOptions::default()
        }
    }

    /// Total closure laundered out of the run's history: the IDs
    /// compacted into the WAL IdMap's retired set plus the in-memory
    /// residue NOT yet retired (laundered-set compaction keeps the
    /// residue empty in steady state).  Counted as a union, not a sum:
    /// in the crash window between retire and compact the residue is a
    /// subset of the retired set, and double-counting it would inflate
    /// the reported accounting.
    pub fn laundered_total(&self) -> usize {
        self.idmap.retired_len()
            + self
                .laundered
                .iter()
                .filter(|&&id| !self.idmap.is_retired(id))
                .count()
    }

    /// `tail_lag_steps` against THIS system's batch/accum geometry —
    /// the number `status`/`fleet_status` report.
    pub fn tail_lag_steps(&self) -> u64 {
        self.ingest.tail_lag_steps(
            self.corpus.len(),
            self.rt.manifest.batch,
            self.cfg.accum,
        )
    }

    /// Expand the request to cl(F) (Alg. A.7 line 1).
    pub fn closure_of(&self, req: &ForgetRequest) -> (Vec<u64>, usize) {
        plan::expand_request_closure(
            &self.corpus,
            &self.ndindex,
            self.closure_params,
            req,
        )
    }

    /// The run's content-addressed checkpoint store (the active
    /// lineage's view) — the handle validated at system construction.
    pub fn store(&self) -> &CheckpointStore {
        &self.store
    }

    /// Invalidate the cached store handle: re-run `open`'s fail-closed
    /// validation and replace it.  Called after a lineage swap
    /// (laundering) — the one operation that restructures the store.
    /// Must never run while a staged lineage is live: `open` retires
    /// every non-active lineage directory.
    pub fn reopen_store(&mut self) -> anyhow::Result<()> {
        self.store = CheckpointStore::open(
            &self.cfg.run_dir.join("ckpt"),
            self.cfg.checkpoint_keep,
        )?;
        Ok(())
    }

    /// CAS accounting for the admin plane (`status`) and benches.
    pub fn cas_stats(&self) -> anyhow::Result<crate::checkpoint::CasStats> {
        self.store.stats()
    }

    /// Plan a laundering pass (pure dry-run; `Ok(None)` = below the
    /// policy threshold).
    pub fn plan_launder(
        &self,
        policy: &LaunderPolicy,
    ) -> Result<Option<PlannedStep>, UnlearnError> {
        let view = self
            .view()
            .map_err(|e| UnlearnError::Internal(format!("{e:#}")))?;
        Planner::plan_launder(&view, policy)
    }

    /// Compact the cumulative forgotten set into a rewritten checkpoint
    /// lineage (audit-gated; see [`launder::execute_launder`]).
    pub fn launder(
        &mut self,
        id: &str,
        policy: &LaunderPolicy,
        force: bool,
    ) -> anyhow::Result<LaunderOutcome> {
        launder::execute_launder(self, id, policy, force)
    }

    /// List the stored full checkpoints (ascending) and the on-disk
    /// size of the latest one — the planner's cost/fallback inputs.
    pub fn checkpoint_index(&self) -> anyhow::Result<(Vec<u32>, u64)> {
        let checkpoints = self.store.list_full()?;
        let checkpoint_bytes = checkpoints
            .last()
            .map(|&s| self.store.full_checkpoint_bytes(s).unwrap_or(0))
            .unwrap_or(0);
        Ok((checkpoints, checkpoint_bytes))
    }

    /// Build the read-only planning view.  The only I/O is listing the
    /// checkpoint store (the planner itself is pure over the view).
    pub fn view(&self) -> anyhow::Result<SystemView<'_>> {
        let (checkpoints, checkpoint_bytes) = self.checkpoint_index()?;
        Ok(self.view_with(checkpoints, checkpoint_bytes))
    }

    /// [`UnlearnSystem::view`] from an already-listed checkpoint index —
    /// no I/O.  Batch planning lists the store once and plans N requests
    /// against it (nothing creates checkpoints mid-batch).
    pub fn view_with(
        &self,
        checkpoints: Vec<u32>,
        checkpoint_bytes: u64,
    ) -> SystemView<'_> {
        // Replay-cost unit: seconds per WAL record.  Prefer the
        // amortized cost of the batched segment entry point — it
        // measures the path replay actually takes, INCLUDING the
        // segment-parallel speedup — and fall back to the raw
        // train_step timer when no segment has run yet.
        let seg_mbs = self
            .rt
            .metrics
            .counter("exec.grad_accumulate.microbatches");
        let step_secs_mean = match self.rt.metrics.timer("exec.grad_accumulate")
        {
            Some((n, tot, _)) if n > 0 && seg_mbs > 0 => tot / seg_mbs as f64,
            _ => self
                .rt
                .metrics
                .timer("exec.train_step")
                .map(|(_, _, mean)| mean)
                .unwrap_or(0.0),
        };
        SystemView {
            corpus: &self.corpus,
            ndindex: &self.ndindex,
            closure_params: self.closure_params,
            adapters: &self.adapters,
            records: &self.records,
            idmap: &self.idmap,
            manifest: &self.manifest,
            forgotten: &self.forgotten,
            ring_earliest: self.ring.earliest_step(),
            ring_available: self.ring.available(),
            ring_budget: self.ring.budget(),
            ring_patch_sizes: self.ring.patch_sizes(),
            logical_step: self.state.logical_step,
            diverged: self.diverged,
            ring_bit_exact: self.ring.bit_exact_reverts(),
            fisher_available: self.fisher.is_some(),
            hot_path: self.hot_path.clone(),
            resume_after_revert: self.resume_after_revert,
            checkpoints,
            checkpoint_bytes,
            param_count: self.rt.manifest.param_count,
            lora_param_count: self.rt.manifest.lora_param_count,
            step_secs_mean,
        }
    }

    /// Dry-run: plan the request without mutating anything.
    pub fn plan(&self, req: &ForgetRequest) -> Result<UnlearnPlan, UnlearnError> {
        let view = self
            .view()
            .map_err(|e| UnlearnError::Internal(format!("{e:#}")))?;
        Planner::plan(&view, req)
    }

    /// Handle one forget request: plan, then execute the fallback chain
    /// (the full Alg. A.7 flow).
    pub fn handle(
        &mut self,
        req: &ForgetRequest,
    ) -> anyhow::Result<ControllerOutcome> {
        let plan = match self.plan(req) {
            Ok(p) => p,
            Err(UnlearnError::DuplicateRequest { id }) => {
                return Ok(ControllerOutcome::duplicate(&id));
            }
            Err(e) => return Err(e.into()),
        };
        Executor::execute(self, req, &plan)
    }
}
