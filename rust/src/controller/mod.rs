//! UNLEARNCONTROLLER (paper Alg. A.7, §4.4): route each forget request
//! to the cheapest path that passes audits, fail closed, and append
//! every action to the signed manifest.
//!
//! Decision order:
//!   1. **Adapter deletion** when cl(F) is confined to cohort adapters.
//!   2. **Recent exact revert** when every offending step is inside the
//!      dense-delta ring window (optionally followed by a filtered
//!      replay of the reverted tail, which restores the retain-only
//!      updates — revert + replay-tail compose into a bounded-work
//!      exact path).
//!   3. **Urgent hot path**: curvature anti-update + retain-tune,
//!      audit-gated; escalate on failure.
//!   4. **Exact replay** (default): nearest checkpoint preceding all
//!      forget influence + `ReplayFilter`.

use std::collections::HashSet;

use crate::adapters::AdapterRegistry;
use crate::audit::{run_audits, AuditContext, AuditReport, AuditThresholds, ModelView};
use crate::checkpoint::{CheckpointStore, TrainState};
use crate::config::{Pins, RunConfig};
use crate::curvature::{hot_path_unlearn, FisherCache, HotPathParams};
use crate::data::corpus::Corpus;
use crate::deltas::DeltaRing;
use crate::manifest::{ActionKind, ForgetManifest, ManifestEntry};
use crate::neardup::{expand_closure, ClosureParams, HammingIndex};
use crate::replay::{
    offending_steps, replay_filter, replay_filter_from_nearest_to,
    ReplayOptions,
};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::wal::{IdMap, WalRecord};

/// Urgency of a forget request (drives the hot-path branch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Urgency {
    Normal,
    High,
}

/// A forget request (user-scoped and/or explicit sample IDs).
#[derive(Debug, Clone)]
pub struct ForgetRequest {
    /// Idempotency key.
    pub id: String,
    pub user: Option<u32>,
    pub sample_ids: Vec<u64>,
    pub urgency: Urgency,
}

/// What the controller did.
#[derive(Debug, Clone)]
pub struct ControllerOutcome {
    pub action: ActionKind,
    pub closure_size: usize,
    pub closure_expanded: usize,
    pub audit: Option<AuditReport>,
    pub escalations: Vec<String>,
    pub details: Json,
    /// False when the idempotency key had already been executed.
    pub executed: bool,
}

/// The live system a controller instance manages.
pub struct UnlearnSystem<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub corpus: Corpus,
    /// Current serving state (θ, Ω).
    pub state: TrainState,
    pub ring: DeltaRing,
    pub adapters: AdapterRegistry,
    pub fisher: Option<FisherCache>,
    pub manifest: ForgetManifest,
    pub records: Vec<WalRecord>,
    pub idmap: IdMap,
    pub pins: Pins,
    pub ndindex: HammingIndex,
    /// Matched member controls + held-out utility IDs for audits.
    pub retain_ids: Vec<u64>,
    pub eval_ids: Vec<u64>,
    pub thresholds: AuditThresholds,
    pub baseline_ppl: Option<f64>,
    pub closure_params: ClosureParams,
    pub hot_path: HotPathParams,
    /// After a ring revert, replay the reverted tail (filtered) to
    /// restore retain-only progress.
    pub resume_after_revert: bool,
    pub audit_seed: u64,
}

impl<'rt> UnlearnSystem<'rt> {
    fn audit_ctx<'a>(&'a self, closure: &'a [u64]) -> AuditContext<'a> {
        AuditContext {
            rt: self.rt,
            corpus: &self.corpus,
            forget_ids: closure,
            retain_ids: &self.retain_ids,
            eval_ids: &self.eval_ids,
            baseline_ppl: self.baseline_ppl,
            thresholds: self.thresholds.clone(),
            seed: self.audit_seed,
        }
    }

    fn append_manifest(
        &mut self,
        req: &ForgetRequest,
        closure: &[u64],
        expanded: usize,
        action: ActionKind,
        details: Json,
        audit: Option<&AuditReport>,
    ) -> anyhow::Result<()> {
        let mut request = Json::obj();
        request
            .set("id", req.id.as_str())
            .set(
                "user",
                req.user.map(Json::from).unwrap_or(Json::Null),
            )
            .set("requested_ids", req.sample_ids.len())
            .set(
                "urgency",
                match req.urgency {
                    Urgency::Normal => "normal",
                    Urgency::High => "high",
                },
            );
        let mut cl = Json::obj();
        cl.set("size", closure.len()).set("expanded", expanded);
        let mut artifacts = Json::obj();
        artifacts
            .set("model_hash", self.state.model_hash())
            .set("optimizer_hash", self.state.optimizer_hash());
        self.manifest.append(&ManifestEntry {
            idempotency_key: req.id.clone(),
            request,
            closure_summary: cl,
            action,
            details,
            audits: audit.map(|a| a.to_json()),
            artifacts,
        })?;
        Ok(())
    }

    /// Expand the request to cl(F) (Alg. A.7 line 1).
    pub fn closure_of(&self, req: &ForgetRequest) -> (Vec<u64>, usize) {
        let mut ids = req.sample_ids.clone();
        if let Some(u) = req.user {
            ids.extend(self.corpus.user_samples(u));
        }
        ids.sort_unstable();
        ids.dedup();
        let cl = expand_closure(
            &self.corpus,
            &self.ndindex,
            &ids,
            self.closure_params,
        );
        (cl.ids, cl.expanded.len())
    }

    /// Handle one forget request (the full Alg. A.7 flow).
    pub fn handle(
        &mut self,
        req: &ForgetRequest,
    ) -> anyhow::Result<ControllerOutcome> {
        if self.manifest.was_executed(&req.id) {
            return Ok(ControllerOutcome {
                action: ActionKind::Refused,
                closure_size: 0,
                closure_expanded: 0,
                audit: None,
                escalations: vec!["duplicate idempotency key".into()],
                details: Json::obj(),
                executed: false,
            });
        }
        let (closure, expanded) = self.closure_of(req);
        anyhow::ensure!(!closure.is_empty(), "empty forget closure");
        let closure_set: HashSet<u64> = closure.iter().copied().collect();
        let mut escalations = Vec::new();
        let mut deleted_cohorts: Vec<u32> = Vec::new();
        let mut adapter_audit: Option<AuditReport> = None;

        // ---- path 1: adapter deletion --------------------------------
        if let Some(cohorts) = self.adapters.covering_cohorts(&closure) {
            if !cohorts.is_empty() {
                let mut deleted = Vec::new();
                let mut refused = false;
                for c in &cohorts {
                    match self.adapters.delete_cohort(*c) {
                        Ok(_) => deleted.push(*c),
                        Err(e) => {
                            escalations
                                .push(format!("adapter delete failed: {e}"));
                            refused = true;
                        }
                    }
                }
                if !refused {
                    let audit = run_audits(
                        &self.audit_ctx(&closure),
                        ModelView::Base(&self.state.params),
                    )?;
                    deleted_cohorts = deleted.clone();
                    adapter_audit = Some(audit.clone());
                    let mut details = Json::obj();
                    details.set(
                        "deleted_cohorts",
                        Json::Arr(
                            deleted.iter().map(|&c| c.into()).collect(),
                        ),
                    );
                    if audit.pass() {
                        self.append_manifest(
                            req,
                            &closure,
                            expanded,
                            ActionKind::AdapterDelete,
                            details.clone(),
                            Some(&audit),
                        )?;
                        return Ok(ControllerOutcome {
                            action: ActionKind::AdapterDelete,
                            closure_size: closure.len(),
                            closure_expanded: expanded,
                            audit: Some(audit),
                            escalations,
                            details,
                            executed: true,
                        });
                    }
                    escalations.push("adapter-delete audit failed".into());
                }
            }
        }

        // ---- offending steps (Alg. A.7 line 6) -----------------------
        let offending = offending_steps(&self.records, &self.idmap, &closure_set)?;

        if offending.is_empty() {
            // nothing in the base was influenced.  If we already deleted
            // cohort adapters, the request IS served (the audit report,
            // pass or fail, rides along in the manifest — there is no
            // stronger path left: the base never saw the data).
            let (action, audit) = if !deleted_cohorts.is_empty() {
                (ActionKind::AdapterDelete, adapter_audit.clone())
            } else {
                let audit = run_audits(
                    &self.audit_ctx(&closure),
                    ModelView::Base(&self.state.params),
                )?;
                (ActionKind::Refused, Some(audit))
            };
            let mut details = Json::obj();
            details.set("note", "no offending steps in WAL");
            if !deleted_cohorts.is_empty() {
                details.set(
                    "deleted_cohorts",
                    Json::Arr(
                        deleted_cohorts.iter().map(|&c| c.into()).collect(),
                    ),
                );
            }
            self.append_manifest(
                req,
                &closure,
                expanded,
                action,
                details.clone(),
                audit.as_ref(),
            )?;
            return Ok(ControllerOutcome {
                action,
                closure_size: closure.len(),
                closure_expanded: expanded,
                audit,
                escalations,
                details,
                executed: true,
            });
        }
        let min_offending = offending[0];

        // ---- path 2: recent exact revert ------------------------------
        if let Some(earliest) = self.ring.earliest_step() {
            if min_offending >= earliest {
                let u = (self.state.logical_step - min_offending) as usize;
                if u <= self.ring.available() {
                    self.ring.revert(&mut self.state, u)?;
                    let mut details = Json::obj();
                    details
                        .set("reverted_steps", u)
                        .set("reverted_to", self.state.logical_step);
                    if self.resume_after_revert {
                        // replay the reverted tail with filtering — the
                        // composition restores retain-only progress exactly
                        let outcome = replay_filter(
                            self.rt,
                            &self.corpus,
                            &self.state,
                            &self.records,
                            &self.idmap,
                            &closure_set,
                            Some(&self.pins),
                            &ReplayOptions::default(),
                        )?;
                        self.state = outcome.state;
                        details.set(
                            "resumed_applied_steps",
                            outcome.invariants.applied_steps,
                        );
                    }
                    let audit = run_audits(
                        &self.audit_ctx(&closure),
                        ModelView::Base(&self.state.params),
                    )?;
                    if audit.pass() {
                        self.append_manifest(
                            req,
                            &closure,
                            expanded,
                            ActionKind::RecentRevert,
                            details.clone(),
                            Some(&audit),
                        )?;
                        return Ok(ControllerOutcome {
                            action: ActionKind::RecentRevert,
                            closure_size: closure.len(),
                            closure_expanded: expanded,
                            audit: Some(audit),
                            escalations,
                            details,
                            executed: true,
                        });
                    }
                    escalations.push("revert audit failed".into());
                }
            }
        }

        // ---- path 3: urgent hot path ----------------------------------
        if req.urgency == Urgency::High {
            if let Some(fisher) = self.fisher.clone() {
                let mut candidate = self.state.clone();
                let hp_out = hot_path_unlearn(
                    self.rt,
                    &self.corpus,
                    &mut candidate,
                    &fisher,
                    &closure_set,
                    &self.retain_ids,
                    &self.hot_path,
                    self.audit_seed,
                )?;
                let audit = run_audits(
                    &self.audit_ctx(&closure),
                    ModelView::Base(&candidate.params),
                )?;
                let mut details = Json::obj();
                details
                    .set("anti_steps", hp_out.anti_steps_applied)
                    .set("backtracks", hp_out.backtracks)
                    .set("forget_loss_before", hp_out.forget_loss_before)
                    .set("forget_loss_after", hp_out.forget_loss_after);
                if audit.pass() {
                    self.state = candidate;
                    self.append_manifest(
                        req,
                        &closure,
                        expanded,
                        ActionKind::HotPathAntiUpdate,
                        details.clone(),
                        Some(&audit),
                    )?;
                    return Ok(ControllerOutcome {
                        action: ActionKind::HotPathAntiUpdate,
                        closure_size: closure.len(),
                        closure_expanded: expanded,
                        audit: Some(audit),
                        escalations,
                        details,
                        executed: true,
                    });
                }
                escalations
                    .push("hot-path audit failed — escalating to replay".into());
            } else {
                escalations.push("no fisher cache — hot path unavailable".into());
            }
        }

        // ---- path 4: exact replay (default) ---------------------------
        // nearest checkpoint at or before the first forget influence;
        // the offending set is already computed above, so hand the
        // target step straight to the replay layer (no second WAL scan)
        let store = CheckpointStore::open(
            &self.cfg.run_dir.join("ckpt"),
            self.cfg.checkpoint_keep,
        )?;
        let (k, outcome) = replay_filter_from_nearest_to(
            self.rt,
            &self.corpus,
            &store,
            &self.records,
            &self.idmap,
            &closure_set,
            min_offending,
            Some(&self.pins),
            &ReplayOptions::default(),
        )?;
        self.state = outcome.state;
        let audit = run_audits(
            &self.audit_ctx(&closure),
            ModelView::Base(&self.state.params),
        )?;
        let mut details = Json::obj();
        details
            .set("from_checkpoint", k)
            .set("applied_steps", outcome.invariants.applied_steps)
            .set(
                "empty_logical_steps",
                outcome.invariants.empty_logical_steps,
            )
            .set(
                "skipped_microbatches",
                outcome.invariants.skipped_microbatches,
            );
        self.append_manifest(
            req,
            &closure,
            expanded,
            ActionKind::ExactReplay,
            details.clone(),
            Some(&audit),
        )?;
        Ok(ControllerOutcome {
            action: ActionKind::ExactReplay,
            closure_size: closure.len(),
            closure_expanded: expanded,
            audit: Some(audit),
            escalations,
            details,
            executed: true,
        })
    }
}
