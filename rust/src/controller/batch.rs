//! Batch coalescing: one shared rebuild serving many requests.
//!
//! Deletion requests arrive as a *stream* (the ROADMAP's
//! millions-of-users north star); most of them bottom out in the same
//! expensive operation — a filtered rebuild of the serving state.
//! [`execute_batch`] unions the closures of N pending requests, runs
//! **one** shared rebuild filtering the union (a bounded ring
//! revert + resumed tail when the union fits the delta-ring window,
//! else a nearest-checkpoint tail replay), and fans per-request
//! manifest entries and outcomes back out.  Exact by Theorem A.1
//! either way: the rebuild starts from a state that precedes every
//! offending step of the union, so replay-filter(∪ᵢ clᵢ) equals the
//! state sequential handling reaches after its *last* rebuild (each
//! sequential rebuild also filters the cumulative union — see
//! `tests/replay_equality.rs`).
//!
//! Sequential parity of the gates: a request whose plan opens with an
//! adapter deletion gets that gate first (shared
//! [`Executor::adapter_step`] — registry mutations and audit identical
//! to the sequential chain); if deletion alone passes its audit the
//! request is served and contributes nothing to the rebuild union.
//! The one deliberate upgrade: urgent requests join the shared *exact*
//! rebuild instead of the approximate hot path — amortized it is as
//! fast, and strictly stronger.  (Audit gates in a batch run against
//! the pre-batch state; a sequential stream would audit later requests
//! against intermediate states.)
//!
//! Failure isolation: every request owns its result slot.  Outcomes
//! already committed in phase A are never discarded; when the shared
//! rebuild cannot run (e.g. checkpoint pruning left the union without
//! a common start point) the members fall back to sequential handling,
//! so only the genuinely unservable requests fail — each with its own
//! error.

use std::collections::HashSet;

use crate::audit::{
    batch_forget_losses, run_audits_with, shared_evals, ModelView,
};
use crate::manifest::ActionKind;
use crate::replay::{offending_steps, replay_filter, ReplayOutcome};
use crate::util::json::Json;

use super::execute::{
    note_deleted, record_adapter_side_effect, replay_tail, Executor,
};
use super::plan::{PlanStep, Planner, UnlearnError, UnlearnPlan};
use super::{ControllerOutcome, ForgetRequest, UnlearnSystem};

/// How the shared rebuild runs.
pub enum SharedMode {
    /// Revert `steps` dense deltas, then resume the reverted tail
    /// filtered by the union (bounded work — the union's influence is
    /// entirely inside the ring window).
    RingRevert { steps: usize },
    /// Filtered tail replay from the nearest stored checkpoint.
    Replay { from_checkpoint: u32 },
}

/// The shared execution a coalesced batch runs once: the union of the
/// member closures (plus everything already forgotten), the earliest
/// step that union influences, and how to rebuild from before it.
pub struct SharedReplayPlan {
    /// Member closures ∪ cumulative forgotten — determines `target`.
    pub union: HashSet<u64>,
    /// `union` ∪ the active lineage's laundered closure — what the
    /// rebuild actually filters (laundered influence is absent from
    /// every checkpoint but still present in the WAL tail).
    pub filter: HashSet<u64>,
    pub target: u32,
    pub mode: SharedMode,
}

/// Plans the shared execution of a coalesced batch.
pub struct BatchPlanner;

impl BatchPlanner {
    /// Union the closures of the replay-bound member plans with the
    /// cumulative forgotten set and pick the cheapest exact rebuild for
    /// the whole union — ring revert when its reach allows, else the
    /// nearest checkpoint from the caller-supplied index (Thm. A.1 in
    /// both cases).  Pure: no I/O, mutates nothing.
    pub fn plan_shared(
        sys: &UnlearnSystem<'_>,
        members: &[&UnlearnPlan],
        checkpoints: &[u32],
    ) -> anyhow::Result<SharedReplayPlan> {
        let mut union: HashSet<u64> = sys.forgotten.clone();
        for p in members {
            union.extend(p.closure.iter().copied());
        }
        let off = offending_steps(&sys.records, &sys.idmap, &union)?;
        let target = *off.first().ok_or_else(|| {
            anyhow::anyhow!("batch union has no offending steps")
        })?;
        let mut filter = union.clone();
        filter.extend(sys.laundered.iter().copied());
        // ring mode needs the logged trajectory intact, the resumed
        // tail (without the resume, reverting alone would discard
        // retain-only progress — not the sequential semantics), and
        // bitwise-exact reverts: XOR patches covering the optimizer.
        // Arithmetic patches revert only up to rounding, which would
        // break the batch ≡ sequential bit-identity guarantee.
        if !sys.diverged
            && sys.resume_after_revert
            && sys.ring.bit_exact_reverts()
        {
            if let Some(earliest) = sys.ring.earliest_step() {
                let needed =
                    sys.state.logical_step.saturating_sub(target) as usize;
                if target >= earliest && needed <= sys.ring.available() {
                    return Ok(SharedReplayPlan {
                        union,
                        filter,
                        target,
                        mode: SharedMode::RingRevert { steps: needed },
                    });
                }
            }
        }
        let from_checkpoint = checkpoints
            .iter()
            .filter(|&&s| s <= target)
            .max()
            .copied()
            .ok_or(UnlearnError::NoCheckpoint { target })?;
        Ok(SharedReplayPlan {
            union,
            filter,
            target,
            mode: SharedMode::Replay { from_checkpoint },
        })
    }
}

/// Run the planned shared rebuild.  On success the serving state is the
/// retain-only state w.r.t. the union (bit-exact, Thm. A.1).
fn run_shared(
    sys: &mut UnlearnSystem<'_>,
    sp: &SharedReplayPlan,
) -> anyhow::Result<ReplayOutcome> {
    match sp.mode {
        SharedMode::RingRevert { steps } => {
            sys.ring.revert(&mut sys.state, steps)?;
            sys.diverged = true;
            replay_filter(
                sys.rt,
                &sys.corpus,
                &sys.state,
                &sys.records,
                &sys.idmap,
                &sp.filter,
                Some(&sys.pins),
                &sys.replay_options(),
            )
        }
        SharedMode::Replay { from_checkpoint } => {
            replay_tail(sys, from_checkpoint, &sp.filter)
        }
    }
}

/// What one drained batch did.
pub struct BatchOutcome {
    /// Per-request results, in submission order.
    pub outcomes: Vec<anyhow::Result<ControllerOutcome>>,
    /// Shared rebuilds actually executed (0 or 1).
    pub replays_run: usize,
    /// Requests that shared the coalesced rebuild.
    pub coalesced_requests: usize,
    /// Checkpoint the shared rebuild started from (None in ring mode).
    pub from_checkpoint: Option<u32>,
    /// Microbatch updates the shared rebuild applied.
    pub applied_steps: u32,
}

/// Execute a batch of requests with rebuild coalescing.  Individual
/// (adapter/no-op/duplicate/error) requests run first in submission
/// order; the rest share a single union-filtered rebuild.
pub fn execute_batch(
    sys: &mut UnlearnSystem<'_>,
    reqs: &[ForgetRequest],
) -> anyhow::Result<BatchOutcome> {
    let mut slots: Vec<Option<anyhow::Result<ControllerOutcome>>> =
        (0..reqs.len()).map(|_| None).collect();
    // per coalesced request: input index, plan, escalations accrued by
    // the adapter gate, cohorts it deleted (owed to the manifest entry)
    struct Member {
        idx: usize,
        plan: UnlearnPlan,
        escalations: Vec<UnlearnError>,
        deleted_cohorts: Vec<u32>,
    }
    let mut coalesced: Vec<Member> = Vec::new();

    // One checkpoint-store listing serves the whole batch (nothing
    // creates checkpoints mid-batch; per-request view() re-listing
    // would be N redundant directory scans under the system lock).
    let (checkpoints, checkpoint_bytes) = sys.checkpoint_index()?;

    // Phase A: plan each request against the current system; run the
    // cheap dispositions (and the adapter gate — sequential parity)
    // immediately.  Adapter deletions never interact with the union.
    for (i, req) in reqs.iter().enumerate() {
        let plan = match Planner::plan(
            &sys.view_with(checkpoints.clone(), checkpoint_bytes),
            req,
        ) {
            Ok(p) => p,
            Err(UnlearnError::DuplicateRequest { id }) => {
                slots[i] = Some(Ok(ControllerOutcome::duplicate(&id)));
                continue;
            }
            Err(e) => {
                slots[i] = Some(Err(e.into()));
                continue;
            }
        };
        if plan.offending.is_empty() {
            slots[i] = Some(Executor::execute(sys, req, &plan));
            continue;
        }
        let mut escalations = plan.notes.clone();
        let mut deleted_cohorts = Vec::new();
        if let Some(PlanStep::AdapterDelete { cohorts }) =
            plan.steps.first().map(|s| &s.step)
        {
            let cohorts = cohorts.clone();
            match Executor::adapter_step(
                sys,
                req,
                &plan,
                &cohorts,
                &mut escalations,
            ) {
                Ok(att) => {
                    if let Some(o) = att.outcome {
                        // adapter deletion alone served it — no replay
                        slots[i] = Some(Ok(o));
                        continue;
                    }
                    deleted_cohorts = att.deleted;
                }
                Err(e) => {
                    slots[i] = Some(Err(e));
                    continue;
                }
            }
        }
        coalesced.push(Member {
            idx: i,
            plan,
            escalations,
            deleted_cohorts,
        });
    }

    // Phase B: one shared rebuild for everything that touched the base.
    let mut replays_run = 0;
    let mut from_checkpoint = None;
    let mut applied_steps = 0;
    if !coalesced.is_empty() {
        let members: Vec<&UnlearnPlan> =
            coalesced.iter().map(|m| &m.plan).collect();
        let shared =
            match BatchPlanner::plan_shared(sys, &members, &checkpoints) {
                Ok(sp) => run_shared(sys, &sp).map(|o| (sp, o)),
                Err(e) => Err(e),
            };
        match shared {
            Err(e) => {
                // The UNION has no shared rebuild point (e.g. checkpoint
                // pruning removed everything preceding one member's
                // influence) or the shared rebuild itself failed.  Fall
                // back to sequential handling so members that can be
                // served individually still are — only the genuinely
                // unservable ones fail, each with its own error.
                let msg = format!("{e:#}");
                for m in &coalesced {
                    let req = &reqs[m.idx];
                    // phase A's registry mutations must not vanish from
                    // the trail: the sequential re-plan can no longer
                    // see the already-deleted cohorts
                    if !m.deleted_cohorts.is_empty() {
                        if let Err(se) = record_adapter_side_effect(
                            sys,
                            req,
                            &m.plan.closure,
                            m.plan.closure_expanded,
                            &m.deleted_cohorts,
                            None,
                        ) {
                            slots[m.idx] = Some(Err(se));
                            continue;
                        }
                    }
                    slots[m.idx] = Some(sys.handle(req).map_err(|he| {
                        anyhow::anyhow!(
                            "coalesced rebuild failed ({msg}); sequential \
                             fallback also failed: {he:#}"
                        )
                    }));
                }
            }
            Ok((sp, outcome)) => {
                sys.state = outcome.state;
                sys.diverged = true;
                for m in &coalesced {
                    sys.forgotten.extend(m.plan.closure.iter().copied());
                }
                sys.persist_forgotten()?;
                replays_run = 1;
                applied_steps = outcome.invariants.applied_steps;
                let action = match sp.mode {
                    SharedMode::RingRevert { .. } => ActionKind::RecentRevert,
                    SharedMode::Replay { from_checkpoint: k } => {
                        from_checkpoint = Some(k);
                        ActionKind::ExactReplay
                    }
                };

                // Fan manifest entries + outcomes back out, one per
                // request, each audited against its own closure; an
                // audit/manifest failure affects only its own slot.
                // Like the sequential last resort, the shared rebuild
                // commits with its audit report attached pass or fail
                // (the state is exact either way) — a failed audit is
                // surfaced as a typed escalation on that member.
                //
                // Every member audits the SAME post-rebuild state, so
                // the request-independent chunks (MIA retain controls,
                // utility PPL) are evaluated once here and reused, AND
                // the per-request forget probes are batched: one
                // `eval_batch` call over the union of the member
                // closures feeds every member's MIA probe.
                // Bit-transparent both ways: per-example losses are
                // pure functions of (state, sample).  On a precompute
                // failure fall back to fully-inline audits so one bad
                // eval cannot sink the whole batch.
                let mut shared = shared_evals(
                    &sys.audit_ctx(&[]),
                    ModelView::Base(&sys.state.params),
                )
                .ok();
                if let Some(sh) = shared.as_mut() {
                    let member_closures: Vec<&[u64]> = coalesced
                        .iter()
                        .map(|m| m.plan.closure.as_slice())
                        .collect();
                    sh.forget_losses = batch_forget_losses(
                        sys.rt,
                        ModelView::Base(&sys.state.params),
                        &sys.corpus,
                        &member_closures,
                    )
                    .ok();
                }
                let n = coalesced.len();
                for m in &coalesced {
                    let req = &reqs[m.idx];
                    if sys.manifest.was_executed(&req.id) {
                        // same idempotency key twice inside one window
                        slots[m.idx] =
                            Some(Ok(ControllerOutcome::duplicate(&req.id)));
                        continue;
                    }
                    let res = (|| -> anyhow::Result<ControllerOutcome> {
                        let audit = run_audits_with(
                            &sys.audit_ctx(&m.plan.closure),
                            ModelView::Base(&sys.state.params),
                            shared.as_ref(),
                        )?;
                        let mut details = Json::obj();
                        details
                            .set("coalesced", n)
                            .set("union_closure", sp.union.len());
                        // detail keys match the sequential paths so
                        // manifest consumers see one schema per action
                        match sp.mode {
                            SharedMode::RingRevert { steps } => {
                                details.set("reverted_steps", steps).set(
                                    "resumed_applied_steps",
                                    outcome.invariants.applied_steps,
                                );
                            }
                            SharedMode::Replay { from_checkpoint: k } => {
                                details.set("from_checkpoint", k).set(
                                    "applied_steps",
                                    outcome.invariants.applied_steps,
                                );
                            }
                        }
                        note_deleted(&mut details, &m.deleted_cohorts);
                        sys.append_manifest(
                            req,
                            &m.plan.closure,
                            m.plan.closure_expanded,
                            action,
                            details.clone(),
                            Some(&audit),
                        )?;
                        let mut escalations = m.escalations.clone();
                        if !audit.pass() {
                            escalations.push(UnlearnError::AuditFailed {
                                path: action,
                            });
                        }
                        Ok(ControllerOutcome {
                            action,
                            closure_size: m.plan.closure.len(),
                            closure_expanded: m.plan.closure_expanded,
                            audit: Some(audit),
                            escalations,
                            details,
                            executed: true,
                        })
                    })();
                    slots[m.idx] = Some(res);
                }
            }
        }
    }

    let coalesced_requests = coalesced.len();
    Ok(BatchOutcome {
        outcomes: slots
            .into_iter()
            .map(|s| s.expect("every slot filled"))
            .collect(),
        replays_run,
        coalesced_requests,
        from_checkpoint,
        applied_steps,
    })
}
