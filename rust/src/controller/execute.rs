//! Audit-gated execution of an [`UnlearnPlan`] (the *action* half of
//! Alg. A.7).  Walks the plan's fallback chain in order; each step runs,
//! is audited, and either commits (signed manifest entry + outcome) or
//! records a typed escalation and falls through to the next step.  The
//! final replay step is the last resort: it always commits, with its
//! audit report attached pass or fail (there is no stronger path left).

use std::collections::HashSet;

use crate::audit::{run_audits, AuditReport, ModelView};
use crate::curvature::hot_path_unlearn;
use crate::manifest::ActionKind;
use crate::replay::{replay_filter, ReplayOutcome};
use crate::util::json::Json;

use super::plan::{PlanStep, UnlearnError, UnlearnPlan};
use super::{ControllerOutcome, ForgetRequest, UnlearnSystem};

/// Executes plans against the live system.  Stateless: all state lives
/// in the [`UnlearnSystem`]; the executor is the only code path that
/// mutates it.
pub struct Executor;

/// Result of one adapter-delete attempt (shared by the sequential
/// chain and batch phase A so the adapter gate behaves identically).
pub(super) struct AdapterAttempt {
    /// Committed outcome when adapter deletion alone served the request
    /// (its audit passed); None when refused or the audit failed.
    pub outcome: Option<ControllerOutcome>,
    /// Cohorts actually removed (recorded even on partial refusal).
    pub deleted: Vec<u32>,
    /// The audit report, when one ran (deletion was not refused).
    pub audit: Option<AuditReport>,
}

/// Manifest-detail note for cohorts removed earlier in the chain — a
/// registry mutation must appear in whichever entry finally commits.
pub(super) fn note_deleted(details: &mut Json, deleted: &[u32]) {
    if !deleted.is_empty() {
        details.set(
            "deleted_cohorts",
            Json::Arr(deleted.iter().map(|&c| c.into()).collect()),
        );
    }
}

/// Record cohorts deleted by a chain that then failed to serve the
/// request.  The registry mutation is permanent and must reach the
/// signed manifest; it is recorded under a derived key so the request's
/// own idempotency key stays unconsumed (the request was NOT served and
/// must remain retryable).
pub(super) fn record_adapter_side_effect(
    sys: &mut UnlearnSystem<'_>,
    req: &ForgetRequest,
    closure: &[u64],
    closure_expanded: usize,
    deleted: &[u32],
    audit: Option<&AuditReport>,
) -> anyhow::Result<()> {
    let mut details = Json::obj();
    details.set(
        "note",
        "chain failed after adapter deletion — request not served; \
         registry mutation recorded for the audit trail",
    );
    note_deleted(&mut details, deleted);
    let side_req = ForgetRequest {
        id: format!("{}#adapter-side-effect", req.id),
        ..req.clone()
    };
    sys.append_manifest(
        &side_req,
        closure,
        closure_expanded,
        ActionKind::AdapterDelete,
        details,
        audit,
    )
}

/// Filtered tail replay from a stored checkpoint — the one replay
/// commit primitive shared by the sequential `ExactReplay` step and the
/// batch coalescer (their bit-equality is the module's core invariant,
/// so they must not drift).
pub(super) fn replay_tail(
    sys: &UnlearnSystem<'_>,
    from_checkpoint: u32,
    filter: &HashSet<u64>,
) -> anyhow::Result<ReplayOutcome> {
    let ck = sys.store().load_full(from_checkpoint)?;
    replay_filter(
        sys.rt,
        &sys.corpus,
        &ck,
        &sys.records,
        &sys.idmap,
        filter,
        Some(&sys.pins),
        &sys.replay_options(),
    )
}

impl Executor {
    /// Run `plan` for `req`.  Returns the outcome of the first step
    /// whose audit gate passes (or the final step regardless).
    pub fn execute(
        sys: &mut UnlearnSystem<'_>,
        req: &ForgetRequest,
        plan: &UnlearnPlan,
    ) -> anyhow::Result<ControllerOutcome> {
        let closure = &plan.closure;
        let closure_set: HashSet<u64> = closure.iter().copied().collect();
        // Exactness across a request *stream*: rebuilds must filter the
        // cumulative union — closure ∪ forgotten ∪ laundered — or a
        // later replay would resurrect data a previous action (or a
        // retired lineage) already erased.  Only closure ∪ forgotten
        // moves the rebuild TARGET; the laundered set is already absent
        // from every active-lineage checkpoint.
        let mut effective = closure_set.clone();
        effective.extend(sys.forgotten.iter().copied());
        effective.extend(sys.laundered.iter().copied());

        let mut escalations: Vec<UnlearnError> = plan.notes.clone();
        let mut deleted_cohorts: Vec<u32> = Vec::new();
        let mut adapter_audit: Option<AuditReport> = None;
        // The last step that mutated the serving state but failed its
        // audit gate.  If the chain then exhausts (e.g. every checkpoint
        // preceding the target was pruned, so no replay was plannable),
        // this mutation must still reach the signed manifest — no state
        // change may escape the audit trail.
        let mut mutated_attempt: Option<(ActionKind, Json, AuditReport)> =
            None;

        for planned in &plan.steps {
            match &planned.step {
                // ---- path 1: adapter deletion ------------------------
                PlanStep::AdapterDelete { cohorts } => {
                    let att = Self::adapter_step(
                        sys,
                        req,
                        plan,
                        cohorts,
                        &mut escalations,
                    )?;
                    // record even partial deletions — adapters already
                    // removed must reach the manifest no matter how the
                    // rest of the chain goes
                    deleted_cohorts = att.deleted;
                    adapter_audit = att.audit;
                    if let Some(o) = att.outcome {
                        return Ok(o);
                    }
                }

                // ---- no base influence: audited no-op ----------------
                PlanStep::NoOp => {
                    let audit = run_audits(
                        &sys.audit_ctx(closure),
                        ModelView::Base(&sys.state.params),
                    )?;
                    let mut details = Json::obj();
                    details.set("note", "no offending steps in WAL");
                    sys.append_manifest(
                        req,
                        closure,
                        plan.closure_expanded,
                        ActionKind::Refused,
                        details.clone(),
                        Some(&audit),
                    )?;
                    return Ok(Self::outcome(
                        ActionKind::Refused,
                        plan,
                        Some(audit),
                        escalations,
                        details,
                    ));
                }

                // ---- path 2: recent exact revert ---------------------
                PlanStep::RingRevert { steps, resume_tail } => {
                    sys.ring.revert(&mut sys.state, *steps)?;
                    sys.diverged = true;
                    let mut details = Json::obj();
                    details
                        .set("reverted_steps", *steps)
                        .set("reverted_to", sys.state.logical_step);
                    if *resume_tail {
                        // replay the reverted tail with filtering — the
                        // composition restores retain-only progress exactly
                        let outcome = replay_filter(
                            sys.rt,
                            &sys.corpus,
                            &sys.state,
                            &sys.records,
                            &sys.idmap,
                            &effective,
                            Some(&sys.pins),
                            &sys.replay_options(),
                        )?;
                        sys.state = outcome.state;
                        details.set(
                            "resumed_applied_steps",
                            outcome.invariants.applied_steps,
                        );
                    }
                    note_deleted(&mut details, &deleted_cohorts);
                    let audit = run_audits(
                        &sys.audit_ctx(closure),
                        ModelView::Base(&sys.state.params),
                    )?;
                    if audit.pass() {
                        sys.commit_forgotten(closure.iter().copied())?;
                        sys.append_manifest(
                            req,
                            closure,
                            plan.closure_expanded,
                            ActionKind::RecentRevert,
                            details.clone(),
                            Some(&audit),
                        )?;
                        return Ok(Self::outcome(
                            ActionKind::RecentRevert,
                            plan,
                            Some(audit),
                            escalations,
                            details,
                        ));
                    }
                    if *resume_tail && sys.ring.bit_exact_reverts() {
                        // bitwise-exact revert + resumed tail IS the
                        // retain-only state (Thm. A.11(a) + A.1) —
                        // committable if the chain exhausts.  A revert
                        // without the resume, or an arithmetic revert
                        // (exact only up to rounding), is never
                        // terminal-committed.
                        mutated_attempt = Some((
                            ActionKind::RecentRevert,
                            details,
                            audit,
                        ));
                    }
                    escalations.push(UnlearnError::AuditFailed {
                        path: ActionKind::RecentRevert,
                    });
                }

                // ---- path 3: urgent hot path -------------------------
                PlanStep::HotPathAntiUpdate { params } => {
                    let fisher = sys
                        .fisher
                        .clone()
                        .ok_or(UnlearnError::NoFisherCache)?;
                    let mut candidate = sys.state.clone();
                    let hp_out = hot_path_unlearn(
                        sys.rt,
                        &sys.corpus,
                        &mut candidate,
                        &fisher,
                        &closure_set,
                        &sys.retain_ids,
                        params,
                        sys.audit_seed,
                    )?;
                    let audit = run_audits(
                        &sys.audit_ctx(closure),
                        ModelView::Base(&candidate.params),
                    )?;
                    let mut details = Json::obj();
                    details
                        .set("anti_steps", hp_out.anti_steps_applied)
                        .set("backtracks", hp_out.backtracks)
                        .set("forget_loss_before", hp_out.forget_loss_before)
                        .set("forget_loss_after", hp_out.forget_loss_after);
                    note_deleted(&mut details, &deleted_cohorts);
                    // the candidate was built on top of any earlier
                    // (audit-failed) revert+resume — full provenance of
                    // the serving state must reach the manifest
                    if let Some((_, prior, _)) = &mutated_attempt {
                        details.set("after_failed_revert", prior.clone());
                    }
                    if audit.pass() {
                        sys.state = candidate;
                        sys.diverged = true;
                        sys.commit_forgotten(closure.iter().copied())?;
                        sys.append_manifest(
                            req,
                            closure,
                            plan.closure_expanded,
                            ActionKind::HotPathAntiUpdate,
                            details.clone(),
                            Some(&audit),
                        )?;
                        return Ok(Self::outcome(
                            ActionKind::HotPathAntiUpdate,
                            plan,
                            Some(audit),
                            escalations,
                            details,
                        ));
                    }
                    escalations.push(UnlearnError::AuditFailed {
                        path: ActionKind::HotPathAntiUpdate,
                    });
                }

                // Laundering is request-independent maintenance, never
                // part of a forget request's fallback chain — route it
                // through `launder::execute_launder` instead.
                PlanStep::Launder { .. } => {
                    return Err(anyhow::anyhow!(
                        "launder steps are not executable inside a \
                         forget-request chain"
                    ));
                }

                // ---- path 4: exact replay (last resort) --------------
                PlanStep::ExactReplay { from_checkpoint, .. } => {
                    let outcome =
                        replay_tail(sys, *from_checkpoint, &effective)?;
                    sys.state = outcome.state;
                    sys.diverged = true;
                    sys.commit_forgotten(closure.iter().copied())?;
                    let audit = run_audits(
                        &sys.audit_ctx(closure),
                        ModelView::Base(&sys.state.params),
                    )?;
                    let mut details = Json::obj();
                    details
                        .set("from_checkpoint", *from_checkpoint)
                        .set("applied_steps", outcome.invariants.applied_steps)
                        .set(
                            "empty_logical_steps",
                            outcome.invariants.empty_logical_steps,
                        )
                        .set(
                            "skipped_microbatches",
                            outcome.invariants.skipped_microbatches,
                        );
                    note_deleted(&mut details, &deleted_cohorts);
                    sys.append_manifest(
                        req,
                        closure,
                        plan.closure_expanded,
                        ActionKind::ExactReplay,
                        details.clone(),
                        Some(&audit),
                    )?;
                    return Ok(Self::outcome(
                        ActionKind::ExactReplay,
                        plan,
                        Some(audit),
                        escalations,
                        details,
                    ));
                }
            }
        }

        // Chain exhausted without a commit.  When the base never saw the
        // data there is no stronger path left, so the terminal
        // disposition MUST still reach the signed manifest: either the
        // adapters were fully deleted and only the (toy-noise-prone)
        // audit failed — the request IS served as an adapter delete —
        // or deletion was refused (e.g. a merged cohort), which is
        // recorded as Refused, listing any cohorts that DID get deleted
        // before the refusal so no mutation escapes the audit trail.
        if plan.offending.is_empty() {
            let complete =
                adapter_audit.is_some() && !deleted_cohorts.is_empty();
            let action = if complete {
                ActionKind::AdapterDelete
            } else {
                ActionKind::Refused
            };
            let audit = match adapter_audit {
                Some(a) => a,
                None => run_audits(
                    &sys.audit_ctx(closure),
                    ModelView::Base(&sys.state.params),
                )?,
            };
            let mut details = Json::obj();
            details.set("note", "no offending steps in WAL");
            note_deleted(&mut details, &deleted_cohorts);
            sys.append_manifest(
                req,
                closure,
                plan.closure_expanded,
                action,
                details.clone(),
                Some(&audit),
            )?;
            return Ok(Self::outcome(
                action,
                plan,
                Some(audit),
                escalations,
                details,
            ));
        }
        // A state-mutating path ran, failed its (toy-noise-prone) audit,
        // and nothing stronger was plannable: commit the terminal
        // disposition with the failed audit attached — the revert+resume
        // state IS the retain-only state (Thm. A.11 + A.1), exactly like
        // the replay last resort commits regardless of its audit.
        if let Some((action, details, audit)) = mutated_attempt {
            sys.commit_forgotten(closure.iter().copied())?;
            sys.append_manifest(
                req,
                closure,
                plan.closure_expanded,
                action,
                details.clone(),
                Some(&audit),
            )?;
            return Ok(Self::outcome(
                action,
                plan,
                Some(audit),
                escalations,
                details,
            ));
        }
        // Failing loudly — but cohorts deleted earlier in the chain are
        // a permanent registry mutation that must still reach the
        // signed manifest.
        if !deleted_cohorts.is_empty() {
            record_adapter_side_effect(
                sys,
                req,
                closure,
                plan.closure_expanded,
                &deleted_cohorts,
                adapter_audit.as_ref(),
            )?;
        }
        let chain: Vec<String> =
            escalations.iter().map(|e| e.to_string()).collect();
        Err(anyhow::Error::new(UnlearnError::PlanExhausted)
            .context(chain.join("; ")))
    }

    /// Run one AdapterDelete step: delete the cohorts (the registry is
    /// mutated even when a later gate fails — data also present in the
    /// base is handled by the caller's replay), audit, and commit iff
    /// the audit passes.  Typed escalations for refusals/audit failures
    /// are pushed onto `escalations`.
    pub(super) fn adapter_step(
        sys: &mut UnlearnSystem<'_>,
        req: &ForgetRequest,
        plan: &UnlearnPlan,
        cohorts: &[u32],
        escalations: &mut Vec<UnlearnError>,
    ) -> anyhow::Result<AdapterAttempt> {
        let mut deleted = Vec::new();
        let mut refused = false;
        for &c in cohorts {
            match sys.adapters.delete_cohort(c) {
                Ok(_) => deleted.push(c),
                Err(e) => {
                    escalations.push(UnlearnError::AdapterDeleteFailed {
                        cohort: c,
                        reason: format!("{e:#}"),
                    });
                    refused = true;
                }
            }
        }
        if refused {
            return Ok(AdapterAttempt {
                outcome: None,
                deleted,
                audit: None,
            });
        }
        let audit = run_audits(
            &sys.audit_ctx(&plan.closure),
            ModelView::Base(&sys.state.params),
        )?;
        let mut details = Json::obj();
        details.set(
            "deleted_cohorts",
            Json::Arr(deleted.iter().map(|&c| c.into()).collect()),
        );
        if audit.pass() {
            sys.append_manifest(
                req,
                &plan.closure,
                plan.closure_expanded,
                ActionKind::AdapterDelete,
                details.clone(),
                Some(&audit),
            )?;
            let outcome = Self::outcome(
                ActionKind::AdapterDelete,
                plan,
                Some(audit.clone()),
                escalations.clone(),
                details,
            );
            return Ok(AdapterAttempt {
                outcome: Some(outcome),
                deleted,
                audit: Some(audit),
            });
        }
        escalations.push(UnlearnError::AuditFailed {
            path: ActionKind::AdapterDelete,
        });
        Ok(AdapterAttempt {
            outcome: None,
            deleted,
            audit: Some(audit),
        })
    }

    fn outcome(
        action: ActionKind,
        plan: &UnlearnPlan,
        audit: Option<AuditReport>,
        escalations: Vec<UnlearnError>,
        details: Json,
    ) -> ControllerOutcome {
        ControllerOutcome {
            action,
            closure_size: plan.closure.len(),
            closure_expanded: plan.closure_expanded,
            audit,
            escalations,
            details,
            executed: true,
        }
    }
}
