//! Run configuration: everything the launcher needs to drive a training
//! or unlearning run.  Loaded from a JSON file and/or CLI overrides.

use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};

/// Training/unlearning run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Directory with AOT artifacts (`make artifacts` output).
    pub artifacts_dir: PathBuf,
    /// Working directory for WAL/checkpoints/manifests.
    pub run_dir: PathBuf,
    /// Logical optimizer steps to train.
    pub steps: u32,
    /// Gradient-accumulation length (microbatches per logical step).
    pub accum: usize,
    /// Base learning rate (peak of warmup+cosine).
    pub lr: f32,
    /// Warmup steps of the schedule.
    pub warmup: u32,
    /// Full-checkpoint cadence K (Table 3 "worst-case replay ≤ K·t_step").
    pub checkpoint_every: u32,
    /// Rolling checkpoints kept.
    pub checkpoint_keep: usize,
    /// Micro-checkpoint cadence M (0 = disabled).
    pub micro_checkpoint_every: u32,
    /// Dense-delta ring window N.
    pub ring_window: usize,
    /// Revert optimizer tensors in the ring too (bitwise G3 reverts).
    pub ring_revert_optimizer: bool,
    /// Master run seed (dataloader order, microbatch seeds).
    pub run_seed: u64,
    /// HMAC key for production-mode WAL hashing (None = toy mode).
    pub hmac_key: Option<Vec<u8>>,
    /// WAL records per segment file.
    pub wal_segment_records: usize,
    /// Admin server: automatically run a laundering pass from the queue
    /// worker when `launder_recommended` flips after a drained forget
    /// burst (off by default — the operator/cron drives laundering via
    /// the `launder` op otherwise).
    pub auto_launder: bool,
    /// Fleet topology pin stamped into the run's `Pins` ("" = this run
    /// is not a fleet shard).  Set by [`crate::fleet`] via
    /// [`crate::shard::ShardSpec::pin_for`]; every replay of the run
    /// must present the same pin or fail closed (topology drift).
    pub shard_pin: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            run_dir: PathBuf::from("runs/default"),
            steps: 200,
            accum: 2,
            lr: 3e-3,
            warmup: 20,
            checkpoint_every: 50,
            checkpoint_keep: 8,
            micro_checkpoint_every: 0,
            ring_window: 16,
            ring_revert_optimizer: true,
            run_seed: 0xC0FFEE,
            hmac_key: None,
            wal_segment_records: 4096,
            auto_launder: false,
            shard_pin: String::new(),
        }
    }
}

impl RunConfig {
    /// Warmup + cosine LR schedule, indexed by the *applied-update*
    /// counter (paper §5: "indexed by a logical step counter"; the VALUE
    /// is what goes into the WAL).
    pub fn lr_at(&self, applied_update: u32) -> f32 {
        let t = applied_update as f32;
        if applied_update < self.warmup {
            return self.lr * (t + 1.0) / self.warmup.max(1) as f32;
        }
        let total = self.steps.max(self.warmup + 1) as f32;
        let progress =
            ((t - self.warmup as f32) / (total - self.warmup as f32)).min(1.0);
        0.5 * self.lr * (1.0 + (std::f32::consts::PI * progress).cos())
    }

    /// Load from JSON, with unset fields defaulting.
    pub fn from_json_file(path: &Path) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let mut c = RunConfig::default();
        let get_u = |k: &str, d: u64| -> u64 {
            j.get(k).and_then(|v| v.as_u64()).unwrap_or(d)
        };
        if let Some(s) = j.get("artifacts_dir").and_then(|v| v.as_str()) {
            c.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = j.get("run_dir").and_then(|v| v.as_str()) {
            c.run_dir = PathBuf::from(s);
        }
        c.steps = get_u("steps", c.steps as u64) as u32;
        c.accum = get_u("accum", c.accum as u64) as usize;
        if let Some(f) = j.get("lr").and_then(|v| v.as_f64()) {
            c.lr = f as f32;
        }
        c.warmup = get_u("warmup", c.warmup as u64) as u32;
        c.checkpoint_every =
            get_u("checkpoint_every", c.checkpoint_every as u64) as u32;
        c.checkpoint_keep =
            get_u("checkpoint_keep", c.checkpoint_keep as u64) as usize;
        c.micro_checkpoint_every = get_u(
            "micro_checkpoint_every",
            c.micro_checkpoint_every as u64,
        ) as u32;
        c.ring_window = get_u("ring_window", c.ring_window as u64) as usize;
        if let Some(b) = j.get("ring_revert_optimizer").and_then(|v| v.as_bool())
        {
            c.ring_revert_optimizer = b;
        }
        c.run_seed = get_u("run_seed", c.run_seed);
        if let Some(k) = j.get("hmac_key").and_then(|v| v.as_str()) {
            c.hmac_key = Some(k.as_bytes().to_vec());
        }
        c.wal_segment_records =
            get_u("wal_segment_records", c.wal_segment_records as u64) as usize;
        if let Some(b) = j.get("auto_launder").and_then(|v| v.as_bool()) {
            c.auto_launder = b;
        }
        if let Some(s) = j.get("shard_pin").and_then(|v| v.as_str()) {
            c.shard_pin = s.to_string();
        }
        Ok(c)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("artifacts_dir", self.artifacts_dir.display().to_string())
            .set("run_dir", self.run_dir.display().to_string())
            .set("steps", self.steps)
            .set("accum", self.accum)
            .set("lr", self.lr)
            .set("warmup", self.warmup)
            .set("checkpoint_every", self.checkpoint_every)
            .set("checkpoint_keep", self.checkpoint_keep)
            .set("micro_checkpoint_every", self.micro_checkpoint_every)
            .set("ring_window", self.ring_window)
            .set("ring_revert_optimizer", self.ring_revert_optimizer)
            .set("run_seed", self.run_seed)
            .set("wal_segment_records", self.wal_segment_records)
            .set("auto_launder", self.auto_launder)
            .set("shard_pin", self.shard_pin.as_str());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let c = RunConfig {
            lr: 1.0,
            warmup: 10,
            steps: 100,
            ..Default::default()
        };
        assert!(c.lr_at(0) > 0.0 && c.lr_at(0) < 0.2);
        assert!((c.lr_at(9) - 1.0).abs() < 1e-6); // end of warmup
        assert!(c.lr_at(50) < 1.0);
        assert!(c.lr_at(99) < c.lr_at(50)); // cosine decays
        assert!(c.lr_at(99) >= 0.0);
    }

    #[test]
    fn lr_is_pure_function_of_applied_updates() {
        let c = RunConfig::default();
        for t in 0..c.steps {
            assert_eq!(c.lr_at(t).to_bits(), c.lr_at(t).to_bits());
        }
    }

    #[test]
    fn json_roundtrip() {
        let dir = crate::util::tempdir("cfg");
        let c = RunConfig {
            steps: 42,
            accum: 3,
            lr: 1.5e-3,
            ..Default::default()
        };
        let p = dir.join("run.json");
        std::fs::write(&p, c.to_json().pretty()).unwrap();
        let back = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(back.steps, 42);
        assert_eq!(back.accum, 3);
        assert!((back.lr - 1.5e-3).abs() < 1e-9);
    }
}
