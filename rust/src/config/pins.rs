//! Reproducibility pins (paper Table 2) with fail-closed verification.
//!
//! A [`Pins`] snapshot is taken when training starts and saved next to
//! the WAL.  Before any replay the current environment is re-pinned and
//! compared; **any** drift yields [`PinDrift`] and the controller refuses
//! / escalates (paper §5 "Replay refuses if any pin drifts", §7 fail-
//! closed behaviour).

use std::fmt;
use std::path::Path;

use crate::util::json::{parse, Json};

/// The pinned execution environment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pins {
    /// Executor backend discriminator ("reference" / "pjrt") — the
    /// [`crate::runtime::ExecutorFingerprint`] kind.  Reference and
    /// PJRT runtimes pin different artifact hash sets anyway, but the
    /// kind pin makes the mixed-backend refusal first-class: a replay
    /// under a different backend than trained fails closed on this
    /// field alone.
    pub executor_kind: String,
    /// Fleet topology pin ("" = unsharded): shard index, shard count
    /// and assignment salt, stamped by the fleet trainer via
    /// [`crate::shard::ShardSpec::pin_for`].  A shard's WAL replayed
    /// under a different topology — changed `n_shards`, changed salt, a
    /// run dir opened as a different shard index, or a sharded run
    /// reopened unsharded — fails closed on this field alone, because
    /// the user→shard routing (hence the corpus partition the WAL's
    /// sample IDs index into) would silently differ.
    pub shard: String,
    /// SHA-256 of every AOT artifact (HLO text, init params), sorted by
    /// name — the "CUDA/cuDNN version pins" analogue: the executable IS
    /// the kernel algorithm choice here.
    pub artifact_hashes: Vec<(String, String)>,
    /// Hash of the model config (shapes, dtypes, dropout, optimizer HPs).
    pub model_config_hash: String,
    /// Tokenizer checksum (pinned build).
    pub tokenizer_checksum: String,
    /// Flat parameter count.
    pub param_count: usize,
    /// Gradient-accumulation length (parallel-layout pin).
    pub accum: usize,
    /// Train microbatch size (parallel-layout pin).
    pub batch: usize,
    /// Logical parallel layout descriptor (single-host here; the FSDP/TP/
    /// PP shape string in production).
    pub layout: String,
    /// Loss reduction — MUST be "sum" for exact replay (Prop. A.8).
    pub reduction: String,
    /// PJRT platform name (e.g. "cpu") — the hardware pin.
    pub platform: String,
}

/// A pin drift: which pin, expected vs found.  Fail-closed trigger.
#[derive(Debug, Clone)]
pub struct PinDrift {
    pub pin: String,
    pub expected: String,
    pub found: String,
}

impl fmt::Display for PinDrift {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "pin drift on {:?}: expected {:?}, found {:?} — refusing to \
             replay (fail-closed)",
            self.pin, self.expected, self.found
        )
    }
}

impl std::error::Error for PinDrift {}

impl Pins {
    /// Compare against a freshly captured environment.  Returns every
    /// drift (empty = safe to replay).
    pub fn verify(&self, current: &Pins) -> Vec<PinDrift> {
        let mut drifts = Vec::new();
        let mut check = |pin: &str, a: &str, b: &str| {
            if a != b {
                drifts.push(PinDrift {
                    pin: pin.to_string(),
                    expected: a.to_string(),
                    found: b.to_string(),
                });
            }
        };
        check(
            "executor_kind",
            &self.executor_kind,
            &current.executor_kind,
        );
        check("shard", &self.shard, &current.shard);
        check(
            "model_config_hash",
            &self.model_config_hash,
            &current.model_config_hash,
        );
        check(
            "tokenizer_checksum",
            &self.tokenizer_checksum,
            &current.tokenizer_checksum,
        );
        check(
            "param_count",
            &self.param_count.to_string(),
            &current.param_count.to_string(),
        );
        check("accum", &self.accum.to_string(), &current.accum.to_string());
        check("batch", &self.batch.to_string(), &current.batch.to_string());
        check("layout", &self.layout, &current.layout);
        check("reduction", &self.reduction, &current.reduction);
        check("platform", &self.platform, &current.platform);
        // artifact-by-artifact comparison
        use std::collections::BTreeMap;
        let a: BTreeMap<_, _> = self.artifact_hashes.iter().cloned().collect();
        let b: BTreeMap<_, _> =
            current.artifact_hashes.iter().cloned().collect();
        for (name, hash) in &a {
            match b.get(name) {
                None => check(&format!("artifact:{name}"), hash, "<missing>"),
                Some(h) => check(&format!("artifact:{name}"), hash, h),
            }
        }
        for name in b.keys() {
            if !a.contains_key(name) {
                check(&format!("artifact:{name}"), "<absent at train>", "new");
            }
        }
        drifts
    }

    /// Fail-closed check: error on any drift.
    pub fn ensure_match(&self, current: &Pins) -> anyhow::Result<()> {
        let drifts = self.verify(current);
        if let Some(d) = drifts.first() {
            anyhow::bail!("{d} ({} drift(s) total)", drifts.len());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut arts = Json::obj();
        for (name, hash) in &self.artifact_hashes {
            arts.set(name, hash.as_str());
        }
        let mut j = Json::obj();
        j.set("executor_kind", self.executor_kind.as_str())
            .set("shard", self.shard.as_str())
            .set("artifact_hashes", arts)
            .set("model_config_hash", self.model_config_hash.as_str())
            .set("tokenizer_checksum", self.tokenizer_checksum.as_str())
            .set("param_count", self.param_count)
            .set("accum", self.accum)
            .set("batch", self.batch)
            .set("layout", self.layout.as_str())
            .set("reduction", self.reduction.as_str())
            .set("platform", self.platform.as_str());
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Pins> {
        let s = |k: &str| -> anyhow::Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("pins missing {k}"))?
                .to_string())
        };
        let mut artifact_hashes = Vec::new();
        if let Some(obj) = j.get("artifact_hashes").and_then(|v| v.as_obj()) {
            for (k, v) in obj {
                artifact_hashes.push((
                    k.clone(),
                    v.as_str().unwrap_or_default().to_string(),
                ));
            }
        }
        Ok(Pins {
            // pins saved before the executor-kind pin existed parse as
            // "" and drift against any current capture — fail-closed
            executor_kind: j
                .get("executor_kind")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            // pins saved before the topology pin existed parse as ""
            // (= unsharded) and drift against any sharded capture
            shard: j
                .get("shard")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            artifact_hashes,
            model_config_hash: s("model_config_hash")?,
            tokenizer_checksum: s("tokenizer_checksum")?,
            param_count: j
                .get("param_count")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
            accum: j.get("accum").and_then(|v| v.as_usize()).unwrap_or(0),
            batch: j.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            layout: s("layout")?,
            reduction: s("reduction")?,
            platform: s("platform")?,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Pins> {
        let j = parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("pins: {e}"))?;
        Pins::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pins() -> Pins {
        Pins {
            executor_kind: "reference".into(),
            shard: String::new(),
            artifact_hashes: vec![
                ("train_step".into(), "aaa".into()),
                ("adamw_update".into(), "bbb".into()),
            ],
            model_config_hash: "cfg123".into(),
            tokenizer_checksum: "tok456".into(),
            param_count: 120064,
            accum: 2,
            batch: 8,
            layout: "single-host;dp=1;tp=1;pp=1".into(),
            reduction: "sum".into(),
            platform: "cpu".into(),
        }
    }

    #[test]
    fn identical_pins_verify_clean() {
        assert!(pins().verify(&pins()).is_empty());
        assert!(pins().ensure_match(&pins()).is_ok());
    }

    #[test]
    fn any_single_drift_fails_closed() {
        let base = pins();
        let mut variants = Vec::new();
        let mut p = pins();
        p.model_config_hash = "other".into();
        variants.push(p);
        // mixed-backend refusal: a PJRT capture against reference pins
        let mut p = pins();
        p.executor_kind = "pjrt".into();
        variants.push(p);
        let mut p = pins();
        p.reduction = "mean".into();
        variants.push(p);
        // fleet topology drift: a sharded capture against unsharded pins
        let mut p = pins();
        p.shard = "shard 3/16 salt 00000000000000ab".into();
        variants.push(p);
        let mut p = pins();
        p.accum = 4;
        variants.push(p);
        let mut p = pins();
        p.artifact_hashes[0].1 = "ddd".into();
        variants.push(p);
        let mut p = pins();
        p.artifact_hashes.pop();
        variants.push(p);
        for v in variants {
            assert!(base.ensure_match(&v).is_err());
        }
    }

    #[test]
    fn json_roundtrip() {
        let p = pins();
        let back = Pins::from_json(&p.to_json()).unwrap();
        // artifact ordering may differ; compare via verify
        assert!(p.verify(&back).is_empty());
    }

    #[test]
    fn save_load() {
        let dir = crate::util::tempdir("pins");
        let p = pins();
        let path = dir.join("pins.json");
        p.save(&path).unwrap();
        assert!(Pins::load(&path).unwrap().verify(&p).is_empty());
    }
}
