//! Run configuration and reproducibility pins (paper Table 2).
//!
//! [`Pins`] is the fail-closed contract: it captures every input that can
//! change numerics (artifact hashes, model-config hash, tokenizer
//! checksum, layout, loss reduction), is recorded at training time, and
//! replay **refuses to run** if any pin drifts ([`Pins::verify`] →
//! `PinDrift`).

pub mod pins;
pub mod run;

pub use pins::{PinDrift, Pins};
pub use run::RunConfig;
