//! Signed forget manifest (paper §4.3): append-only, hash-chained,
//! HMAC-signed record of every unlearning action and its artifacts.
//!
//! Each entry carries: the request, the forget-closure summary, the path
//! taken (adapter delete / dense revert / anti-update / replay), audit
//! outcomes, content-addressed artifact IDs, an idempotency key, the
//! previous entry's chain hash, and an HMAC-SHA256 signature over the
//! canonical encoding (the offline stand-in for asymmetric signing —
//! see DESIGN.md substitutions).  Tampering with any byte of any entry
//! breaks the chain verification.

use std::collections::HashSet;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::hashing::{hex, hmac_sha256, sha256_hex};
use crate::util::json::{parse, Json};

/// The action kinds of Alg. A.7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActionKind {
    AdapterDelete,
    RecentRevert,
    HotPathAntiUpdate,
    ExactReplay,
    /// Checkpoint laundering: the cumulative forgotten closure compacted
    /// into a rewritten (lineage-swapped) base checkpoint sequence.
    Launder,
    Refused,
}

impl ActionKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ActionKind::AdapterDelete => "adapter_delete",
            ActionKind::RecentRevert => "recent_revert",
            ActionKind::HotPathAntiUpdate => "hot_path_anti_update",
            ActionKind::ExactReplay => "exact_replay",
            ActionKind::Launder => "launder",
            ActionKind::Refused => "refused",
        }
    }
}

/// One manifest entry (pre-signing content).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Idempotency key (duplicate submissions are rejected).
    pub idempotency_key: String,
    /// Free-form request description (user id, sample ids, urgency).
    pub request: Json,
    /// Closure summary: size, expanded count.
    pub closure_summary: Json,
    pub action: ActionKind,
    /// Action details (steps replayed, deltas reverted, adapter ids...).
    pub details: Json,
    /// Audit report JSON (None when no audits ran, e.g. refusals).
    pub audits: Option<Json>,
    /// Content-addressed artifact ids (path -> sha256).
    pub artifacts: Json,
}

/// Append-only signed manifest file (JSON lines).
pub struct ForgetManifest {
    path: PathBuf,
    key: Vec<u8>,
    seq: u64,
    prev_hash: String,
    seen_keys: HashSet<String>,
}

impl ForgetManifest {
    /// Open (or create) the manifest at `path`, replaying the chain to
    /// restore state and verify integrity.
    pub fn open(path: &Path, key: &[u8]) -> anyhow::Result<ForgetManifest> {
        let mut m = ForgetManifest {
            path: path.to_path_buf(),
            key: key.to_vec(),
            seq: 0,
            prev_hash: "genesis".to_string(),
            seen_keys: HashSet::new(),
        };
        if path.exists() {
            for (entry, _) in m.verify_chain()? {
                m.seq = entry.get("seq").and_then(|v| v.as_u64()).unwrap_or(0) + 1;
                if let Some(k) =
                    entry.get("idempotency_key").and_then(|v| v.as_str())
                {
                    m.seen_keys.insert(k.to_string());
                }
                m.prev_hash = entry
                    .get("entry_hash")
                    .and_then(|v| v.as_str())
                    .unwrap_or("genesis")
                    .to_string();
            }
        }
        Ok(m)
    }

    /// Append an entry.  Returns the entry hash, or `Ok(None)` if the
    /// idempotency key was already executed (duplicate suppression,
    /// Alg. A.7 "idempotency keys prevent duplicate execution").
    pub fn append(
        &mut self,
        entry: &ManifestEntry,
    ) -> anyhow::Result<Option<String>> {
        if self.seen_keys.contains(&entry.idempotency_key) {
            return Ok(None);
        }
        let mut j = Json::obj();
        j.set("seq", self.seq)
            .set("idempotency_key", entry.idempotency_key.as_str())
            .set("request", entry.request.clone())
            .set("closure_summary", entry.closure_summary.clone())
            .set("action", entry.action.as_str())
            .set("details", entry.details.clone())
            .set(
                "audits",
                entry.audits.clone().unwrap_or(Json::Null),
            )
            .set("artifacts", entry.artifacts.clone())
            .set("prev_hash", self.prev_hash.as_str());
        // chain hash over the canonical (sorted-key, compact) encoding
        let body = j.encode();
        let entry_hash = sha256_hex(body.as_bytes());
        let sig = hex(&hmac_sha256(&self.key, body.as_bytes()));
        j.set("entry_hash", entry_hash.as_str())
            .set("hmac", sig.as_str());
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", j.encode())?;
        f.sync_all()?;
        self.seq += 1;
        self.prev_hash = entry_hash.clone();
        self.seen_keys.insert(entry.idempotency_key.clone());
        Ok(Some(entry_hash))
    }

    /// Manifest file location (read-side verification without holding
    /// the controller lock — the admin server's `manifest` op).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Signing/verification key bytes (same-process read-side use).
    pub fn key(&self) -> &[u8] {
        &self.key
    }

    pub fn was_executed(&self, idempotency_key: &str) -> bool {
        self.seen_keys.contains(idempotency_key)
    }

    pub fn len(&self) -> u64 {
        self.seq
    }

    pub fn is_empty(&self) -> bool {
        self.seq == 0
    }

    /// Verify the whole chain; returns (entry, valid_signature) pairs.
    /// Errors on any chain-hash break (tamper evidence).
    pub fn verify_chain(&self) -> anyhow::Result<Vec<(Json, bool)>> {
        Self::verify_chain_at(&self.path, &self.key)
    }

    /// [`ForgetManifest::verify_chain`] without an open manifest — the
    /// read-side verification path (e.g. the admin server's `manifest`
    /// op), which must not pay `open`'s state-restoring second pass.
    pub fn verify_chain_at(
        path: &Path,
        key: &[u8],
    ) -> anyhow::Result<Vec<(Json, bool)>> {
        let mut out = Vec::new();
        if !path.exists() {
            return Ok(out);
        }
        let text = std::fs::read_to_string(path)?;
        let mut prev = "genesis".to_string();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = parse(line)
                .map_err(|e| anyhow::anyhow!("manifest line {lineno}: {e}"))?;
            // recompute the chain hash over the body (entry minus
            // entry_hash and hmac fields)
            let mut body = j.clone();
            if let Json::Obj(map) = &mut body {
                map.remove("entry_hash");
                map.remove("hmac");
            }
            let expect_hash = sha256_hex(body.encode().as_bytes());
            let stored_hash = j
                .get("entry_hash")
                .and_then(|v| v.as_str())
                .unwrap_or_default();
            anyhow::ensure!(
                expect_hash == stored_hash,
                "manifest entry {lineno}: chain hash mismatch (tampered)"
            );
            let stored_prev = j
                .get("prev_hash")
                .and_then(|v| v.as_str())
                .unwrap_or_default();
            anyhow::ensure!(
                stored_prev == prev,
                "manifest entry {lineno}: chain broken (prev_hash)"
            );
            let sig_ok = j
                .get("hmac")
                .and_then(|v| v.as_str())
                .map(|s| {
                    s == hex(&hmac_sha256(key, body.encode().as_bytes()))
                })
                .unwrap_or(false);
            prev = stored_hash.to_string();
            out.push((j, sig_ok));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(key: &str) -> ManifestEntry {
        let mut req = Json::obj();
        req.set("user", 3u64).set("urgency", "normal");
        let mut cl = Json::obj();
        cl.set("requested", 9u64).set("expanded", 2u64);
        ManifestEntry {
            idempotency_key: key.to_string(),
            request: req,
            closure_summary: cl,
            action: ActionKind::ExactReplay,
            details: Json::obj(),
            audits: None,
            artifacts: Json::obj(),
        }
    }

    #[test]
    fn append_and_verify_chain() {
        let dir = crate::util::tempdir("manifest");
        let path = dir.join("forget.manifest");
        let mut m = ForgetManifest::open(&path, b"signing-key").unwrap();
        assert!(m.append(&entry("req-1")).unwrap().is_some());
        assert!(m.append(&entry("req-2")).unwrap().is_some());
        let chain = m.verify_chain().unwrap();
        assert_eq!(chain.len(), 2);
        assert!(chain.iter().all(|(_, sig)| *sig));
    }

    #[test]
    fn idempotency_suppresses_duplicates() {
        let dir = crate::util::tempdir("manifest-idem");
        let path = dir.join("forget.manifest");
        let mut m = ForgetManifest::open(&path, b"k").unwrap();
        assert!(m.append(&entry("dup")).unwrap().is_some());
        assert!(m.append(&entry("dup")).unwrap().is_none());
        assert_eq!(m.len(), 1);
        assert!(m.was_executed("dup"));
    }

    #[test]
    fn reopen_restores_state() {
        let dir = crate::util::tempdir("manifest-reopen");
        let path = dir.join("forget.manifest");
        {
            let mut m = ForgetManifest::open(&path, b"k").unwrap();
            m.append(&entry("a")).unwrap();
            m.append(&entry("b")).unwrap();
        }
        let mut m = ForgetManifest::open(&path, b"k").unwrap();
        assert_eq!(m.len(), 2);
        assert!(m.was_executed("a"));
        assert!(m.append(&entry("a")).unwrap().is_none());
        assert!(m.append(&entry("c")).unwrap().is_some());
        assert!(m.verify_chain().unwrap().iter().all(|(_, s)| *s));
    }

    #[test]
    fn tamper_detected() {
        let dir = crate::util::tempdir("manifest-tamper");
        let path = dir.join("forget.manifest");
        let mut m = ForgetManifest::open(&path, b"k").unwrap();
        m.append(&entry("x")).unwrap();
        let text = std::fs::read_to_string(&path)
            .unwrap()
            .replace("\"user\":3", "\"user\":4");
        std::fs::write(&path, text).unwrap();
        assert!(m.verify_chain().is_err());
    }

    #[test]
    fn wrong_key_fails_signature_but_not_chain() {
        let dir = crate::util::tempdir("manifest-key");
        let path = dir.join("forget.manifest");
        let mut m = ForgetManifest::open(&path, b"right").unwrap();
        m.append(&entry("x")).unwrap();
        let m2 = ForgetManifest::open(&path, b"wrong").unwrap();
        let chain = m2.verify_chain().unwrap();
        assert!(chain.iter().all(|(_, sig)| !*sig));
    }
}
