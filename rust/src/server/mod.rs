//! Admin server: forget requests over TCP, line-delimited JSON.
//!
//! (tokio is not in the offline vendor set — std::net + scoped threads
//! are fully adequate for an admin/control plane; the request path of
//! the *model* is not served here.  The threaded design leans on the
//! `Executor: Send + Sync` contract: the reference backend is lock-free
//! by construction, the pjrt backend serializes its non-thread-safe
//! client behind one mutex — see DESIGN.md "Execution backends".)
//!
//! ## Architecture
//!
//! - **Event-loop connection layer** ([`event_loop`]): one nonblocking
//!   poll loop owns every admin connection — N idle clients cost one
//!   thread, and a stalled client never blocks other admin traffic
//!   (per-connection buffers, bounded write stalls).  Controller
//!   actions stay serialized by the job queue, not by connection
//!   handling.
//! - **Zero-alloc hot dispatch**: the hot ops (`submit`/`poll`/
//!   `status`/`jobs`/`launder`/`shutdown`) extract their fields with
//!   [`crate::util::json_scan`] lazy path scans over the raw line
//!   bytes — no JSON tree is built; cold ops (`plan`, `forget`) still
//!   tree-parse.  The scanner is property-tested byte-equivalent to
//!   the tree parser, so the wire contract is unchanged.
//! - **Async job queue**: `submit` enqueues and returns a job id
//!   immediately; a single worker thread drains the queue with a
//!   coalescing window and executes each drained batch through
//!   [`crate::controller::execute_batch`] — N queued replay-bound
//!   requests share **one** union-filtered tail replay.
//! - **Read ops off the write lock**: `status` reads a published
//!   snapshot, `audit` evaluates against a snapshotted parameter Arc,
//!   and `manifest` verifies the chain from disk — none of them queue
//!   behind a long replay holding the system lock.
//! - **Poison containment**: a panicked lock holder yields a typed
//!   `lock_poisoned` error response instead of bricking the admin
//!   plane.  (Job-table/snapshot locks guard plain data and recover
//!   via `into_inner`; the *system* lock fails closed — a half-mutated
//!   system must not keep executing forget actions.)
//!
//! ## Protocol (one JSON object per line)
//!
//!   {"op":"status"}                  → incl. CAS/lineage/GC stats
//!   {"op":"submit","id":"req-1","user":3,"urgency":"high"}   → job id
//!   {"op":"launder"}                 → job id (admin maintenance)
//!   {"op":"ingest","id":"d1","user":9,"texts":["…"],"train_steps":2}
//!                                    → job id (docs + tail advance)
//!   {"op":"poll","job":"job-1"}
//!   {"op":"jobs"}
//!   {"op":"plan","id":"req-2","sample_ids":[1,2,3]}          → dry-run
//!   {"op":"forget","id":"req-3","user":4}                    → sync
//!   {"op":"audit"}
//!   {"op":"manifest"}
//!   {"op":"shutdown"}
//!
//! Response: one JSON object per line: {"ok":true,...} /
//! {"ok":false,"error":...,"error_kind":...}
//!
//! ## Durability
//!
//! An acked `submit` is a promise.  With a jobs WAL configured
//! ([`ServerCtx::with_jobs_wal`]; `serve` puts it at
//! `<run_dir>/jobs.wal`), every accepted job is appended (fsynced)
//! before the ack and marked on completion; on startup the pending
//! suffix — submitted but never completed — is re-queued under its
//! original job ids, so a restart mid-burst no longer silently drops
//! accepted work.  Re-running a job that completed between its WAL
//! mark and the crash is harmless: idempotency keys suppress the
//! double execution.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use crate::audit::{run_audits, AuditThresholds, ModelView};
use crate::checkpoint::CasStats;
use crate::controller::{
    execute_batch, ControllerOutcome, ForgetRequest, LaunderPolicy,
    UnlearnError, UnlearnSystem, Urgency,
};
use crate::data::corpus::Corpus;
use crate::ingest::{self, IngestDoc};
use crate::manifest::ForgetManifest;
use crate::runtime::Runtime;
use crate::util::json::{parse, Json};
use crate::util::json_scan;

mod event_loop;
pub use event_loop::{serve_event_loop, serve_line_conn};

/// Job lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }
}

/// A job queue payload: what a queued job carries between `submit` and
/// the worker's drain.  The queue itself is payload-agnostic — the
/// single-system server queues [`JobRequest`]s, the fleet server queues
/// shard-addressable forget requests — so the durability machinery
/// (fsync-before-ack, torn-final-line tolerance, seq high-water
/// compaction) exists exactly once.
pub trait JobPayload: Clone + Send + 'static {
    /// The idempotency/request key shown in `jobs`/`poll`.
    fn request_id(&self) -> &str;
    /// Stable wire discriminator for `jobs`/`poll` rows.
    fn kind(&self) -> &'static str;
    /// Wire/WAL encoding (the `request` object of a WAL submit event).
    fn to_json(&self) -> Json;
    /// Decode a WAL submit event's `request` object.
    fn from_json(j: &Json) -> anyhow::Result<Self>;
    /// Decode a WAL submit event's `request` value from its raw bytes
    /// (the recovery replay hot path).  The default round-trips
    /// through the tree parser; payloads whose fields are flat
    /// override it with [`crate::util::json_scan`] lazy scans so
    /// replaying a large backlog never builds a tree per record.
    fn from_raw(raw: &[u8]) -> anyhow::Result<Self> {
        let s = std::str::from_utf8(raw).map_err(|e| {
            anyhow::anyhow!("invalid utf-8 in WAL payload: {e}")
        })?;
        let j = parse(s).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
        Self::from_json(&j)
    }
}

/// What a job executes when the worker drains it.
#[derive(Debug, Clone)]
pub enum JobRequest {
    /// A forget request (coalesced with its batch).
    Forget(ForgetRequest),
    /// A laundering pass; `id` is the manifest idempotency key (empty =
    /// derive from the job id at execution time).
    Launder { id: String },
    /// An online-ingest round: append `texts` as `user`'s documents and
    /// advance the trained tail by `train_steps` (see `ingest::`).  A
    /// barrier in the drain order: forget groups never coalesce across
    /// it, so the executed interleaving is exactly the submission order
    /// the interleave log records.
    Ingest {
        id: String,
        user: u32,
        texts: Vec<String>,
        train_steps: u32,
    },
}

impl JobPayload for JobRequest {
    fn request_id(&self) -> &str {
        match self {
            JobRequest::Forget(r) => &r.id,
            JobRequest::Launder { id } => id,
            JobRequest::Ingest { id, .. } => id,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            JobRequest::Forget(_) => "forget",
            JobRequest::Launder { .. } => "launder",
            JobRequest::Ingest { .. } => "ingest",
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            JobRequest::Forget(r) => {
                j.set("kind", "forget")
                    .set("id", r.id.as_str())
                    .set(
                        "user",
                        r.user.map(Json::from).unwrap_or(Json::Null),
                    )
                    .set(
                        "sample_ids",
                        Json::Arr(
                            r.sample_ids.iter().map(|&s| s.into()).collect(),
                        ),
                    )
                    .set(
                        "urgency",
                        match r.urgency {
                            Urgency::High => "high",
                            Urgency::Normal => "normal",
                        },
                    );
            }
            JobRequest::Launder { id } => {
                j.set("kind", "launder").set("id", id.as_str());
            }
            JobRequest::Ingest {
                id,
                user,
                texts,
                train_steps,
            } => {
                j.set("kind", "ingest")
                    .set("id", id.as_str())
                    .set("user", *user)
                    .set(
                        "texts",
                        Json::Arr(
                            texts
                                .iter()
                                .map(|t| Json::from(t.as_str()))
                                .collect(),
                        ),
                    )
                    .set("train_steps", *train_steps as u64);
            }
        }
        j
    }

    fn from_json(j: &Json) -> anyhow::Result<JobRequest> {
        match j.get("kind").and_then(|v| v.as_str()) {
            Some("launder") => Ok(JobRequest::Launder {
                id: j
                    .get("id")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            }),
            Some("ingest") => parse_ingest(j),
            Some("forget") | None => Ok(JobRequest::Forget(parse_request(j)?)),
            Some(other) => anyhow::bail!("unknown job kind {other:?}"),
        }
    }

    /// Lazy-scan mirror of [`JobPayload::from_json`] — same field
    /// semantics (property-tested in `util::json_scan`), no tree.
    fn from_raw(raw: &[u8]) -> anyhow::Result<JobRequest> {
        match json_scan::scan_str(raw, "kind")
            .map_err(scan_err)?
            .as_deref()
        {
            Some("launder") => Ok(JobRequest::Launder {
                id: json_scan::scan_str(raw, "id")
                    .map_err(scan_err)?
                    .map(|s| s.into_owned())
                    .unwrap_or_default(),
            }),
            Some("ingest") => {
                // string arrays have no lazy scan; ingest is a cold,
                // low-rate op so the tree parse is acceptable here
                let s = std::str::from_utf8(raw).map_err(|e| {
                    anyhow::anyhow!("invalid utf-8 in WAL payload: {e}")
                })?;
                let j =
                    parse(s).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
                parse_ingest(&j)
            }
            Some("forget") | None => {
                Ok(JobRequest::Forget(parse_request_scan(raw)?))
            }
            Some(other) => anyhow::bail!("unknown job kind {other:?}"),
        }
    }
}

/// Parse the `ingest` job shape (shared by the tree and raw paths).
fn parse_ingest(j: &Json) -> anyhow::Result<JobRequest> {
    let texts = j
        .get("texts")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("ingest job missing texts[]"))?
        .iter()
        .map(|t| {
            t.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("ingest texts[] non-string"))
        })
        .collect::<anyhow::Result<Vec<String>>>()?;
    Ok(JobRequest::Ingest {
        id: j
            .get("id")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string(),
        user: j
            .get("user")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow::anyhow!("ingest job missing user"))?
            as u32,
        texts,
        // missing → 1 (ingest normally advances the tail); an EXPLICIT
        // 0 passes through as a docs-only round, which `run_round`
        // supports
        train_steps: j
            .get("train_steps")
            .and_then(|v| v.as_u64())
            .unwrap_or(1) as u32,
    })
}

/// Scanner refusals surface exactly like tree-parser refusals.
pub(crate) fn scan_err(e: json_scan::ScanError) -> anyhow::Error {
    anyhow::anyhow!("bad json: {e}")
}

/// One submitted job.
struct Job<P> {
    job_id: String,
    request: P,
    status: JobStatus,
    result: Option<Json>,
}

/// Completed (done/failed) jobs retained for `poll` after execution.
/// Oldest completed entries beyond this are pruned so a long-running
/// admin server's job table — and the `jobs` dump — stay bounded;
/// pruned job ids poll as unknown.  Queued/running jobs are never
/// pruned.
const COMPLETED_RETENTION: usize = 1024;

/// Job table behind the queue mutex.  `closed` lives under the same
/// lock as the jobs so refusal-after-close is race-free: a submission
/// either lands before `close()` (the worker's final drain sees it) or
/// observes `closed` and is refused — an acked job can never slip in
/// after the worker's last look.
struct JobTable<P> {
    jobs: Vec<Job<P>>,
    closed: bool,
}

/// FIFO job table + worker wakeup, generic over its payload (the
/// single-system server uses the [`JobRequest`] default; the fleet
/// server its shard-addressable payload).  Guards plain data only, so
/// poisoned guards are safely recovered via `into_inner`.  With a WAL
/// path set, accepted jobs are persisted before they are acked and
/// marked on completion, so a restart can re-queue the pending suffix.
pub struct JobQueue<P: JobPayload = JobRequest> {
    table: Mutex<JobTable<P>>,
    cv: Condvar,
    seq: AtomicU64,
    /// Append-only jobs WAL (one JSON event per line).  Written under
    /// the table lock so event order matches queue order.
    wal_path: Option<PathBuf>,
}

fn recover<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|p| p.into_inner())
}

impl<P: JobPayload> JobQueue<P> {
    pub(crate) fn new() -> JobQueue<P> {
        JobQueue {
            table: Mutex::new(JobTable {
                jobs: Vec::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            seq: AtomicU64::new(1),
            wal_path: None,
        }
    }

    /// Open a WAL-backed queue, re-queueing every job the WAL records
    /// as submitted but not completed (original job ids preserved; the
    /// sequence counter resumes past the highest recorded id).
    pub fn with_wal(path: &Path) -> anyhow::Result<JobQueue<P>> {
        let mut jobs: Vec<Job<P>> = Vec::new();
        let mut max_id = 0u64;
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let lines: Vec<&str> = text.lines().collect();
            for (lineno, line) in lines.iter().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                // Lazy scans instead of a tree per record: recovery
                // needs only event/next/job plus the raw payload span.
                // Every scan validates the whole line, so the torn-line
                // policy is unchanged — a torn FINAL line is the
                // expected crash artifact of an interrupted append
                // (completion marks are not fsynced; a torn submit was
                // never acked) and is dropped (compaction below
                // rewrites a clean file); corruption anywhere else
                // fails closed.  Only the first scan can hit a refusal:
                // once it validates, the rest cannot fail.
                let b = line.as_bytes();
                let event = match json_scan::scan_str(b, "event") {
                    Ok(ev) => ev,
                    Err(_) if lineno + 1 == lines.len() => break,
                    Err(e) => {
                        anyhow::bail!("jobs WAL line {lineno}: {e}")
                    }
                };
                // the id sequence's high-water mark, written at the head
                // of every compacted file: completed jobs vanish from
                // the suffix, but their ids must never be reused — a
                // client's stale handle (or a derived auto-launder
                // idempotency key) would silently alias a new job
                if event.as_deref() == Some("seq") {
                    if let Some(n) = json_scan::scan_u64(b, "next")
                        .map_err(scan_err)?
                    {
                        max_id = max_id.max(n.saturating_sub(1));
                    }
                    continue;
                }
                let job_id = json_scan::scan_str(b, "job")
                    .map_err(scan_err)?
                    .ok_or_else(|| {
                        anyhow::anyhow!("jobs WAL line {lineno}: missing job")
                    })?
                    .into_owned();
                if let Some(n) = job_id
                    .strip_prefix("job-")
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    max_id = max_id.max(n);
                }
                match event.as_deref() {
                    Some("submit") => {
                        let raw = json_scan::scan_raw(b, "request")
                            .map_err(scan_err)?
                            .ok_or_else(|| {
                                anyhow::anyhow!(
                                    "jobs WAL line {lineno}: missing request"
                                )
                            })?;
                        jobs.push(Job {
                            job_id,
                            request: P::from_raw(raw)?,
                            status: JobStatus::Queued,
                            result: None,
                        });
                    }
                    Some("done") => {
                        jobs.retain(|job| job.job_id != job_id);
                    }
                    other => anyhow::bail!(
                        "jobs WAL line {lineno}: unknown event {other:?}"
                    ),
                }
            }
        }
        // Compact: rewrite the WAL to a `seq` high-water-mark header
        // plus the recovered pending suffix (atomic tmp+rename) so the
        // file — and every future recovery — stays bounded by in-flight
        // work, not by service history, while ids keep advancing past
        // completed work across ANY number of restarts (without the
        // header, a later recovery of a fully drained file would reset
        // the counter and alias old job ids).
        if path.exists() {
            let mut text = String::new();
            let mut seq = Json::obj();
            seq.set("event", "seq").set("next", max_id + 1);
            text.push_str(&seq.encode());
            text.push('\n');
            for job in &jobs {
                let mut ev = Json::obj();
                ev.set("event", "submit")
                    .set("job", job.job_id.as_str())
                    .set("request", job.request.to_json());
                text.push_str(&ev.encode());
                text.push('\n');
            }
            crate::checkpoint::write_atomic(path, &text)?;
        }
        let q = JobQueue {
            table: Mutex::new(JobTable {
                jobs,
                closed: false,
            }),
            cv: Condvar::new(),
            seq: AtomicU64::new(max_id + 1),
            wal_path: Some(path.to_path_buf()),
        };
        Ok(q)
    }

    fn wal_append(&self, event: &Json, sync: bool) -> anyhow::Result<()> {
        let Some(path) = &self.wal_path else {
            return Ok(());
        };
        // Two distinct fault points: the append and (for acked submits)
        // the fsync behind the durability promise.
        let mut line = event.encode();
        line.push('\n');
        crate::util::faultfs::append(path, line.as_bytes())?;
        if sync {
            crate::util::faultfs::fsync(path)?;
        }
        Ok(())
    }

    /// Enqueue a request; returns its job id immediately, `Ok(None)`
    /// when the queue has been closed for shutdown, and an error when
    /// the durability promise cannot be made (jobs-WAL write failed —
    /// the job is NOT queued).
    pub fn submit(&self, request: P) -> anyhow::Result<Option<String>> {
        let mut g = recover(self.table.lock());
        if g.closed {
            return Ok(None);
        }
        let job_id = format!("job-{}", self.seq.fetch_add(1, Ordering::SeqCst));
        let mut ev = Json::obj();
        ev.set("event", "submit")
            .set("job", job_id.as_str())
            .set("request", request.to_json());
        self.wal_append(&ev, true)?;
        g.jobs.push(Job {
            job_id: job_id.clone(),
            request,
            status: JobStatus::Queued,
            result: None,
        });
        drop(g);
        self.cv.notify_all();
        Ok(Some(job_id))
    }

    /// Refuse further submissions and wake the worker for its final
    /// drain.
    pub fn close(&self) {
        recover(self.table.lock()).closed = true;
        self.cv.notify_all();
    }

    pub fn queued_len(&self) -> usize {
        recover(self.table.lock())
            .jobs
            .iter()
            .filter(|j| j.status == JobStatus::Queued)
            .count()
    }

    /// Jobs not yet completed (queued + running) — the operator's
    /// queue-backlog number: work the server has promised but not yet
    /// finished.
    pub fn pending_len(&self) -> usize {
        recover(self.table.lock())
            .jobs
            .iter()
            .filter(|j| {
                matches!(j.status, JobStatus::Queued | JobStatus::Running)
            })
            .count()
    }

    /// On-disk size of the jobs WAL (None = no WAL configured).  Grows
    /// with in-flight work and un-compacted completion marks; recovery
    /// compacts it, so a steadily climbing number between restarts means
    /// backlog, not history.
    pub fn wal_bytes(&self) -> Option<u64> {
        self.wal_path
            .as_ref()
            .and_then(|p| std::fs::metadata(p).ok())
            .map(|m| m.len())
    }

    /// Job status/result as a wire object.
    pub fn poll(&self, job_id: &str) -> Option<Json> {
        let g = recover(self.table.lock());
        g.jobs.iter().find(|j| j.job_id == job_id).map(job_json)
    }

    /// All jobs, submission order.
    pub fn jobs_json(&self) -> Json {
        let g = recover(self.table.lock());
        Json::Arr(g.jobs.iter().map(job_json).collect())
    }

    /// Atomically claim every queued job (marks them Running).
    pub(crate) fn take_queued(&self) -> Vec<(String, P)> {
        let mut g = recover(self.table.lock());
        let mut out = Vec::new();
        for j in g.jobs.iter_mut() {
            if j.status == JobStatus::Queued {
                j.status = JobStatus::Running;
                out.push((j.job_id.clone(), j.request.clone()));
            }
        }
        out
    }

    pub(crate) fn publish(&self, job_id: &str, status: JobStatus, result: Json) {
        let mut g = recover(self.table.lock());
        if let Some(j) = g.jobs.iter_mut().find(|j| j.job_id == job_id) {
            j.status = status;
            j.result = Some(result);
        }
        if matches!(status, JobStatus::Done | JobStatus::Failed) {
            // completion mark: best-effort (a lost mark only means the
            // job re-runs on recovery, where its idempotency key
            // suppresses double execution)
            let mut ev = Json::obj();
            ev.set("event", "done")
                .set("job", job_id)
                .set("status", status.as_str());
            let _ = self.wal_append(&ev, false);
        }
        // bound the table: prune the oldest completed entries
        let completed = g
            .jobs
            .iter()
            .filter(|j| {
                matches!(j.status, JobStatus::Done | JobStatus::Failed)
            })
            .count();
        if completed > COMPLETED_RETENTION {
            let mut excess = completed - COMPLETED_RETENTION;
            g.jobs.retain(|j| {
                if excess > 0
                    && matches!(
                        j.status,
                        JobStatus::Done | JobStatus::Failed
                    )
                {
                    excess -= 1;
                    false
                } else {
                    true
                }
            });
        }
    }

    /// Fail every job stuck in Running (the worker died mid-drain).
    pub(crate) fn fail_running(&self, reason: &str) {
        let mut g = recover(self.table.lock());
        for j in g.jobs.iter_mut() {
            if j.status == JobStatus::Running {
                let mut r = Json::obj();
                r.set("ok", false).set("error", reason);
                j.status = JobStatus::Failed;
                j.result = Some(r);
                let mut ev = Json::obj();
                ev.set("event", "done")
                    .set("job", j.job_id.as_str())
                    .set("status", JobStatus::Failed.as_str());
                let _ = self.wal_append(&ev, false);
            }
        }
    }

    /// Block until a job is queued, then linger up to `window` so a
    /// burst coalesces into one drained batch.  Returns false once the
    /// queue is closed AND empty (everything acknowledged has been
    /// claimed).
    ///
    /// The idle phase is a plain condvar wait — an empty queue costs
    /// zero wakeups (the old worker polled every 50 ms and then slept
    /// a full coalescing window per drain, even when nothing else was
    /// coming).  The linger phase is deadline-based `wait_timeout`
    /// arithmetic and is cut short the moment `close()` flips, so
    /// shutdown is prompt instead of paying the window.
    pub(crate) fn wait_for_burst(&self, window: Duration) -> bool {
        let mut g = recover(self.table.lock());
        // idle: wait for work or close (both notify_all this condvar)
        loop {
            if g.jobs.iter().any(|j| j.status == JobStatus::Queued) {
                break;
            }
            if g.closed {
                return false;
            }
            g = recover(self.cv.wait(g));
        }
        if g.closed {
            // final drain — the burst is over by definition
            return true;
        }
        // coalescing linger: bounded by a monotonic deadline
        let start = crate::metrics::monotonic_now();
        loop {
            let elapsed = crate::metrics::monotonic_now()
                .saturating_duration_since(start);
            let Some(remaining) = window.checked_sub(elapsed) else {
                return true;
            };
            if remaining.is_zero() {
                return true;
            }
            let (g2, _) = recover(self.cv.wait_timeout(g, remaining));
            g = g2;
            if g.closed {
                return true; // shutdown: drain what we have, now
            }
        }
    }
}

fn job_json<P: JobPayload>(j: &Job<P>) -> Json {
    let mut o = Json::obj();
    o.set("job", j.job_id.as_str())
        .set("request_id", j.request.request_id())
        .set("kind", j.request.kind())
        .set("status", j.status.as_str())
        .set("result", j.result.clone().unwrap_or(Json::Null));
    o
}

/// Published system snapshot: everything `status` reports plus the
/// parameter vector `audit` evaluates — refreshed by the worker after
/// every state change, read without touching the system lock.
#[derive(Clone)]
pub struct StatusSnapshot {
    pub model_hash: String,
    pub optimizer_hash: String,
    pub logical_step: u32,
    pub applied_updates: u32,
    pub ring_available: usize,
    pub adapters: usize,
    pub manifest_entries: u64,
    /// Closure entries accumulated since the last laundering pass.
    pub forgotten_pending: usize,
    /// IDs laundered into the active checkpoint lineage.
    pub laundered_ids: usize,
    /// CAS/lineage accounting (None when the store is unreadable).
    pub cas: Option<CasStats>,
    /// True when the launder policy says the forgotten set has inflated
    /// rebuild cost past the budget — the operator (or a cron) should
    /// submit {"op":"launder"}.
    pub launder_recommended: bool,
    /// Online-ingest watermarks: the step the serving state has trained
    /// through, how many docs arrived via the ingest log, and how many
    /// optimizer steps of uncovered tail are waiting for the next
    /// train-increment (0 ⇒ the serving state covers the full corpus).
    pub trained_step: u32,
    pub ingested_docs: u64,
    pub tail_lag_steps: u64,
    pub params: Arc<Vec<f32>>,
}

fn snapshot_of(
    sys: &UnlearnSystem<'_>,
    policy: &LaunderPolicy,
) -> StatusSnapshot {
    StatusSnapshot {
        model_hash: sys.state.model_hash(),
        optimizer_hash: sys.state.optimizer_hash(),
        logical_step: sys.state.logical_step,
        applied_updates: sys.state.applied_updates,
        ring_available: sys.ring.available(),
        adapters: sys.adapters.len(),
        manifest_entries: sys.manifest.len(),
        forgotten_pending: sys.forgotten.len(),
        laundered_ids: sys.laundered_total(),
        cas: sys.cas_stats().ok(),
        launder_recommended: matches!(sys.plan_launder(policy), Ok(Some(_))),
        trained_step: sys.state.logical_step,
        ingested_docs: sys.ingest.ingested_docs,
        tail_lag_steps: sys.tail_lag_steps(),
        params: Arc::new(sys.state.params.clone()),
    }
}

/// Owned copies of the audit fixtures, captured once at server start so
/// the `audit`/`manifest` ops never need the system lock.
struct AuditView {
    corpus: Corpus,
    retain_ids: Vec<u64>,
    eval_ids: Vec<u64>,
    thresholds: AuditThresholds,
    baseline_ppl: Option<f64>,
    seed: u64,
    manifest_path: std::path::PathBuf,
    manifest_key: Vec<u8>,
}

/// Shared server state: the protocol core (`dispatch`) and the worker
/// both run against this.  Constructed once per `serve` (or per test).
pub struct ServerCtx<'a, 'rt> {
    pub system: &'a Mutex<UnlearnSystem<'rt>>,
    rt: &'rt Runtime,
    pub jobs: JobQueue,
    snapshot: RwLock<StatusSnapshot>,
    audit_view: AuditView,
    pub shutdown: AtomicBool,
    /// How long the worker lingers after the first queued job before
    /// draining, letting a burst coalesce into one batch.
    pub coalesce_window: Duration,
    /// Threshold for the `launder_recommended` status bit and for
    /// worker-executed launder jobs.
    pub launder_policy: LaunderPolicy,
    /// Run a laundering pass from the worker when `launder_recommended`
    /// flips after a drained forget burst (mirrors
    /// `RunConfig::auto_launder`, captured at server start).
    pub auto_launder: bool,
}

impl<'a, 'rt> ServerCtx<'a, 'rt> {
    pub fn new(
        system: &'a Mutex<UnlearnSystem<'rt>>,
    ) -> anyhow::Result<ServerCtx<'a, 'rt>> {
        Self::build(system, JobQueue::new())
    }

    /// [`ServerCtx::new`] with a persistent jobs WAL at `wal_path`:
    /// accepted-but-incomplete jobs from a previous process are
    /// re-queued (the worker drains them on start).
    pub fn with_jobs_wal(
        system: &'a Mutex<UnlearnSystem<'rt>>,
        wal_path: &Path,
    ) -> anyhow::Result<ServerCtx<'a, 'rt>> {
        Self::build(system, JobQueue::with_wal(wal_path)?)
    }

    fn build(
        system: &'a Mutex<UnlearnSystem<'rt>>,
        jobs: JobQueue,
    ) -> anyhow::Result<ServerCtx<'a, 'rt>> {
        let launder_policy = LaunderPolicy::default();
        let sys = system
            .lock()
            .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
        let snapshot = RwLock::new(snapshot_of(&sys, &launder_policy));
        let audit_view = AuditView {
            corpus: sys.corpus.clone(),
            retain_ids: sys.retain_ids.clone(),
            eval_ids: sys.eval_ids.clone(),
            thresholds: sys.thresholds.clone(),
            baseline_ppl: sys.baseline_ppl,
            seed: sys.audit_seed,
            manifest_path: sys.manifest.path().to_path_buf(),
            manifest_key: sys.manifest.key().to_vec(),
        };
        let rt = sys.rt;
        let auto_launder = sys.cfg.auto_launder;
        drop(sys);
        Ok(ServerCtx {
            system,
            rt,
            jobs,
            snapshot,
            audit_view,
            shutdown: AtomicBool::new(false),
            coalesce_window: Duration::from_millis(15),
            launder_policy,
            auto_launder,
        })
    }

    fn refresh_snapshot(&self, sys: &UnlearnSystem<'_>) {
        *recover(self.snapshot.write()) =
            snapshot_of(sys, &self.launder_policy);
    }
}

/// Drain every currently queued job in SUBMISSION ORDER.  Consecutive
/// forget jobs coalesce into one `execute_batch` group (N queued
/// replay-bound requests share one union-filtered tail replay); ingest
/// and launder jobs are ordering BARRIERS that flush the pending
/// forget group first, so the run's interleave log — when online
/// ingest has attached one — records exactly the order the server
/// executed and an oracle rebuild can reproduce it.  After the drain,
/// when `ServerCtx::auto_launder` is set and the drained forgets
/// flipped `launder_recommended`, an automatic laundering pass runs
/// under the same lock.  Returns the number of jobs processed.
/// Exposed so tests (and the worker) share the exact same drain path.
pub fn drain_queue_once(ctx: &ServerCtx<'_, '_>) -> usize {
    let batch = ctx.jobs.take_queued();
    if batch.is_empty() {
        return 0;
    }
    match ctx.system.lock() {
        Err(_) => {
            let err = UnlearnError::LockPoisoned;
            for (job_id, _) in &batch {
                let mut r = Json::obj();
                r.set("ok", false)
                    .set("error", err.to_string())
                    .set("error_kind", err.kind());
                ctx.jobs.publish(job_id, JobStatus::Failed, r);
            }
        }
        Ok(mut sys) => {
            // The run's interleave log, when online ingest attached
            // one: forget/launder barriers are recorded into it so an
            // oracle rebuild sees the same order the server executed.
            // `Ok(None)` means never attached — fine, nothing to
            // record into.  `Err` means an EXISTING log is unreadable:
            // executing mutations anyway would punch unlogged holes in
            // the total order the retain-only oracle replays, so the
            // batch fails loudly here instead of deferring discovery
            // to the next ingest job's attach.
            let mut ilog = match ingest::IngestLog::open(&sys.cfg.run_dir)
            {
                Ok(l) => l,
                Err(e) => {
                    eprintln!(
                        "[server] interleave log unreadable — failing \
                         the drained batch (fail-closed): {e:#}"
                    );
                    for (job_id, _) in &batch {
                        let mut r = Json::obj();
                        r.set("ok", false)
                            .set(
                                "error",
                                format!(
                                    "interleave log unreadable: {e:#}"
                                ),
                            )
                            .set("error_kind", "ingest_log_unreadable");
                        ctx.jobs.publish(job_id, JobStatus::Failed, r);
                    }
                    return batch.len();
                }
            };
            let mut pending: Vec<(String, ForgetRequest)> = Vec::new();
            let mut first_forget: Option<String> = None;
            for (job_id, req) in &batch {
                match req {
                    JobRequest::Forget(r) => {
                        if first_forget.is_none() {
                            first_forget = Some(job_id.clone());
                        }
                        pending.push((job_id.clone(), r.clone()));
                    }
                    JobRequest::Launder { id } => {
                        flush_forget_group(
                            ctx,
                            &mut sys,
                            &mut pending,
                            ilog.as_mut(),
                        );
                        // an empty key derives from the job id so
                        // auto-submitted launders stay idempotent per
                        // job
                        let key = if id.is_empty() {
                            format!("launder-{job_id}")
                        } else {
                            id.clone()
                        };
                        run_launder_job(
                            ctx,
                            &mut sys,
                            job_id,
                            &key,
                            ilog.as_mut(),
                        );
                    }
                    JobRequest::Ingest {
                        id,
                        user,
                        texts,
                        train_steps,
                    } => {
                        flush_forget_group(
                            ctx,
                            &mut sys,
                            &mut pending,
                            ilog.as_mut(),
                        );
                        let key = if id.is_empty() {
                            format!("ingest-{job_id}")
                        } else {
                            id.clone()
                        };
                        run_ingest_job(
                            ctx,
                            &mut sys,
                            &mut ilog,
                            job_id,
                            &key,
                            *user,
                            texts,
                            *train_steps,
                        );
                    }
                }
            }
            flush_forget_group(ctx, &mut sys, &mut pending, ilog.as_mut());
            // Auto-laundering (config-gated): a drained forget burst
            // can flip `launder_recommended` — instead of waiting for
            // the operator/cron to notice the status bit, compact the
            // freshly accrued forgotten set right here, under the same
            // lock as the batch (no forget can interleave between the
            // check and the pass).  Runs AFTER explicit launder jobs so
            // it never steals their work; the plan re-check keeps it a
            // no-op when one of them already compacted.  The threshold
            // is the same policy the status bit uses (`force` stays
            // false); the idempotency key derives from the burst's
            // first forget job id, so a crash-and-recover re-drain
            // cannot double-launder.  A failure only logs: the next
            // burst re-checks, and the serving state is unchanged
            // (laundering swaps atomically or not at all).
            if ctx.auto_launder {
                if let Some(first) = first_forget.as_deref() {
                    if let Ok(Some(_)) = sys.plan_launder(&ctx.launder_policy)
                    {
                        let key = format!("auto-launder-{first}");
                        match sys.launder(&key, &ctx.launder_policy, false) {
                            Ok(out) if out.executed => eprintln!(
                                "auto-launder after burst: generation {}, \
                                 {} id(s) compacted, {} checkpoint(s) \
                                 rewritten",
                                out.generation,
                                out.laundered_now,
                                out.checkpoints_written
                            ),
                            Ok(_) => {}
                            Err(e) => eprintln!(
                                "auto-launder failed (state unchanged; \
                                 will re-check after the next burst): {e:#}"
                            ),
                        }
                    }
                }
            }
            ctx.refresh_snapshot(&sys);
        }
    }
    batch.len()
}

/// Execute the pending consecutive-forget group as ONE coalesced
/// batch, publishing per-job results in submission order.  Executed
/// forgets are recorded into the interleave log when the run has one
/// (bookkeeping, not the action: a failed append must not fail a
/// forget that already committed to the signed manifest — it only
/// logs, and the manifest remains the authoritative record).
fn flush_forget_group(
    ctx: &ServerCtx<'_, '_>,
    sys: &mut UnlearnSystem<'_>,
    group: &mut Vec<(String, ForgetRequest)>,
    mut ilog: Option<&mut ingest::IngestLog>,
) {
    if group.is_empty() {
        return;
    }
    let reqs: Vec<ForgetRequest> =
        group.iter().map(|(_, r)| r.clone()).collect();
    match execute_batch(sys, &reqs) {
        Ok(out) => {
            for ((job_id, req), res) in
                group.iter().zip(out.outcomes.into_iter())
            {
                match res {
                    Ok(o) => {
                        if o.executed {
                            if let Some(log) = ilog.as_deref_mut() {
                                if let Err(e) = log
                                    .record_forget(&req.id, o.closure_size)
                                {
                                    eprintln!(
                                        "interleave log: forget record \
                                         failed: {e:#}"
                                    );
                                }
                            }
                        }
                        ctx.jobs.publish(
                            job_id,
                            JobStatus::Done,
                            outcome_json(&o),
                        );
                    }
                    Err(e) => {
                        let mut r = Json::obj();
                        r.set("ok", false).set("error", format!("{e:#}"));
                        ctx.jobs.publish(job_id, JobStatus::Failed, r);
                    }
                }
            }
        }
        Err(e) => {
            for (job_id, _) in group.iter() {
                let mut r = Json::obj();
                r.set("ok", false).set("error", format!("{e:#}"));
                ctx.jobs.publish(job_id, JobStatus::Failed, r);
            }
        }
    }
    group.clear();
}

/// Execute one launder job under the held system lock.  force=true by
/// design: an explicit operator submission overrides the
/// recommendation threshold (the policy gates only the automatic
/// post-drain pass).
fn run_launder_job(
    ctx: &ServerCtx<'_, '_>,
    sys: &mut UnlearnSystem<'_>,
    job_id: &str,
    key: &str,
    ilog: Option<&mut ingest::IngestLog>,
) {
    match sys.launder(key, &ctx.launder_policy, true) {
        Ok(out) => {
            if out.executed {
                if let Some(log) = ilog {
                    if let Err(e) = log.record_launder(key) {
                        eprintln!(
                            "interleave log: launder record failed: {e:#}"
                        );
                    }
                }
            }
            let mut r = out.to_json();
            r.set("ok", true);
            ctx.jobs.publish(job_id, JobStatus::Done, r);
        }
        Err(e)
            if matches!(
                e.downcast_ref::<UnlearnError>(),
                Some(UnlearnError::NothingToLaunder)
            ) =>
        {
            // a scheduled cron launder on a quiet system is a
            // successful no-op, not a failure
            let mut r = Json::obj();
            r.set("ok", true)
                .set("executed", false)
                .set("note", "nothing to launder");
            ctx.jobs.publish(job_id, JobStatus::Done, r);
        }
        Err(e) => {
            let mut r = Json::obj();
            r.set("ok", false).set("error", format!("{e:#}"));
            if let Some(ue) = e.downcast_ref::<UnlearnError>() {
                r.set("error_kind", ue.kind());
            }
            ctx.jobs.publish(job_id, JobStatus::Failed, r);
        }
    }
}

/// Execute one ingest job: attach (or reuse) the run's interleave log
/// and run a full scheduler round — durable doc append, then a bounded
/// train-increment over the grown corpus.  The round key derives from
/// the request id, so a crash-and-recover re-drain of the jobs WAL
/// skips the halves that already committed instead of double-training
/// (same idempotency posture as forget keys).
#[allow(clippy::too_many_arguments)]
fn run_ingest_job(
    ctx: &ServerCtx<'_, '_>,
    sys: &mut UnlearnSystem<'_>,
    ilog: &mut Option<ingest::IngestLog>,
    job_id: &str,
    req_id: &str,
    user: u32,
    texts: &[String],
    train_steps: u32,
) {
    let result = (|| -> anyhow::Result<ingest::IncrementOutcome> {
        if ilog.is_none() {
            *ilog = Some(ingest::IngestLog::attach(
                &sys.cfg.run_dir,
                sys.corpus.len(),
            )?);
        }
        let log = ilog.as_mut().expect("attached above");
        let docs: Vec<IngestDoc> = texts
            .iter()
            .map(|t| IngestDoc {
                user,
                text: t.clone(),
            })
            .collect();
        let sched = ingest::IngestScheduler::new(train_steps);
        sched.run_round(sys, log, ingest::round_of(req_id), &docs)
    })();
    match result {
        Ok(out) => {
            let mut r = Json::obj();
            r.set("ok", true)
                .set("executed", out.executed)
                .set("docs", texts.len() as u64)
                .set("from_step", out.step.from_step as u64)
                .set("n_steps", out.step.n_steps as u64)
                .set("updates_applied", out.updates_applied as u64)
                .set("trained_step", sys.state.logical_step as u64)
                .set("tail_lag_steps", sys.tail_lag_steps());
            ctx.jobs.publish(job_id, JobStatus::Done, r);
        }
        Err(e) => {
            let mut r = Json::obj();
            r.set("ok", false).set("error", format!("{e:#}"));
            if let Some(ue) = e.downcast_ref::<UnlearnError>() {
                r.set("error_kind", ue.kind());
            }
            ctx.jobs.publish(job_id, JobStatus::Failed, r);
        }
    }
}

/// The queue worker: waits for submissions, lingers one coalescing
/// window so bursts batch up, then drains.  A submission acknowledged
/// as "queued" is a promise: `wait_for_burst` only returns false once
/// the queue is closed AND empty (closing and enqueueing share one
/// lock, so nothing acked can slip past the final drain), and a panic
/// inside a drain fails the claimed jobs loudly instead of stranding
/// them as running-forever while the queue keeps acking.  The
/// coalescing linger lives inside `wait_for_burst` as condvar deadline
/// arithmetic — an empty queue idles with no periodic wakeups and no
/// fixed sleep per drain, and `close()` interrupts the linger so
/// shutdown is prompt.
pub fn run_worker(ctx: &ServerCtx<'_, '_>) {
    while ctx.jobs.wait_for_burst(ctx.coalesce_window) {
        let drained = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| drain_queue_once(ctx)),
        );
        if drained.is_err() {
            ctx.jobs
                .fail_running("worker panicked during drain (state lock \
                               poisoned — admin write plane fails closed)");
        }
    }
}

/// Serve `system` on `addr` until a shutdown op arrives.
pub fn serve(
    system: Arc<Mutex<UnlearnSystem<'_>>>,
    addr: &str,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("unlearn admin server listening on {local}");
    // durable job queue: accepted work survives a restart mid-burst
    let wal_path = {
        let sys = system
            .lock()
            .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
        sys.cfg.run_dir.join("jobs.wal")
    };
    let ctx = ServerCtx::with_jobs_wal(&system, &wal_path)?;
    let recovered = ctx.jobs.queued_len();
    if recovered > 0 {
        eprintln!("recovered {recovered} pending job(s) from {}",
                  wal_path.display());
    }
    let result = std::thread::scope(|s| {
        s.spawn(|| run_worker(&ctx));
        let r = serve_event_loop(listener, &ctx.shutdown, |line| {
            dispatch(line, &ctx)
        });
        // the loop only returns once shutdown flipped (or on a setup
        // error) — either way, release the worker for its final drain
        // so the scope join cannot hang
        ctx.jobs.close();
        ctx.shutdown.store(true, Ordering::SeqCst);
        r
    });
    result
}

/// Execute one op (exposed for unit tests without sockets).
pub fn dispatch(line: &str, ctx: &ServerCtx<'_, '_>) -> Json {
    match dispatch_inner(line, ctx) {
        Ok(j) => j,
        Err(e) => {
            let mut j = Json::obj();
            j.set("ok", false).set("error", format!("{e:#}"));
            if let Some(ue) = e.downcast_ref::<UnlearnError>() {
                j.set("error_kind", ue.kind());
            }
            j
        }
    }
}

/// [`parse_request`] over raw line bytes via the zero-alloc lazy
/// scanner — the hot `submit` path never builds a tree.  Field
/// semantics are byte-equivalent to the tree path (the equivalence is
/// property-tested in `util::json_scan`).
pub(crate) fn parse_request_scan(b: &[u8]) -> anyhow::Result<ForgetRequest> {
    let id = json_scan::scan_str(b, "id")
        .map_err(scan_err)?
        .ok_or_else(|| anyhow::anyhow!("request needs id"))?
        .into_owned();
    let user = json_scan::scan_u64(b, "user")
        .map_err(scan_err)?
        .map(|u| u as u32);
    let sample_ids = json_scan::scan_u64s(b, "sample_ids")
        .map_err(scan_err)?
        .unwrap_or_default();
    let urgency =
        match json_scan::scan_str(b, "urgency").map_err(scan_err)?.as_deref()
        {
            Some("high") => Urgency::High,
            _ => Urgency::Normal,
        };
    Ok(ForgetRequest {
        id,
        user,
        sample_ids,
        urgency,
    })
}

/// Parse the request fields shared by `submit`, `plan` and `forget`
/// (and, via the fleet payload, the fleet server's ops).
pub(crate) fn parse_request(req: &Json) -> anyhow::Result<ForgetRequest> {
    let id = req
        .get("id")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("request needs id"))?
        .to_string();
    let user = req.get("user").and_then(|v| v.as_u64()).map(|u| u as u32);
    let sample_ids: Vec<u64> = req
        .get("sample_ids")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
        .unwrap_or_default();
    let urgency = match req.get("urgency").and_then(|v| v.as_str()) {
        Some("high") => Urgency::High,
        _ => Urgency::Normal,
    };
    Ok(ForgetRequest {
        id,
        user,
        sample_ids,
        urgency,
    })
}

/// Wire encoding of a controller outcome (sync `forget` + job results).
fn outcome_json(outcome: &ControllerOutcome) -> Json {
    let mut out = Json::obj();
    out.set("ok", true)
        .set("action", outcome.action.as_str())
        .set("executed", outcome.executed)
        .set("closure_size", outcome.closure_size)
        .set("closure_expanded", outcome.closure_expanded)
        .set(
            "audit_pass",
            outcome
                .audit
                .as_ref()
                .map(|a| Json::Bool(a.pass()))
                .unwrap_or(Json::Null),
        )
        .set(
            "escalations",
            Json::Arr(
                outcome.escalations.iter().map(|e| e.to_json()).collect(),
            ),
        )
        .set("details", outcome.details.clone());
    out
}

fn dispatch_inner(
    line: &str,
    ctx: &ServerCtx<'_, '_>,
) -> anyhow::Result<Json> {
    // Hot path: one validating lazy scan pulls `op` straight from the
    // raw bytes — no tree is built for `status`/`submit`/`poll`/
    // `jobs`/`launder`/`shutdown`.  The scan validates the whole line,
    // so malformed requests get the same typed "bad json" refusal the
    // tree parser produced.  Cold ops (`plan`, `forget`) re-parse the
    // already-validated line into a tree below.
    let b = line.as_bytes();
    let op = json_scan::scan_str(b, "op")
        .map_err(scan_err)?
        .ok_or_else(|| anyhow::anyhow!("missing op"))?;
    let mut out = Json::obj();
    match op.as_ref() {
        // ---- read plane: never takes the system lock -----------------
        "status" => {
            let snap = recover(ctx.snapshot.read()).clone();
            out.set("ok", true)
                .set("model_hash", snap.model_hash.as_str())
                .set("optimizer_hash", snap.optimizer_hash.as_str())
                .set("logical_step", snap.logical_step)
                .set("applied_updates", snap.applied_updates)
                .set("ring_available", snap.ring_available)
                .set("adapters", snap.adapters)
                .set("manifest_entries", snap.manifest_entries)
                .set("forgotten_pending", snap.forgotten_pending)
                .set("laundered_ids", snap.laundered_ids)
                .set("launder_recommended", snap.launder_recommended)
                // online-ingest watermarks: trained_step is the step
                // the serving state covers; tail_lag_steps > 0 means
                // committed ingest docs are waiting for an increment
                .set("trained_step", snap.trained_step)
                .set("ingested_docs", snap.ingested_docs)
                .set("tail_lag_steps", snap.tail_lag_steps)
                .set("queued_jobs", ctx.jobs.queued_len())
                // queue backlog at a glance: promised-but-unfinished
                // jobs + the jobs-WAL footprint backing that promise
                .set("pending_jobs", ctx.jobs.pending_len())
                .set(
                    "jobs_wal_bytes",
                    ctx.jobs
                        .wal_bytes()
                        .map(Json::from)
                        .unwrap_or(Json::Null),
                );
            if let Some(cas) = &snap.cas {
                let mut c = Json::obj();
                c.set("objects", cas.objects)
                    .set("object_bytes", cas.object_bytes)
                    .set("manifests", cas.manifests)
                    .set("referenced_bytes", cas.referenced_bytes)
                    .set("dedup_ratio", cas.dedup_ratio)
                    .set("generation", cas.generation);
                out.set("cas", c);
            }
        }
        "audit" => {
            let snap = recover(ctx.snapshot.read()).clone();
            let av = &ctx.audit_view;
            let closure: Vec<u64> =
                av.retain_ids.iter().take(8).copied().collect();
            let actx = crate::audit::AuditContext {
                rt: ctx.rt,
                corpus: &av.corpus,
                forget_ids: &closure,
                retain_ids: &av.retain_ids,
                eval_ids: &av.eval_ids,
                baseline_ppl: av.baseline_ppl,
                thresholds: av.thresholds.clone(),
                seed: av.seed,
            };
            let report =
                run_audits(&actx, ModelView::Base(&snap.params))?;
            out.set("ok", true).set("report", report.to_json());
        }
        "manifest" => {
            // Lock-free chain verification from disk.  The worker may be
            // mid-append (one writeln + fsync under the system lock), so
            // a torn final line is possible — retry briefly before
            // reporting corruption.
            let mut attempt = 0;
            let chain = loop {
                let res = ForgetManifest::verify_chain_at(
                    &ctx.audit_view.manifest_path,
                    &ctx.audit_view.manifest_key,
                );
                match res {
                    Ok(chain) => break chain,
                    Err(_) if attempt < 3 => {
                        attempt += 1;
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    Err(e) => return Err(e),
                }
            };
            out.set("ok", true)
                .set("entries", chain.len())
                .set(
                    "signatures_valid",
                    chain.iter().all(|(_, s)| *s),
                );
        }

        // ---- job plane -----------------------------------------------
        "submit" => {
            let freq = parse_request_scan(b)?;
            // refused once the queue is closed for shutdown: an accepted
            // submission is a promise the departing worker could no
            // longer keep (the check shares the job-table lock with
            // close(), so acceptance vs. refusal is race-free)
            let job = ctx
                .jobs
                .submit(JobRequest::Forget(freq))?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "server is shutting down — submission refused"
                    )
                })?;
            out.set("ok", true)
                .set("job", job.as_str())
                .set("status", "queued");
        }
        "launder" => {
            // admin maintenance: compact the cumulative forgotten set
            // into a rewritten checkpoint lineage.  Queued like any
            // other job so it serializes with in-flight forget batches
            // (the worker drains the burst first, then launders).
            let id = json_scan::scan_str(b, "id")
                .map_err(scan_err)?
                .map(|s| s.into_owned())
                .unwrap_or_default();
            let job = ctx
                .jobs
                .submit(JobRequest::Launder { id })?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "server is shutting down — submission refused"
                    )
                })?;
            out.set("ok", true)
                .set("job", job.as_str())
                .set("status", "queued");
        }
        "ingest" => {
            // online ingest: durable doc append + bounded
            // train-increment, queued like forget/launder so it
            // serializes with them in exact submission order (the
            // drain loop treats it as an interleave barrier).  Cold
            // low-rate op: tree-parse the already-validated line.
            let req =
                parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
            let ireq = parse_ingest(&req)?;
            let job = ctx.jobs.submit(ireq)?.ok_or_else(|| {
                anyhow::anyhow!(
                    "server is shutting down — submission refused"
                )
            })?;
            out.set("ok", true)
                .set("job", job.as_str())
                .set("status", "queued");
        }
        "poll" => {
            let job = json_scan::scan_str(b, "job")
                .map_err(scan_err)?
                .ok_or_else(|| anyhow::anyhow!("poll needs job"))?;
            match ctx.jobs.poll(&job) {
                Some(j) => {
                    out.set("ok", true);
                    if let Json::Obj(m) = &j {
                        for (k, v) in m {
                            out.set(k, v.clone());
                        }
                    }
                }
                None => anyhow::bail!("unknown job {job:?}"),
            }
        }
        "jobs" => {
            out.set("ok", true).set("jobs", ctx.jobs.jobs_json());
        }

        // ---- write plane: typed poison containment -------------------
        // (cold ops: tree-parse the already-validated line — these take
        // the system lock and run replays, so a tree is noise here)
        "plan" => {
            let req =
                parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
            let freq = parse_request(&req)?;
            let sys = ctx
                .system
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            match sys.plan(&freq) {
                Ok(plan) => {
                    out.set("ok", true).set("plan", plan.to_json());
                }
                Err(e) => {
                    out.set("ok", false)
                        .set("error", e.to_string())
                        .set("error_kind", e.kind());
                }
            }
        }
        "forget" => {
            let req =
                parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
            let freq = parse_request(&req)?;
            let mut sys = ctx
                .system
                .lock()
                .map_err(|_| anyhow::Error::new(UnlearnError::LockPoisoned))?;
            let outcome = sys.handle(&freq);
            // republish even on failure: a failed chain may still have
            // mutated the serving state (e.g. a revert whose fallback
            // errored) and the read plane must not go stale
            ctx.refresh_snapshot(&sys);
            out = outcome_json(&outcome?);
        }
        "shutdown" => {
            ctx.jobs.close(); // refuse new submissions, wake the worker
            ctx.shutdown.store(true, Ordering::SeqCst);
            out.set("ok", true).set("shutting_down", true);
        }
        other => anyhow::bail!("unknown op {other:?}"),
    }
    Ok(out)
}
