//! Admin server: forget requests over TCP, line-delimited JSON.
//!
//! (tokio is not in the offline vendor set — std::net + a thread per
//! connection is fully adequate for an admin/control plane; the request
//! path of the *model* is not served here.)
//!
//! Protocol (one JSON object per line):
//!   {"op":"status"}
//!   {"op":"forget","id":"req-1","user":3,"urgency":"high"}
//!   {"op":"forget","id":"req-2","sample_ids":[1,2,3]}
//!   {"op":"audit"}
//!   {"op":"manifest"}
//!   {"op":"shutdown"}
//! Response: one JSON object per line: {"ok":true,...} / {"ok":false,"error":...}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::audit::{run_audits, ModelView};
use crate::controller::{ForgetRequest, UnlearnSystem, Urgency};
use crate::util::json::{parse, Json};

/// Serve `system` on `addr` until a shutdown op arrives.  Connections
/// are handled sequentially: the PJRT client is not `Sync` (Rc + raw
/// pointers inside the `xla` crate), and serializing controller actions
/// is semantically what we want anyway — unlearning actions must not
/// interleave (the Mutex would serialize them regardless).
pub fn serve(
    system: Arc<Mutex<UnlearnSystem<'_>>>,
    addr: &str,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("unlearn admin server listening on {local}");
    let shutdown = Arc::new(AtomicBool::new(false));
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        if let Err(e) =
            handle_conn(stream, Arc::clone(&system), Arc::clone(&shutdown))
        {
            eprintln!("connection error: {e:#}");
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    system: Arc<Mutex<UnlearnSystem<'_>>>,
    shutdown: Arc<AtomicBool>,
) -> anyhow::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // connection closed
        }
        let response = dispatch(line.trim(), &system, &shutdown);
        writeln!(stream, "{}", response.encode())?;
        if shutdown.load(Ordering::SeqCst) {
            let _ = peer; // connection ends; serve() observes the flag
            return Ok(());
        }
    }
}

/// Execute one op (exposed for unit tests without sockets).
pub fn dispatch(
    line: &str,
    system: &Mutex<UnlearnSystem<'_>>,
    shutdown: &AtomicBool,
) -> Json {
    match dispatch_inner(line, system, shutdown) {
        Ok(j) => j,
        Err(e) => {
            let mut j = Json::obj();
            j.set("ok", false).set("error", format!("{e:#}"));
            j
        }
    }
}

fn dispatch_inner(
    line: &str,
    system: &Mutex<UnlearnSystem<'_>>,
    shutdown: &AtomicBool,
) -> anyhow::Result<Json> {
    let req = parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let op = req
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing op"))?;
    let mut out = Json::obj();
    match op {
        "status" => {
            let sys = system.lock().unwrap();
            out.set("ok", true)
                .set("model_hash", sys.state.model_hash())
                .set("optimizer_hash", sys.state.optimizer_hash())
                .set("logical_step", sys.state.logical_step)
                .set("applied_updates", sys.state.applied_updates)
                .set("ring_available", sys.ring.available())
                .set("adapters", sys.adapters.len())
                .set("manifest_entries", sys.manifest.len());
        }
        "forget" => {
            let id = req
                .get("id")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("forget needs id"))?
                .to_string();
            let user = req.get("user").and_then(|v| v.as_u64()).map(|u| u as u32);
            let sample_ids: Vec<u64> = req
                .get("sample_ids")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
                .unwrap_or_default();
            let urgency = match req.get("urgency").and_then(|v| v.as_str()) {
                Some("high") => Urgency::High,
                _ => Urgency::Normal,
            };
            let freq = ForgetRequest {
                id,
                user,
                sample_ids,
                urgency,
            };
            let mut sys = system.lock().unwrap();
            let outcome = sys.handle(&freq)?;
            out.set("ok", true)
                .set("action", outcome.action.as_str())
                .set("executed", outcome.executed)
                .set("closure_size", outcome.closure_size)
                .set("closure_expanded", outcome.closure_expanded)
                .set(
                    "audit_pass",
                    outcome
                        .audit
                        .as_ref()
                        .map(|a| Json::Bool(a.pass()))
                        .unwrap_or(Json::Null),
                )
                .set(
                    "escalations",
                    Json::Arr(
                        outcome
                            .escalations
                            .iter()
                            .map(|s| Json::Str(s.clone()))
                            .collect(),
                    ),
                )
                .set("details", outcome.details);
        }
        "audit" => {
            let sys = system.lock().unwrap();
            let closure: Vec<u64> = sys.retain_ids.iter().take(8).copied().collect();
            let ctx = crate::audit::AuditContext {
                rt: sys.rt,
                corpus: &sys.corpus,
                forget_ids: &closure,
                retain_ids: &sys.retain_ids,
                eval_ids: &sys.eval_ids,
                baseline_ppl: sys.baseline_ppl,
                thresholds: sys.thresholds.clone(),
                seed: sys.audit_seed,
            };
            let report = run_audits(&ctx, ModelView::Base(&sys.state.params))?;
            out.set("ok", true).set("report", report.to_json());
        }
        "manifest" => {
            let sys = system.lock().unwrap();
            let chain = sys.manifest.verify_chain()?;
            out.set("ok", true)
                .set("entries", chain.len())
                .set(
                    "signatures_valid",
                    chain.iter().all(|(_, s)| *s),
                );
        }
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            out.set("ok", true).set("shutting_down", true);
        }
        other => anyhow::bail!("unknown op {other:?}"),
    }
    Ok(out)
}
