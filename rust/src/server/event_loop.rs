//! Nonblocking admin-plane connection layer (shared by the
//! single-system and fleet servers).
//!
//! The previous transport was thread-per-connection: N idle admin
//! clients cost N parked threads, each burning a 200 ms read-timeout
//! wakeup to observe shutdown.  This module replaces it with a single
//! poll loop over nonblocking `std::net` sockets (no new deps — mio and
//! tokio are not in the offline vendor set): one thread owns the
//! listener and every registered connection, sweeping them for
//! readiness with per-connection read/write buffers.
//!
//! ## Readiness loop
//!
//! `serve_event_loop` alternates two phases per sweep: drain the
//! nonblocking accept queue, then [`Conn::pump`] every connection.  A
//! pump flushes pending response bytes, reads one bounded chunk
//! (`READ_CHUNK`, so one fast writer cannot starve its neighbors),
//! dispatches every complete line, and reports whether it made
//! progress.  When a full sweep makes none, the loop sleeps one
//! `IDLE_TICK` — idle cost is one thread and one short timer for the
//! whole plane, not a timer per client.
//!
//! ## Buffer ownership & hardening (unchanged wire contract)
//!
//! Each `Conn` owns its buffers; nothing is shared across connections.
//! The hardening invariants of the old loop carry over verbatim and
//! are re-proven by `tests/server_transport.rs` against this loop:
//!
//! - **1 MiB line cap**: a client streaming bytes with no newline gets
//!   the same typed refusal, then the connection closes.
//! - **EOF with a partial line** still dispatches the fragment (the
//!   old `read_until` returned it at EOF), so a trailing unterminated
//!   request gets its refusal before the close.
//! - **Write stalls are bounded, progress is not**: responses flush in
//!   `WRITE_CHUNK`-bounded slices (one connection draining a multi-MiB
//!   `replica_status`/manifest response cannot monopolize a sweep) and
//!   the stall clock resets whenever bytes move, so a slow-but-draining
//!   reader receives the full payload no matter how long it takes; only
//!   a client making ZERO progress for `WRITE_STALL_LIMIT` is cut off
//!   (the old loop's 5 s write timeout, re-expressed for nonblocking
//!   sockets).
//! - **Shutdown**: the loop re-checks the flag every sweep — no
//!   self-connect poke needed — then grants a bounded grace period to
//!   flush already-queued responses (the shutdown ack itself).
//!
//! All deadline arithmetic reads the clock through
//! [`crate::metrics::monotonic_now`], the detlint-sanctioned monotonic
//! source; timeouts never reach serialized state.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Refuse request lines above this size (typed response, then close).
pub(crate) const MAX_LINE_BYTES: usize = 1 << 20;

/// Bytes read per pump: large enough for bulk transfers to move
/// quickly, small enough that one firehose client cannot monopolize a
/// sweep.
const READ_CHUNK: usize = 16 * 1024;

/// Bytes written per flush call: large enough that bulk responses
/// drain in a handful of sweeps, small enough that one connection
/// with a multi-MiB buffered response cannot monopolize the loop.
const WRITE_CHUNK: usize = 256 * 1024;

/// Sleep when a full sweep made no progress (the loop's only timer).
/// Also the idle tick of the single-connection wrapper
/// [`serve_line_conn`] — short enough that synchronous request/response
/// round-trips over it stay sub-millisecond-ish, long enough that an
/// idle plane is a timer, not a spin.
const IDLE_TICK: Duration = Duration::from_millis(1);

/// A connection whose writes make no progress for this long is closed
/// (successor of the old per-stream 5 s write timeout).
const WRITE_STALL_LIMIT: Duration = Duration::from_secs(5);

/// How long shutdown waits for queued response bytes (e.g. the
/// shutdown ack) to flush before the loop returns.
const SHUTDOWN_FLUSH_GRACE: Duration = Duration::from_millis(500);

/// Outcome of one [`Conn::pump`].
enum Pump {
    /// Bytes moved or lines dispatched this pump.
    Progress,
    /// Nothing to do; caller may sleep.
    Idle,
    /// Connection is finished (EOF / refusal / stall) and fully
    /// flushed — drop it.
    Close,
}

/// One registered connection: nonblocking stream + owned buffers.
struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet terminated by a newline.
    rbuf: Vec<u8>,
    /// Encoded responses not yet accepted by the kernel.
    wbuf: Vec<u8>,
    /// Prefix of `wbuf` already written.
    wpos: usize,
    /// No more reads; close once `wbuf` drains.
    closing: bool,
    /// Lines dispatched on this connection (drives the legacy shutdown
    /// poke in [`serve_line_conn`]).
    dispatched: u64,
    /// When the current write stall started.
    stalled_since: Option<Instant>,
}

impl Conn {
    fn new(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        Ok(Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            closing: false,
            dispatched: 0,
            stalled_since: None,
        })
    }

    fn queue_response(&mut self, resp: &Json) {
        self.wbuf.extend_from_slice(resp.encode().as_bytes());
        self.wbuf.push(b'\n');
    }

    fn pending_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Push buffered response bytes into the socket — at most
    /// [`WRITE_CHUNK`] per call; true if any moved.
    ///
    /// Write-stall accounting lives here so EVERY flush site feeds the
    /// clock: the timer starts only on a zero-progress attempt with
    /// bytes still pending and resets whenever bytes move, so a
    /// slow-but-draining reader is never evicted mid-payload — only a
    /// client making no progress at all for `WRITE_STALL_LIMIT` is.
    fn flush(&mut self) -> std::io::Result<bool> {
        let mut written = 0usize;
        while self.pending_write() && written < WRITE_CHUNK {
            let end =
                self.wbuf.len().min(self.wpos + (WRITE_CHUNK - written));
            match self.stream.write(&self.wbuf[self.wpos..end]) {
                Ok(0) => {
                    return Err(std::io::Error::from(
                        std::io::ErrorKind::WriteZero,
                    ))
                }
                Ok(n) => {
                    self.wpos += n;
                    written += n;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    continue
                }
                Err(e) => return Err(e),
            }
        }
        if !self.pending_write() && self.wpos > 0 {
            self.wbuf.clear();
            self.wpos = 0;
        }
        if written > 0 || !self.pending_write() {
            self.stalled_since = None;
        } else if self.stalled_since.is_none() {
            self.stalled_since = Some(crate::metrics::monotonic_now());
        }
        Ok(written > 0)
    }

    /// Dispatch every complete line in `rbuf`; stops early once the
    /// shutdown flag flips (one op past shutdown is never served —
    /// same contract as the old loop).
    fn dispatch_lines(
        &mut self,
        shutdown: &AtomicBool,
        dispatch_line: &impl Fn(&str) -> Json,
    ) {
        let mut start = 0;
        while let Some(nl) =
            self.rbuf[start..].iter().position(|&b| b == b'\n')
        {
            let end = start + nl;
            let line = String::from_utf8_lossy(&self.rbuf[start..end]);
            let resp = dispatch_line(line.trim());
            self.wbuf.extend_from_slice(resp.encode().as_bytes());
            self.wbuf.push(b'\n');
            self.dispatched += 1;
            start = end + 1;
            if shutdown.load(Ordering::SeqCst) {
                self.closing = true;
                break;
            }
        }
        if start > 0 {
            self.rbuf.drain(..start);
        }
    }

    /// One readiness step: flush, read a bounded chunk, dispatch.
    fn pump(
        &mut self,
        shutdown: &AtomicBool,
        dispatch_line: &impl Fn(&str) -> Json,
    ) -> std::io::Result<Pump> {
        let progressed = self.flush()?;
        // evict only on zero-progress sweeps: `flush` owns the stall
        // clock and resets it whenever bytes move, so a large response
        // draining slowly never hits this — a dead reader does
        if let Some(t0) = self.stalled_since {
            if crate::metrics::monotonic_now().saturating_duration_since(t0)
                > WRITE_STALL_LIMIT
            {
                return Ok(Pump::Close);
            }
        }
        if self.closing {
            if !self.pending_write() {
                return Ok(Pump::Close);
            }
            return Ok(if progressed { Pump::Progress } else { Pump::Idle });
        }

        let mut chunk = [0u8; READ_CHUNK];
        match self.stream.read(&mut chunk) {
            Ok(0) => {
                // Peer EOF.  The old `read_until` loop returned a
                // trailing unterminated fragment at EOF and dispatched
                // it — preserve that: the fragment gets its (typically
                // typed-refusal) response before the close.
                if !self.rbuf.is_empty() {
                    let line =
                        String::from_utf8_lossy(&self.rbuf).into_owned();
                    let resp = dispatch_line(line.trim());
                    self.queue_response(&resp);
                    self.dispatched += 1;
                    self.rbuf.clear();
                }
                self.closing = true;
                self.flush()?;
                Ok(if self.pending_write() {
                    Pump::Progress
                } else {
                    Pump::Close
                })
            }
            Ok(n) => {
                self.rbuf.extend_from_slice(&chunk[..n]);
                self.dispatch_lines(shutdown, dispatch_line);
                // cap AFTER extracting complete lines: only an
                // unterminated line can grow without bound
                if !self.closing && self.rbuf.len() > MAX_LINE_BYTES {
                    let mut j = Json::obj();
                    j.set("ok", false).set(
                        "error",
                        "request line exceeds 1 MiB — closing",
                    );
                    self.queue_response(&j);
                    self.rbuf.clear();
                    self.closing = true;
                }
                self.flush()?;
                Ok(Pump::Progress)
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                Ok(if progressed { Pump::Progress } else { Pump::Idle })
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                Ok(Pump::Progress)
            }
            Err(e) => Err(e),
        }
    }
}

/// Best-effort bounded flush of every connection's queued responses at
/// shutdown (so the shutdown ack reaches its client), then drop them.
fn drain_responses(conns: &mut Vec<Conn>) {
    let t0 = crate::metrics::monotonic_now();
    loop {
        conns.retain_mut(|c| match c.flush() {
            Ok(_) => c.pending_write(),
            Err(_) => false,
        });
        if conns.is_empty() {
            return;
        }
        if crate::metrics::monotonic_now().saturating_duration_since(t0)
            >= SHUTDOWN_FLUSH_GRACE
        {
            return;
        }
        std::thread::sleep(IDLE_TICK);
    }
}

/// Serve line-framed JSON on `listener` with a single poll-loop thread
/// until `shutdown` flips.  `dispatch_line` maps one request line to
/// one response object; it runs on the loop thread, so long-running
/// work must go through the job queue (which is exactly how both admin
/// planes are structured — `submit` acks immediately and the worker
/// thread executes).
pub fn serve_event_loop(
    listener: TcpListener,
    shutdown: &AtomicBool,
    dispatch_line: impl Fn(&str) -> Json,
) -> anyhow::Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        let mut progressed = false;
        // phase 1: drain the accept queue
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    progressed = true;
                    match Conn::new(stream) {
                        Ok(c) => conns.push(c),
                        Err(e) => {
                            eprintln!("connection setup error: {e:#}")
                        }
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    continue
                }
                Err(e) => {
                    eprintln!("accept error: {e:#}");
                    break;
                }
            }
        }
        // phase 2: pump every connection
        let mut i = 0;
        while i < conns.len() {
            match conns[i].pump(shutdown, &dispatch_line) {
                Ok(Pump::Progress) => {
                    progressed = true;
                    i += 1;
                }
                Ok(Pump::Idle) => i += 1,
                Ok(Pump::Close) => {
                    progressed = true;
                    conns.swap_remove(i);
                }
                Err(e) => {
                    eprintln!("connection error: {e:#}");
                    progressed = true;
                    conns.swap_remove(i);
                }
            }
        }
        if shutdown.load(Ordering::SeqCst) {
            drain_responses(&mut conns);
            return Ok(());
        }
        if !progressed {
            std::thread::sleep(IDLE_TICK);
        }
    }
}

/// The line-framed admin loop for ONE already-accepted connection —
/// the transport contract of the old thread-per-connection handler,
/// now expressed as a single-connection [`Conn::pump`] driver so the
/// hardening (line cap, EOF-fragment dispatch, bounded writes,
/// shutdown observation) exists exactly once.
///
/// - Bounded reads/writes and the 1 MiB cap: see [`Conn::pump`].
/// - Shutdown poke: after serving the op that flipped the flag, a
///   self-connect unblocks a legacy blocking acceptor even with no
///   further clients (the event loop does not need it, but external
///   thread-per-connection drivers like the transport tests still do).
///
/// `pub` so the adversarial transport suite can drive it over a real
/// socket pair without standing up a full system behind it.
pub fn serve_line_conn(
    stream: TcpStream,
    local: SocketAddr,
    shutdown: &AtomicBool,
    dispatch_line: impl Fn(&str) -> Json,
) -> anyhow::Result<()> {
    let mut conn = Conn::new(stream)?;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            // flag flipped elsewhere while this connection idled: flush
            // whatever is queued and leave quietly (no poke — same as
            // the old loop's top-of-iteration check)
            let mut only = vec![conn];
            drain_responses(&mut only);
            return Ok(());
        }
        match conn.pump(shutdown, &dispatch_line) {
            Ok(Pump::Close) => return Ok(()),
            Ok(Pump::Progress) => {
                if shutdown.load(Ordering::SeqCst) && conn.dispatched > 0 {
                    // this connection served the op that flipped the
                    // flag: flush the ack, then poke a legacy blocking
                    // acceptor awake
                    let mut only = vec![conn];
                    drain_responses(&mut only);
                    let _ = TcpStream::connect(local);
                    return Ok(());
                }
            }
            Ok(Pump::Idle) => std::thread::sleep(IDLE_TICK),
            Err(e) => return Err(e.into()),
        }
    }
}
