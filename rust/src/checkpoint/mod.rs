//! Content-addressed checkpoint store (CAS) with laundered lineages.
//!
//! Tensors are stored once per distinct bit pattern under
//! `objects/<sha256>`; checkpoints are lightweight JSON *manifests*
//! referencing those blobs by hash.  Identical tensors dedup across
//! checkpoints, micro-checkpoints and runs sharing a store root — and
//! checkpoint *laundering* (rewriting the lineage with the forgotten
//! closure filtered out) pays only for the tensors that actually
//! changed.
//!
//! On-disk layout under the store root:
//!
//! ```text
//! objects/<64-hex sha256>            raw LE f32 tensor image
//! lineages/gen-<g>/ckpt-<step>.json  full-checkpoint manifest
//! lineages/gen-<g>/micro-<step>.json weights-only manifest
//! lineages/gen-<g>/laundered.json    closure laundered out of gen g
//! LINEAGE.json                       {"active": g} (tmp+rename swap)
//! ```
//!
//! The **active lineage** is the one `list_full`/`load_full` serve.
//! Laundering stages a successor generation ([`LineageStage`]): clean
//! checkpoints are *adopted* (manifest copied — blobs shared, zero
//! tensor bytes written), contaminated ones are replaced by filtered
//! replay snapshots, then `commit` atomically swaps `LINEAGE.json`,
//! retires the old generation and garbage-collects.
//!
//! Garbage collection is refcount-by-scan: an object is live iff some
//! manifest in **any** lineage directory (active, staged, or not yet
//! retired) references its hash; everything else is removed.  The old
//! `keep`-based rolling prune survives as a manifest-level policy —
//! removing a manifest merely drops references, the blobs die in the
//! following sweep.
//!
//! Restoration stays exact by construction (assumption A4): `load_full`
//! re-hashes every tensor read and refuses a mismatch with a typed
//! [`StoreError`]; `open` refuses a store whose active manifests
//! reference missing blobs (dangling reference).

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::util::hashing::StreamingSha256;
use crate::util::json::{parse, Json};
use crate::util::simd;

/// Full training state at a logical step boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Flat parameter vector (training dtype f32).
    pub params: Vec<f32>,
    /// Adam first moment.
    pub m: Vec<f32>,
    /// Adam second moment.
    pub v: Vec<f32>,
    /// Applied-update counter (paper `opt_step`; bias-correction index).
    pub applied_updates: u32,
    /// Logical step the state corresponds to (next step to execute).
    pub logical_step: u32,
}

impl TrainState {
    pub fn zeros_like(params: Vec<f32>) -> TrainState {
        let n = params.len();
        TrainState {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            applied_updates: 0,
            logical_step: 0,
        }
    }

    /// Bit-identity of the full (θ, Ω) state — the G1 equality relation.
    pub fn bits_equal(&self, other: &TrainState) -> bool {
        use crate::util::bytes::bits_equal;
        bits_equal(&self.params, &other.params)
            && bits_equal(&self.m, &other.m)
            && bits_equal(&self.v, &other.v)
            && self.applied_updates == other.applied_updates
    }

    /// Content hashes in the Table 5 style (64-bit hex prefixes).
    pub fn model_hash(&self) -> String {
        crate::util::bytes::state_hash64(&self.params)
    }

    /// Hash over the full optimizer state (m ‖ v ‖ step counter) —
    /// streamed over the zero-copy views, no concatenated copy.
    pub fn optimizer_hash(&self) -> String {
        let mut h = StreamingSha256::new();
        h.update(simd::as_bytes(&self.m));
        h.update(simd::as_bytes(&self.v));
        h.update(&self.applied_updates.to_le_bytes());
        let hex = h.finalize_hex();
        hex[..16].to_string()
    }
}

/// Typed failure taxonomy of the store (fail-closed restore paths).
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    /// A blob's recomputed hash differs from the manifest reference —
    /// the object was truncated or corrupted (refuse inexact restore).
    HashMismatch {
        step: u32,
        tensor: &'static str,
        expect: String,
        got: String,
    },
    /// A manifest references an object that does not exist on disk.
    DanglingObject {
        step: u32,
        tensor: &'static str,
        hash: String,
    },
    /// No manifest for the requested step in the active lineage.
    MissingCheckpoint { step: u32 },
    /// A manifest file exists but cannot be parsed / lacks fields.
    CorruptManifest { path: String, detail: String },
}

impl StoreError {
    /// Stable machine-readable discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            StoreError::HashMismatch { .. } => "hash_mismatch",
            StoreError::DanglingObject { .. } => "dangling_object",
            StoreError::MissingCheckpoint { .. } => "missing_checkpoint",
            StoreError::CorruptManifest { .. } => "corrupt_manifest",
        }
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::HashMismatch { step, tensor, expect, got } => write!(
                f,
                "checkpoint {tensor} hash mismatch at step {step}: manifest \
                 {expect} vs stored {got} — refusing inexact restore (A4)"
            ),
            StoreError::DanglingObject { step, tensor, hash } => write!(
                f,
                "checkpoint {tensor} at step {step} references missing \
                 object {hash} (dangling manifest reference)"
            ),
            StoreError::MissingCheckpoint { step } => {
                write!(f, "no checkpoint manifest for step {step}")
            }
            StoreError::CorruptManifest { path, detail } => {
                write!(f, "corrupt checkpoint manifest {path}: {detail}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Store-wide accounting (the `status`/bench "CAS" row).
#[derive(Debug, Clone, PartialEq)]
pub struct CasStats {
    /// Distinct blobs on disk.
    pub objects: u64,
    /// Bytes actually stored (each distinct tensor once).
    pub object_bytes: u64,
    /// Checkpoint manifests across all lineage directories.
    pub manifests: u64,
    /// Bytes a naive one-file-per-tensor store would hold (every
    /// manifest reference priced at its object's size).
    pub referenced_bytes: u64,
    /// `object_bytes / referenced_bytes` — 1.0 means no sharing, lower
    /// is better (0.33 = every blob referenced three times on average).
    pub dedup_ratio: f64,
    /// Active lineage generation.
    pub generation: u64,
    /// IDs laundered out of the active lineage.
    pub laundered_ids: u64,
}

/// One GC sweep's outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct GcStats {
    pub removed_objects: u64,
    pub removed_bytes: u64,
    pub live_objects: u64,
}

/// Stream a tensor's bytes to `path` (tmp + rename so readers never see
/// a partial object).  Routed through [`crate::util::faultfs`] so the
/// crash matrix can kill or tear the blob write at any point.
fn write_object(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    crate::util::faultfs::write(&tmp, bytes)?;
    crate::util::faultfs::rename(&tmp, path)?;
    Ok(())
}

/// Atomic small-file write (manifests, LINEAGE.json; also shared by
/// the controller's durable-set files so tmp+rename semantics live in
/// exactly one place).  Both steps are fault-injection points: a crash
/// between them leaves only a `.tmp`, which every reader ignores.
/// `pub` so the crash-matrix suite can sweep the commit primitive
/// itself, not just its call sites.
pub fn write_atomic(path: &Path, text: &str) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    crate::util::faultfs::write(&tmp, text.as_bytes())?;
    crate::util::faultfs::rename(&tmp, path)?;
    Ok(())
}

/// The `{"ids":[...]}` id-set document (laundered.json /
/// forgotten.json) — one encode, one decode, shared by the store, the
/// controller and the harness.
pub(crate) fn ids_json(ids: &[u64]) -> Json {
    let mut j = Json::obj();
    j.set(
        "ids",
        Json::Arr(ids.iter().map(|&i| i.into()).collect()),
    );
    j
}

/// Read an id-set document; a missing file is the empty set.
pub(crate) fn read_ids_json(path: &Path) -> anyhow::Result<Vec<u64>> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let j = parse(&fs::read_to_string(path)?).map_err(|e| {
        anyhow::anyhow!("bad id-set file {}: {e}", path.display())
    })?;
    Ok(j.get("ids")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
        .unwrap_or_default())
}

/// Read an object file straight into an f32 buffer (single allocation),
/// returning (tensor, recomputed sha256-hex).
fn read_object_hashed(path: &Path) -> anyhow::Result<(Vec<f32>, String)> {
    let len = fs::metadata(path)?.len() as usize;
    anyhow::ensure!(
        len % 4 == 0,
        "object {} length {len} not 4-aligned — refusing inexact \
         restore (A4)",
        path.display()
    );
    let mut out = vec![0.0f32; len / 4];
    let mut f = fs::File::open(path)?;
    f.read_exact(simd::as_bytes_mut(&mut out))?;
    // no trailing bytes (metadata raced a writer?)
    let mut probe = [0u8; 1];
    anyhow::ensure!(
        f.read(&mut probe)? == 0,
        "object {} grew past its metadata length",
        path.display()
    );
    let mut h = StreamingSha256::new();
    h.update(simd::as_bytes(&out));
    Ok((out, h.finalize_hex()))
}

fn manifest_name(step: u32, micro: bool) -> String {
    let tag = if micro { "micro" } else { "ckpt" };
    format!("{tag}-{step:08}.json")
}

/// Content-addressed checkpoint store rooted at a directory.
pub struct CheckpointStore {
    root: PathBuf,
    /// Keep at most this many full checkpoints in the active lineage
    /// (manifest-level rolling prune; blobs die in the GC sweep).
    pub keep: usize,
}

impl CheckpointStore {
    pub fn open(root: &Path, keep: usize) -> anyhow::Result<CheckpointStore> {
        let store = CheckpointStore {
            root: root.to_path_buf(),
            keep: keep.max(1),
        };
        fs::create_dir_all(store.objects_dir())?;
        fs::create_dir_all(store.lineages_dir())?;
        if !store.lineage_file().exists() {
            fs::create_dir_all(store.lineage_dir(0))?;
            let mut j = Json::obj();
            j.set("active", 0u64);
            write_atomic(&store.lineage_file(), &j.pretty())?;
        } else {
            fs::create_dir_all(store.lineage_dir(store.active_generation()?))?;
        }
        // Retire leftovers from a crash window: a generation whose swap
        // committed but whose cleanup didn't (commit died between the
        // LINEAGE.json rename and remove_dir_all), or a staged lineage
        // whose process died before commit/abort.  At open time only
        // the active generation is live; anything else would pin its
        // blobs through the GC's liveness scan forever.
        let active_dir = store.lineage_dir(store.active_generation()?);
        let mut swept = false;
        for dir in store.lineage_dirs()? {
            if dir != active_dir {
                crate::util::faultfs::remove_dir_all(&dir)?;
                swept = true;
            }
        }
        if swept {
            store.gc()?;
        }
        store.validate_active()?;
        Ok(store)
    }

    fn objects_dir(&self) -> PathBuf {
        self.root.join("objects")
    }

    fn lineages_dir(&self) -> PathBuf {
        self.root.join("lineages")
    }

    fn lineage_dir(&self, generation: u64) -> PathBuf {
        self.lineages_dir().join(format!("gen-{generation:08}"))
    }

    fn lineage_file(&self) -> PathBuf {
        self.root.join("LINEAGE.json")
    }

    fn object_path(&self, hash: &str) -> PathBuf {
        self.objects_dir().join(hash)
    }

    /// The active lineage generation (re-read from disk on every query
    /// so long-lived instances observe a swap by another instance).
    pub fn active_generation(&self) -> anyhow::Result<u64> {
        let text = fs::read_to_string(self.lineage_file())?;
        let j = parse(&text)
            .map_err(|e| anyhow::anyhow!("bad LINEAGE.json: {e}"))?;
        j.get("active")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow::anyhow!("LINEAGE.json missing 'active'"))
    }

    fn active_dir(&self) -> anyhow::Result<PathBuf> {
        Ok(self.lineage_dir(self.active_generation()?))
    }

    /// IDs laundered out of the active lineage (empty for gen 0 or a
    /// lineage that was never laundered).  After laundered-set
    /// compaction this is only the *residue* — IDs not yet folded into
    /// the WAL IdMap's retired set; see [`CheckpointStore::
    /// laundered_meta`] for the full accounting.
    pub fn laundered_ids(&self) -> anyhow::Result<Vec<u64>> {
        read_ids_json(&self.active_dir()?.join("laundered.json"))
    }

    /// The active lineage's laundered-set accounting: (residue IDs not
    /// yet compacted into the IdMap, count of IDs already retired
    /// there).  The residue is what a reopening harness must still add
    /// to its replay filters; the retired count is bookkeeping only
    /// (the IdMap enforces those during traversal).
    pub fn laundered_meta(&self) -> anyhow::Result<(Vec<u64>, u64)> {
        let path = self.active_dir()?.join("laundered.json");
        if !path.exists() {
            return Ok((Vec::new(), 0));
        }
        let j = parse(&fs::read_to_string(&path)?).map_err(|e| {
            anyhow::anyhow!("bad laundered.json {}: {e}", path.display())
        })?;
        let ids = j
            .get("ids")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
            .unwrap_or_default();
        let retired =
            j.get("retired").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok((ids, retired))
    }

    /// Compact the active lineage's `laundered.json` after its closure
    /// was folded into the WAL IdMap's retired set: the residue empties
    /// and only the cumulative `retired` count remains, so the file
    /// stops growing with service lifetime.  Ordering contract: the
    /// caller retires the IDs (and persists the IdMap) FIRST — a crash
    /// before this rewrite merely leaves the full residue on disk,
    /// which reopening harnesses keep filtering (double coverage is
    /// harmless; a gap would not be).
    pub fn compact_laundered(&self, retired_total: u64) -> anyhow::Result<()> {
        let path = self.active_dir()?.join("laundered.json");
        let mut j = if path.exists() {
            parse(&fs::read_to_string(&path)?).map_err(|e| {
                anyhow::anyhow!("bad laundered.json {}: {e}", path.display())
            })?
        } else {
            Json::obj()
        };
        j.set("ids", Json::Arr(Vec::new()))
            .set("retired", retired_total);
        write_atomic(&path, &j.pretty())
    }

    /// Store one tensor, deduplicating on content: hash the in-memory
    /// bytes, write the blob only when that hash is new to the store.
    fn put_tensor(&self, data: &[f32]) -> anyhow::Result<String> {
        let bytes = simd::as_bytes(data);
        let mut h = StreamingSha256::new();
        h.update(bytes);
        let hash = h.finalize_hex();
        let path = self.object_path(&hash);
        if !path.exists() {
            write_object(&path, bytes)?;
        }
        Ok(hash)
    }

    /// Load one tensor by hash, verifying content (fail-closed).
    fn get_tensor(
        &self,
        step: u32,
        tensor: &'static str,
        hash: &str,
    ) -> anyhow::Result<Vec<f32>> {
        let path = self.object_path(hash);
        if !path.exists() {
            return Err(StoreError::DanglingObject {
                step,
                tensor,
                hash: hash.to_string(),
            }
            .into());
        }
        let (data, got) = read_object_hashed(&path)?;
        if got != hash {
            return Err(StoreError::HashMismatch {
                step,
                tensor,
                expect: hash.to_string(),
                got,
            }
            .into());
        }
        Ok(data)
    }

    fn full_manifest(&self, state: &TrainState) -> anyhow::Result<Json> {
        let params = self.put_tensor(&state.params)?;
        let m = self.put_tensor(&state.m)?;
        let v = self.put_tensor(&state.v)?;
        let mut meta = Json::obj();
        meta.set("logical_step", state.logical_step)
            .set("applied_updates", state.applied_updates)
            .set("param_count", state.params.len())
            .set("params_sha256", params.as_str())
            .set("m_sha256", m.as_str())
            .set("v_sha256", v.as_str())
            .set("kind", "full");
        Ok(meta)
    }

    /// Save a full checkpoint (weights + optimizer) into the active
    /// lineage.  Tensors already present in the CAS cost one hash pass
    /// and zero writes.
    pub fn save_full(&self, state: &TrainState) -> anyhow::Result<PathBuf> {
        let meta = self.full_manifest(state)?;
        let path = self
            .active_dir()?
            .join(manifest_name(state.logical_step, false));
        write_atomic(&path, &meta.pretty())?;
        self.prune()?;
        Ok(path)
    }

    /// Save a weights-only micro-checkpoint (Table 3 row 2).  At a step
    /// that also has a full checkpoint the params blob dedups to zero
    /// extra tensor bytes.
    pub fn save_micro(&self, state: &TrainState) -> anyhow::Result<PathBuf> {
        let params = self.put_tensor(&state.params)?;
        let mut meta = Json::obj();
        meta.set("logical_step", state.logical_step)
            .set("applied_updates", state.applied_updates)
            .set("param_count", state.params.len())
            .set("params_sha256", params.as_str())
            .set("kind", "micro");
        let path = self
            .active_dir()?
            .join(manifest_name(state.logical_step, true));
        write_atomic(&path, &meta.pretty())?;
        Ok(path)
    }

    fn read_manifest(&self, path: &Path) -> anyhow::Result<Json> {
        let text = fs::read_to_string(path)?;
        parse(&text).map_err(|e| {
            StoreError::CorruptManifest {
                path: path.display().to_string(),
                detail: e.to_string(),
            }
            .into()
        })
    }

    fn manifest_hash(
        meta: &Json,
        path: &Path,
        tensor: &'static str,
        key: &str,
    ) -> anyhow::Result<String> {
        meta.get(key)
            .and_then(|j| j.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| {
                StoreError::CorruptManifest {
                    path: path.display().to_string(),
                    detail: format!("missing {tensor} hash field {key}"),
                }
                .into()
            })
    }

    /// Load a full checkpoint from the active lineage, verifying every
    /// tensor's content hash (A4: exact restoration or typed failure).
    pub fn load_full(&self, step: u32) -> anyhow::Result<TrainState> {
        let path = self.active_dir()?.join(manifest_name(step, false));
        if !path.exists() {
            return Err(StoreError::MissingCheckpoint { step }.into());
        }
        let meta = self.read_manifest(&path)?;
        let params = self.get_tensor(
            step,
            "params",
            &Self::manifest_hash(&meta, &path, "params", "params_sha256")?,
        )?;
        let m = self.get_tensor(
            step,
            "m",
            &Self::manifest_hash(&meta, &path, "m", "m_sha256")?,
        )?;
        let v = self.get_tensor(
            step,
            "v",
            &Self::manifest_hash(&meta, &path, "v", "v_sha256")?,
        )?;
        Ok(TrainState {
            params,
            m,
            v,
            applied_updates: meta
                .get("applied_updates")
                .and_then(|j| j.as_u64())
                .unwrap_or(0) as u32,
            logical_step: meta
                .get("logical_step")
                .and_then(|j| j.as_u64())
                .unwrap_or(step as u64) as u32,
        })
    }

    fn list_full_in(dir: &Path) -> anyhow::Result<Vec<u32>> {
        let mut steps = Vec::new();
        if !dir.exists() {
            return Ok(steps);
        }
        for e in fs::read_dir(dir)? {
            let name = e?.file_name().to_string_lossy().into_owned();
            if let Some(s) = name
                .strip_prefix("ckpt-")
                .and_then(|s| s.strip_suffix(".json"))
            {
                if let Ok(step) = s.parse() {
                    steps.push(step);
                }
            }
        }
        steps.sort_unstable();
        Ok(steps)
    }

    /// All full-checkpoint steps in the active lineage, ascending.
    pub fn list_full(&self) -> anyhow::Result<Vec<u32>> {
        Self::list_full_in(&self.active_dir()?)
    }

    /// Latest full checkpoint at or before `step` (Alg. A.7 line 14:
    /// "load nearest checkpoint C_k").  After a launder swap this serves
    /// the laundered lineage — nearest-checkpoint selection is lineage
    /// aware by construction.
    pub fn nearest_at_or_before(&self, step: u32) -> anyhow::Result<Option<u32>> {
        Ok(self
            .list_full()?
            .into_iter()
            .filter(|&s| s <= step)
            .max())
    }

    /// Logical bytes of a full checkpoint (Table 3 accounting): the sum
    /// of its referenced object sizes.  Shared blobs are counted here
    /// per reference; `stats()` reports the physical dedup.
    pub fn full_checkpoint_bytes(&self, step: u32) -> anyhow::Result<u64> {
        let path = self.active_dir()?.join(manifest_name(step, false));
        if !path.exists() {
            return Err(StoreError::MissingCheckpoint { step }.into());
        }
        let meta = self.read_manifest(&path)?;
        let mut total = 0u64;
        for key in ["params_sha256", "m_sha256", "v_sha256"] {
            if let Some(hash) = meta.get(key).and_then(|j| j.as_str()) {
                total += fs::metadata(self.object_path(hash))
                    .map(|md| md.len())
                    .unwrap_or(0);
            }
        }
        Ok(total)
    }

    /// Rolling manifest prune (the old `keep` policy) + GC sweep when
    /// anything was dropped.
    fn prune(&self) -> anyhow::Result<()> {
        let dir = self.active_dir()?;
        let steps = Self::list_full_in(&dir)?;
        if steps.len() <= self.keep {
            return Ok(());
        }
        for &s in &steps[..steps.len() - self.keep] {
            crate::util::faultfs::remove_file(&dir.join(manifest_name(s, false)))?;
        }
        self.gc()?;
        Ok(())
    }

    fn lineage_dirs(&self) -> anyhow::Result<Vec<PathBuf>> {
        let mut dirs = Vec::new();
        for e in fs::read_dir(self.lineages_dir())? {
            let e = e?;
            if e.file_type()?.is_dir() {
                dirs.push(e.path());
            }
        }
        dirs.sort();
        Ok(dirs)
    }

    /// Every (hash, referencing-manifest-count) across ALL lineage
    /// directories — active, staged, and not-yet-retired alike.  GC
    /// liveness; fail-closed: an unparseable manifest aborts the scan
    /// (never delete blobs whose liveness is unknown).
    fn referenced_hashes(&self) -> anyhow::Result<HashMap<String, u64>> {
        let mut refs: HashMap<String, u64> = HashMap::new();
        for dir in self.lineage_dirs()? {
            for e in fs::read_dir(&dir)? {
                let path = e?.path();
                let name = path
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_default();
                let is_manifest = (name.starts_with("ckpt-")
                    || name.starts_with("micro-"))
                    && name.ends_with(".json");
                if !is_manifest {
                    continue;
                }
                let meta = self.read_manifest(&path)?;
                for key in ["params_sha256", "m_sha256", "v_sha256"] {
                    if let Some(h) = meta.get(key).and_then(|j| j.as_str()) {
                        *refs.entry(h.to_string()).or_insert(0) += 1;
                    }
                }
            }
        }
        Ok(refs)
    }

    /// Refcounted garbage collection: remove every object no manifest
    /// in any live lineage references (plus stale `.tmp` leftovers).
    pub fn gc(&self) -> anyhow::Result<GcStats> {
        let live = self.referenced_hashes()?;
        let mut stats = GcStats {
            removed_objects: 0,
            removed_bytes: 0,
            live_objects: 0,
        };
        for e in fs::read_dir(self.objects_dir())? {
            let e = e?;
            let path = e.path();
            let name = e.file_name().to_string_lossy().into_owned();
            if name.ends_with(".tmp") {
                let _ = crate::util::faultfs::remove_file(&path); // interrupted writer
                continue;
            }
            if live.contains_key(&name) {
                stats.live_objects += 1;
            } else {
                stats.removed_bytes += e.metadata().map(|m| m.len()).unwrap_or(0);
                stats.removed_objects += 1;
                crate::util::faultfs::remove_file(&path)?;
            }
        }
        Ok(stats)
    }

    /// Store-wide accounting (objects, dedup ratio, lineage state).
    pub fn stats(&self) -> anyhow::Result<CasStats> {
        let refs = self.referenced_hashes()?;
        let mut objects = 0u64;
        let mut object_bytes = 0u64;
        let mut size_of: HashMap<String, u64> = HashMap::new();
        for e in fs::read_dir(self.objects_dir())? {
            let e = e?;
            let name = e.file_name().to_string_lossy().into_owned();
            if name.ends_with(".tmp") {
                continue;
            }
            let len = e.metadata()?.len();
            objects += 1;
            object_bytes += len;
            size_of.insert(name, len);
        }
        let mut manifests = 0u64;
        for dir in self.lineage_dirs()? {
            for e in fs::read_dir(&dir)? {
                let name = e?.file_name().to_string_lossy().into_owned();
                if (name.starts_with("ckpt-") || name.starts_with("micro-"))
                    && name.ends_with(".json")
                {
                    manifests += 1;
                }
            }
        }
        // detlint: allow(unordered-iter) — u64 sum is order-independent
        // and CasStats is operator observability, never hashed or replayed
        let referenced_bytes: u64 = refs
            .iter()
            .map(|(h, n)| size_of.get(h).copied().unwrap_or(0) * n)
            .sum();
        Ok(CasStats {
            objects,
            object_bytes,
            manifests,
            referenced_bytes,
            dedup_ratio: if referenced_bytes > 0 {
                object_bytes as f64 / referenced_bytes as f64
            } else {
                1.0
            },
            generation: self.active_generation()?,
            laundered_ids: {
                let (residue, retired) = self.laundered_meta()?;
                residue.len() as u64 + retired
            },
        })
    }

    /// Structural validation of the active lineage: every manifest must
    /// parse and every referenced object must exist.  Content hashes are
    /// verified on load; `open` checks reachability only.
    fn validate_active(&self) -> anyhow::Result<()> {
        let dir = self.active_dir()?;
        for step in Self::list_full_in(&dir)? {
            let path = dir.join(manifest_name(step, false));
            let meta = self.read_manifest(&path)?;
            for (tensor, key) in [
                ("params", "params_sha256"),
                ("m", "m_sha256"),
                ("v", "v_sha256"),
            ] {
                let hash =
                    Self::manifest_hash(&meta, &path, tensor, key)?;
                if !self.object_path(&hash).exists() {
                    return Err(StoreError::DanglingObject {
                        step,
                        tensor,
                        hash,
                    }
                    .into());
                }
            }
        }
        Ok(())
    }

    /// Begin staging the successor lineage (the laundering target).  An
    /// aborted earlier stage at the same generation is discarded.
    ///
    /// While a stage is live, do not `open` another store on the same
    /// root: `open` retires every non-active lineage directory
    /// (crash-leftover cleanup) and would sweep the stage.  The
    /// controller guarantees this by holding the system lock across the
    /// whole launder pass.
    pub fn begin_lineage(&self) -> anyhow::Result<LineageStage<'_>> {
        let generation = self.active_generation()? + 1;
        let dir = self.lineage_dir(generation);
        if dir.exists() {
            crate::util::faultfs::remove_dir_all(&dir)?;
        }
        fs::create_dir_all(&dir)?;
        Ok(LineageStage {
            store: self,
            generation,
            dir,
        })
    }
}

/// A staged (not yet active) lineage generation.  Blobs written through
/// the stage land in the shared CAS immediately — they are live (the
/// staged directory's manifests reference them) but invisible to
/// readers until `commit` swaps `LINEAGE.json`.
pub struct LineageStage<'a> {
    store: &'a CheckpointStore,
    pub generation: u64,
    dir: PathBuf,
}

impl LineageStage<'_> {
    /// Write a laundered checkpoint into the staged lineage.
    pub fn save_full(&self, state: &TrainState) -> anyhow::Result<PathBuf> {
        let meta = self.store.full_manifest(state)?;
        let path = self.dir.join(manifest_name(state.logical_step, false));
        write_atomic(&path, &meta.pretty())?;
        Ok(path)
    }

    /// Adopt a clean checkpoint from the active lineage: copy its
    /// manifest verbatim — the blobs are shared, zero tensor bytes move.
    pub fn adopt_full(&self, step: u32) -> anyhow::Result<()> {
        let src = self
            .store
            .active_dir()?
            .join(manifest_name(step, false));
        if !src.exists() {
            return Err(StoreError::MissingCheckpoint { step }.into());
        }
        crate::util::faultfs::copy(&src, &self.dir.join(manifest_name(step, false)))?;
        Ok(())
    }

    /// Full-checkpoint steps staged so far (adopted + written).
    pub fn list_full(&self) -> anyhow::Result<Vec<u32>> {
        CheckpointStore::list_full_in(&self.dir)
    }

    /// Atomically make this lineage active: persist its laundered
    /// closure, swap `LINEAGE.json` (tmp + rename), retire the previous
    /// generation's manifests and sweep unreferenced blobs.
    /// `retired` carries the count of IDs ALREADY folded into the WAL
    /// IdMap's retired set by earlier compactions, so the laundered
    /// accounting stays exact in every crash window.
    pub fn commit(
        self,
        laundered: &[u64],
        laundered_at_step: u32,
        retired: u64,
    ) -> anyhow::Result<()> {
        let previous = self.store.active_generation()?;
        let mut lj = ids_json(laundered);
        lj.set("laundered_at_step", laundered_at_step)
            .set("parent_generation", previous)
            .set("retired", retired);
        write_atomic(&self.dir.join("laundered.json"), &lj.pretty())?;
        let mut j = Json::obj();
        j.set("active", self.generation);
        // the swap point: readers see the old lineage before this
        // rename and the complete new one after it
        write_atomic(&self.store.lineage_file(), &j.pretty())?;
        // The swap is DURABLE from the rename above: cleanup must not
        // be able to fail a committed commit — the caller's in-memory
        // transition and signed-manifest record have to follow the
        // swap no matter what.  A failed retire/sweep only strands the
        // old generation's blobs temporarily: the next store open
        // retires every non-active lineage dir and re-runs the GC.
        let cleanup = (|| -> anyhow::Result<()> {
            crate::util::faultfs::remove_dir_all(
                &self.store.lineage_dir(previous),
            )?;
            self.store.gc()?;
            Ok(())
        })();
        if let Err(e) = cleanup {
            eprintln!(
                "post-swap lineage cleanup failed (committed swap \
                 unaffected; the next store open retires and re-sweeps): \
                 {e:#}"
            );
        }
        Ok(())
    }

    /// Discard the staged lineage (audit gate refused the swap) and
    /// sweep any blobs only it referenced.
    pub fn abort(self) -> anyhow::Result<()> {
        crate::util::faultfs::remove_dir_all(&self.dir)?;
        self.store.gc()?;
        Ok(())
    }
}

/// Convenience for tests: distinct blob hashes a state would reference.
pub fn state_tensor_hashes(state: &TrainState) -> HashSet<String> {
    let mut out = HashSet::new();
    for t in [&state.params, &state.m, &state.v] {
        let mut h = StreamingSha256::new();
        h.update(simd::as_bytes(t));
        out.insert(h.finalize_hex());
    }
    out
}

// ---------------------------------------------------------------------------
// Read-side CAS export/import — the replica sync protocol's primitive layer.
//
// These are free path-based functions rather than `CheckpointStore`
// methods on purpose: `CheckpointStore::open` retires every non-active
// lineage directory, which on a replica mid-pull would destroy the
// generation being staged.  The import side only ever creates or
// replaces files under a NON-active generation directory and swaps
// `LINEAGE.json` last, so a crash at any point leaves the mirror
// serving the old generation — the eventual `open` sweep is the
// recovery path (old-or-new, never mixed).
// ---------------------------------------------------------------------------

fn lineage_dir_of(root: &Path, generation: u64) -> PathBuf {
    root.join("lineages").join(format!("gen-{generation:08}"))
}

fn object_path_of(root: &Path, hash: &str) -> PathBuf {
    root.join("objects").join(hash)
}

/// One manifest file of an exported lineage, by name and full text.
/// Shipping the exact bytes (not a re-encode) keeps the mirror
/// byte-identical to the source lineage directory.
#[derive(Debug, Clone)]
pub struct ExportedManifest {
    /// File name inside the lineage dir (`ckpt-…`/`micro-…`.json).
    pub name: String,
    /// Verbatim manifest text.
    pub contents: String,
}

/// A source store's active lineage, flattened for transfer: the
/// manifests plus the sorted set of object hashes they reference.
/// Objects themselves are pulled separately (and only if missing —
/// content addressing makes the pull a byte-level diff).
#[derive(Debug, Clone)]
pub struct CasSnapshot {
    /// Generation this snapshot captures.
    pub generation: u64,
    /// Manifest files, sorted by name.
    pub manifests: Vec<ExportedManifest>,
    /// Verbatim `laundered.json` text, if the lineage has one.
    pub laundered: Option<String>,
    /// Every object hash any manifest references — sorted, deduped.
    pub object_hashes: Vec<String>,
}

/// The active generation recorded in a store root's `LINEAGE.json`.
/// Errors if the file is absent (no store, or a mirror that never
/// completed a first sync) — callers treat that as "nothing adopted".
pub fn read_generation(root: &Path) -> anyhow::Result<u64> {
    let text = fs::read_to_string(root.join("LINEAGE.json"))?;
    let j = parse(&text).map_err(|e| anyhow::anyhow!("bad LINEAGE.json: {e}"))?;
    j.get("active")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| anyhow::anyhow!("LINEAGE.json missing 'active'"))
}

/// Export the active lineage of the store at `root` for replication.
/// Read-only; safe against a live writer because a lineage's manifest
/// set only changes through whole-file tmp+rename writes.
pub fn export_snapshot(root: &Path) -> anyhow::Result<CasSnapshot> {
    let generation = read_generation(root)?;
    let dir = lineage_dir_of(root, generation);
    let mut names: Vec<String> = Vec::new();
    for e in fs::read_dir(&dir)? {
        let name = e?.file_name().to_string_lossy().into_owned();
        let is_manifest = (name.starts_with("ckpt-")
            || name.starts_with("micro-"))
            && name.ends_with(".json");
        if is_manifest {
            names.push(name);
        }
    }
    names.sort_unstable();
    let mut manifests = Vec::with_capacity(names.len());
    let mut object_hashes: Vec<String> = Vec::new();
    for name in names {
        let path = dir.join(&name);
        let contents = fs::read_to_string(&path)?;
        let meta = parse(&contents).map_err(|e| {
            anyhow::Error::new(StoreError::CorruptManifest {
                path: path.display().to_string(),
                detail: e.to_string(),
            })
        })?;
        for key in ["params_sha256", "m_sha256", "v_sha256"] {
            if let Some(h) = meta.get(key).and_then(|j| j.as_str()) {
                object_hashes.push(h.to_string());
            }
        }
        manifests.push(ExportedManifest { name, contents });
    }
    object_hashes.sort_unstable();
    object_hashes.dedup();
    let lpath = dir.join("laundered.json");
    let laundered = if lpath.exists() {
        Some(fs::read_to_string(&lpath)?)
    } else {
        None
    };
    Ok(CasSnapshot {
        generation,
        manifests,
        laundered,
        object_hashes,
    })
}

/// Does the store at `root` already hold this object?  (The dedup
/// probe: a replica skips the transfer entirely when true.)
pub fn object_present(root: &Path, hash: &str) -> bool {
    object_path_of(root, hash).is_file()
}

/// On-disk size of an object (0 if absent) — the dedup accounting's
/// bytes-not-transferred term.
pub fn object_len(root: &Path, hash: &str) -> u64 {
    fs::metadata(object_path_of(root, hash))
        .map(|m| m.len())
        .unwrap_or(0)
}

/// Read an object's raw bytes, verifying content against its name.
/// Fail-closed on both ends of the wire: the source refuses to export
/// a corrupt blob, the sink refuses to ingest one.
pub fn read_object_verified(root: &Path, hash: &str) -> anyhow::Result<Vec<u8>> {
    let path = object_path_of(root, hash);
    let bytes = fs::read(&path)?;
    let mut h = StreamingSha256::new();
    h.update(&bytes);
    let got = h.finalize_hex();
    anyhow::ensure!(
        got == hash,
        "object {} hashes to {got} — refusing to replicate a corrupt \
         blob (A4)",
        path.display()
    );
    Ok(bytes)
}

/// Ingest one object into the store at `root`.  The recomputed hash
/// must match `hash` (fail closed on a torn or tampered transfer);
/// an already-present object costs zero writes.  Returns whether
/// bytes were actually written.
pub fn import_object(root: &Path, hash: &str, bytes: &[u8]) -> anyhow::Result<bool> {
    let mut h = StreamingSha256::new();
    h.update(bytes);
    let got = h.finalize_hex();
    anyhow::ensure!(
        got == hash,
        "refusing to ingest object {hash}: content hashes to {got} \
         (fail closed)"
    );
    fs::create_dir_all(root.join("objects"))?;
    let path = object_path_of(root, hash);
    if path.exists() {
        return Ok(false);
    }
    write_object(&path, bytes)?;
    Ok(true)
}

/// Start staging `generation` at `root`: clear any half-pulled remnant
/// of the same generation (a previous sync that died) and recreate the
/// directory empty.  Never touches `LINEAGE.json` or any other
/// generation's directory.
pub fn begin_import(root: &Path, generation: u64) -> anyhow::Result<()> {
    let dir = lineage_dir_of(root, generation);
    if dir.exists() {
        crate::util::faultfs::remove_dir_all(&dir)?;
    }
    fs::create_dir_all(&dir)?;
    Ok(())
}

/// Stage one manifest (or `laundered.json`) file into a generation
/// directory, verbatim, via the atomic write primitive.  Names are
/// validated against the lineage-dir vocabulary so a malicious or
/// corrupt snapshot cannot write outside the staged directory.
pub fn import_manifest(
    root: &Path,
    generation: u64,
    name: &str,
    contents: &str,
) -> anyhow::Result<()> {
    let plain = !name.contains('/') && !name.contains('\\') && !name.contains("..");
    let known = name == "laundered.json"
        || ((name.starts_with("ckpt-") || name.starts_with("micro-"))
            && name.ends_with(".json"));
    anyhow::ensure!(
        plain && known,
        "refusing to import manifest with unexpected name {name:?}"
    );
    write_atomic(&lineage_dir_of(root, generation).join(name), contents)
}

/// Adopt a fully staged generation: verify every object every staged
/// manifest references is present (a half-pulled generation must never
/// become servable), then swap `LINEAGE.json` — the single commit
/// point.  A crash before the swap leaves the old generation active;
/// after it, the new one is complete by the check just performed.
pub fn adopt_generation(root: &Path, generation: u64) -> anyhow::Result<()> {
    let dir = lineage_dir_of(root, generation);
    let mut names: Vec<String> = Vec::new();
    for e in fs::read_dir(&dir)? {
        let name = e?.file_name().to_string_lossy().into_owned();
        if (name.starts_with("ckpt-") || name.starts_with("micro-"))
            && name.ends_with(".json")
        {
            names.push(name);
        }
    }
    names.sort_unstable();
    for name in &names {
        let path = dir.join(name);
        let meta = parse(&fs::read_to_string(&path)?).map_err(|e| {
            anyhow::Error::new(StoreError::CorruptManifest {
                path: path.display().to_string(),
                detail: e.to_string(),
            })
        })?;
        let step = meta
            .get("logical_step")
            .and_then(|j| j.as_u64())
            .unwrap_or(0) as u32;
        for (tensor, key) in [
            ("params", "params_sha256"),
            ("m", "m_sha256"),
            ("v", "v_sha256"),
        ] {
            if let Some(h) = meta.get(key).and_then(|j| j.as_str()) {
                if !object_present(root, h) {
                    return Err(StoreError::DanglingObject {
                        step,
                        tensor,
                        hash: h.to_string(),
                    }
                    .into());
                }
            }
        }
    }
    let mut j = Json::obj();
    j.set("active", generation);
    write_atomic(&root.join("LINEAGE.json"), &j.pretty())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{f32_vec_adversarial, for_all};
    use crate::util::rng::SplitMix64;
    use crate::util::tempdir;

    fn state(seed: u64, n: usize, step: u32) -> TrainState {
        let mut r = SplitMix64::new(seed);
        TrainState {
            params: (0..n).map(|_| r.normal() as f32).collect(),
            m: (0..n).map(|_| r.normal() as f32 * 0.01).collect(),
            v: (0..n).map(|_| (r.normal() as f32).abs()).collect(),
            applied_updates: step,
            logical_step: step,
        }
    }

    fn count_objects(dir: &Path) -> usize {
        fs::read_dir(dir.join("objects")).unwrap().count()
    }

    #[test]
    fn save_load_bit_exact() {
        let dir = tempdir("ckpt");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(1, 1000, 5);
        store.save_full(&s).unwrap();
        let back = store.load_full(5).unwrap();
        assert!(s.bits_equal(&back));
        assert_eq!(back.logical_step, 5);
    }

    #[test]
    fn manifest_hashes_match_canonical_tensor_hashes() {
        let dir = tempdir("ckpt-hash");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(9, 333, 2);
        let mpath = store.save_full(&s).unwrap();
        let meta = parse(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
        for (name, tensor) in
            [("params", &s.params), ("m", &s.m), ("v", &s.v)]
        {
            let stored = meta
                .get(&format!("{name}_sha256"))
                .unwrap()
                .as_str()
                .unwrap();
            assert_eq!(
                stored,
                crate::util::bytes::state_hash_full(tensor),
                "{name} object key must equal the canonical tensor hash"
            );
            assert!(
                dir.join("objects").join(stored).exists(),
                "{name} blob stored under its hash"
            );
        }
    }

    #[test]
    fn adversarial_bit_patterns_roundtrip() {
        let dir = tempdir("ckpt-adv");
        let store = CheckpointStore::open(&dir, 100_000).unwrap();
        for_all("checkpoint nan/denormal roundtrip", |rng| {
            let n = rng.below(200) as usize + 1;
            let mut s = state(rng.next_u64(), n, rng.below(1000) as u32);
            s.params = f32_vec_adversarial(rng, n);
            store.save_full(&s).unwrap();
            let back = store.load_full(s.logical_step).unwrap();
            assert!(s.bits_equal(&back));
        });
    }

    #[test]
    fn cas_dedups_shared_tensors() {
        // two checkpoints sharing unchanged optimizer tensors store each
        // distinct blob once: object count < naive file count
        let dir = tempdir("ckpt-dedup");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s1 = state(4, 512, 1);
        let mut s2 = s1.clone();
        s2.logical_step = 2;
        s2.params = state(5, 512, 2).params; // only the weights moved
        store.save_full(&s1).unwrap();
        store.save_full(&s2).unwrap();
        let naive_files = 6; // 2 checkpoints x 3 tensors
        assert_eq!(count_objects(&dir), 4, "m/v blobs shared");
        assert!(count_objects(&dir) < naive_files);
        let st = store.stats().unwrap();
        assert_eq!(st.objects, 4);
        assert_eq!(st.manifests, 2);
        assert!(
            st.dedup_ratio < 1.0,
            "sharing must show up in the ratio: {}",
            st.dedup_ratio
        );
        // micro checkpoint at a full-checkpoint step adds ZERO blobs
        store.save_micro(&s2).unwrap();
        assert_eq!(count_objects(&dir), 4);
        // both checkpoints restore exactly despite sharing
        assert!(store.load_full(1).unwrap().bits_equal(&s1));
        assert!(store.load_full(2).unwrap().bits_equal(&s2));
    }

    #[test]
    fn corrupted_blob_fails_closed_with_hash_mismatch() {
        let dir = tempdir("ckpt-tamper");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(2, 100, 7);
        let mpath = store.save_full(&s).unwrap();
        let meta = parse(&fs::read_to_string(&mpath).unwrap()).unwrap();
        let phash = meta.get("params_sha256").unwrap().as_str().unwrap();
        let pbin = dir.join("objects").join(phash);
        let mut raw = fs::read(&pbin).unwrap();
        raw[13] ^= 1;
        fs::write(&pbin, raw).unwrap();
        let err = store.load_full(7).unwrap_err();
        match err.downcast_ref::<StoreError>() {
            Some(StoreError::HashMismatch { step, tensor, .. }) => {
                assert_eq!(*step, 7);
                assert_eq!(*tensor, "params");
            }
            other => panic!("expected typed HashMismatch, got {other:?}"),
        }
    }

    #[test]
    fn truncated_blob_fails_closed() {
        let dir = tempdir("ckpt-trunc");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(3, 64, 1);
        let mpath = store.save_full(&s).unwrap();
        let meta = parse(&fs::read_to_string(&mpath).unwrap()).unwrap();
        let mhash = meta.get("m_sha256").unwrap().as_str().unwrap();
        let mbin = dir.join("objects").join(mhash);
        let raw = fs::read(&mbin).unwrap();
        fs::write(&mbin, &raw[..raw.len() - 2]).unwrap(); // unaligned too
        assert!(store.load_full(1).is_err());
        // 4-aligned truncation is caught by the hash, not the length
        fs::write(&mbin, &raw[..raw.len() - 8]).unwrap();
        let err = store.load_full(1).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<StoreError>(),
            Some(StoreError::HashMismatch { tensor: "m", .. })
        ));
    }

    #[test]
    fn open_reports_dangling_reference_as_typed_error() {
        let dir = tempdir("ckpt-dangling");
        {
            let store = CheckpointStore::open(&dir, 10).unwrap();
            let s = state(6, 80, 3);
            let mpath = store.save_full(&s).unwrap();
            let meta =
                parse(&fs::read_to_string(&mpath).unwrap()).unwrap();
            let vhash = meta.get("v_sha256").unwrap().as_str().unwrap();
            fs::remove_file(dir.join("objects").join(vhash)).unwrap();
        }
        let err = CheckpointStore::open(&dir, 10).unwrap_err();
        match err.downcast_ref::<StoreError>() {
            Some(StoreError::DanglingObject { step, tensor, .. }) => {
                assert_eq!(*step, 3);
                assert_eq!(*tensor, "v");
            }
            other => panic!("expected typed DanglingObject, got {other:?}"),
        }
    }

    #[test]
    fn rolling_prune_keeps_latest_and_gcs_blobs() {
        let dir = tempdir("ckpt-gc");
        let store = CheckpointStore::open(&dir, 3).unwrap();
        for step in [1, 2, 3, 4, 5] {
            store.save_full(&state(step as u64, 50, step)).unwrap();
        }
        assert_eq!(store.list_full().unwrap(), vec![3, 4, 5]);
        // distinct random tensors: exactly the 9 live blobs remain
        assert_eq!(count_objects(&dir), 9);
        assert!(store.load_full(3).is_ok());
        assert!(store.load_full(1).is_err(), "pruned manifest is gone");
    }

    #[test]
    fn gc_never_collects_objects_referenced_by_any_live_lineage() {
        let dir = tempdir("ckpt-gc-lineage");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s0 = state(11, 64, 0);
        let s1 = state(12, 64, 4);
        store.save_full(&s0).unwrap();
        store.save_full(&s1).unwrap();

        // stage a successor lineage that adopts ONLY step 0 and writes
        // one new laundered checkpoint
        let stage = store.begin_lineage().unwrap();
        stage.adopt_full(0).unwrap();
        let laundered = state(13, 64, 4);
        stage.save_full(&laundered).unwrap();

        // while both lineages are live, a sweep removes nothing that
        // either references — s1's blobs are still held by gen 0
        let before = count_objects(&dir);
        let gcs = store.gc().unwrap();
        assert_eq!(gcs.removed_objects, 0);
        assert_eq!(count_objects(&dir), before);
        assert!(store.load_full(4).unwrap().bits_equal(&s1));

        // commit: gen 0 retires, s1's unshared blobs are collected,
        // adopted s0 survives via the shared manifest
        stage.commit(&[7, 8], 1, 0).unwrap();
        assert_eq!(store.active_generation().unwrap(), 1);
        assert_eq!(store.laundered_ids().unwrap(), vec![7, 8]);
        assert!(store.load_full(0).unwrap().bits_equal(&s0));
        assert!(store.load_full(4).unwrap().bits_equal(&laundered));
        let live: HashSet<String> = state_tensor_hashes(&s0)
            .union(&state_tensor_hashes(&laundered))
            .cloned()
            .collect();
        assert_eq!(count_objects(&dir), live.len());
        for h in state_tensor_hashes(&s1)
            .difference(&state_tensor_hashes(&laundered))
        {
            if !live.contains(h) {
                assert!(
                    !dir.join("objects").join(h).exists(),
                    "retired-only blob must be collected"
                );
            }
        }
    }

    #[test]
    fn laundered_compaction_empties_residue_and_keeps_count() {
        let dir = tempdir("ckpt-laundered-compact");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        store.save_full(&state(31, 64, 0)).unwrap();
        let stage = store.begin_lineage().unwrap();
        stage.adopt_full(0).unwrap();
        // 2 ids previously retired by an earlier compaction, 3 new
        stage.commit(&[10, 11, 12], 2, 2).unwrap();
        assert_eq!(store.laundered_meta().unwrap(), (vec![10, 11, 12], 2));
        assert_eq!(store.stats().unwrap().laundered_ids, 5);
        // fold the residue into the IdMap → compact the lineage file
        store.compact_laundered(5).unwrap();
        assert_eq!(store.laundered_meta().unwrap(), (Vec::new(), 5));
        assert!(store.laundered_ids().unwrap().is_empty());
        assert_eq!(store.stats().unwrap().laundered_ids, 5);
        // compaction is idempotent and the file stays bounded
        let path = dir
            .join("lineages")
            .join("gen-00000001")
            .join("laundered.json");
        let size = fs::metadata(&path).unwrap().len();
        store.compact_laundered(5).unwrap();
        assert_eq!(fs::metadata(&path).unwrap().len(), size);
    }

    #[test]
    fn abort_discards_stage_and_preserves_active() {
        let dir = tempdir("ckpt-abort");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(21, 64, 0);
        store.save_full(&s).unwrap();
        let stage = store.begin_lineage().unwrap();
        stage.save_full(&state(22, 64, 2)).unwrap();
        stage.abort().unwrap();
        assert_eq!(store.active_generation().unwrap(), 0);
        assert_eq!(store.list_full().unwrap(), vec![0]);
        // only the active checkpoint's blobs remain
        assert_eq!(count_objects(&dir), state_tensor_hashes(&s).len());
        assert!(store.load_full(0).unwrap().bits_equal(&s));
    }

    #[test]
    fn nearest_lookup() {
        let dir = tempdir("ckpt-near");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        for step in [10, 20, 30] {
            store.save_full(&state(step as u64, 10, step)).unwrap();
        }
        assert_eq!(store.nearest_at_or_before(25).unwrap(), Some(20));
        assert_eq!(store.nearest_at_or_before(30).unwrap(), Some(30));
        assert_eq!(store.nearest_at_or_before(5).unwrap(), None);
    }

    #[test]
    fn hashes_match_table5_style() {
        let s = state(3, 64, 0);
        assert_eq!(s.model_hash().len(), 16);
        assert_eq!(s.optimizer_hash().len(), 16);
        let mut s2 = s.clone();
        s2.applied_updates += 1; // step counter is part of optimizer state
        assert_ne!(s.optimizer_hash(), s2.optimizer_hash());
    }
}
