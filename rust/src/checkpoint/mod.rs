//! Checkpoint store: full checkpoints (weights + optimizer state, exact
//! f32 bit images) every K steps and optional weights-only
//! micro-checkpoints every M steps (paper §5, Table 3).
//!
//! File format per checkpoint: a directory `ckpt-{step:08}` containing
//! `params.bin`, `m.bin`, `v.bin` (LE f32 images), `meta.json` (logical
//! step, applied-update counter, content hashes) — restoration is exact
//! by construction (assumption A4): bytes in, bytes out.
//!
//! I/O is single-pass and copy-free: `save_full` streams each tensor's
//! zero-copy byte view to disk while feeding the same bytes to the
//! SHA-256 hasher (the meta hashes are a by-product of the write, not a
//! second serialization), and `load_full` reads straight into the f32
//! buffer's byte view and hashes that — no intermediate `Vec<u8>`
//! round-trips of parameter-sized tensors anywhere.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::hashing::StreamingSha256;
use crate::util::json::{parse, Json};
use crate::util::simd;

/// Full training state at a logical step boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Flat parameter vector (training dtype f32).
    pub params: Vec<f32>,
    /// Adam first moment.
    pub m: Vec<f32>,
    /// Adam second moment.
    pub v: Vec<f32>,
    /// Applied-update counter (paper `opt_step`; bias-correction index).
    pub applied_updates: u32,
    /// Logical step the state corresponds to (next step to execute).
    pub logical_step: u32,
}

impl TrainState {
    pub fn zeros_like(params: Vec<f32>) -> TrainState {
        let n = params.len();
        TrainState {
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            applied_updates: 0,
            logical_step: 0,
        }
    }

    /// Bit-identity of the full (θ, Ω) state — the G1 equality relation.
    pub fn bits_equal(&self, other: &TrainState) -> bool {
        use crate::util::bytes::bits_equal;
        bits_equal(&self.params, &other.params)
            && bits_equal(&self.m, &other.m)
            && bits_equal(&self.v, &other.v)
            && self.applied_updates == other.applied_updates
    }

    /// Content hashes in the Table 5 style (64-bit hex prefixes).
    pub fn model_hash(&self) -> String {
        crate::util::bytes::state_hash64(&self.params)
    }

    /// Hash over the full optimizer state (m ‖ v ‖ step counter) —
    /// streamed over the zero-copy views, no concatenated copy.
    pub fn optimizer_hash(&self) -> String {
        let mut h = StreamingSha256::new();
        h.update(simd::as_bytes(&self.m));
        h.update(simd::as_bytes(&self.v));
        h.update(&self.applied_updates.to_le_bytes());
        let hex = h.finalize_hex();
        hex[..16].to_string()
    }
}

/// Stream a tensor's byte view to `path`, hashing while writing.
/// Returns the full SHA-256 hex (identical to
/// `util::bytes::state_hash_full` of the same tensor).
fn write_tensor_hashed(path: &Path, data: &[f32]) -> anyhow::Result<String> {
    let bytes = simd::as_bytes(data);
    let mut f = std::io::BufWriter::new(fs::File::create(path)?);
    let mut h = StreamingSha256::new();
    for chunk in bytes.chunks(1 << 20) {
        h.update(chunk);
        f.write_all(chunk)?;
    }
    f.flush()?;
    Ok(h.finalize_hex())
}

/// Read a tensor file straight into an f32 buffer (single allocation,
/// no byte-vector round-trip), returning (tensor, sha256-hex).
fn read_tensor_hashed(path: &Path) -> anyhow::Result<(Vec<f32>, String)> {
    let len = fs::metadata(path)?.len() as usize;
    anyhow::ensure!(
        len % 4 == 0,
        "tensor file {} length {len} not 4-aligned — refusing inexact \
         restore (A4)",
        path.display()
    );
    let mut out = vec![0.0f32; len / 4];
    let mut f = fs::File::open(path)?;
    f.read_exact(simd::as_bytes_mut(&mut out))?;
    // no trailing bytes (metadata raced a writer?)
    let mut probe = [0u8; 1];
    anyhow::ensure!(
        f.read(&mut probe)? == 0,
        "tensor file {} grew past its metadata length",
        path.display()
    );
    let mut h = StreamingSha256::new();
    h.update(simd::as_bytes(&out));
    Ok((out, h.finalize_hex()))
}

/// On-disk checkpoint store rooted at a directory.
pub struct CheckpointStore {
    root: PathBuf,
    /// Keep at most this many full checkpoints (rolling K snapshots).
    pub keep: usize,
}

impl CheckpointStore {
    pub fn open(root: &Path, keep: usize) -> anyhow::Result<CheckpointStore> {
        fs::create_dir_all(root)?;
        Ok(CheckpointStore {
            root: root.to_path_buf(),
            keep: keep.max(1),
        })
    }

    fn dir_for(&self, step: u32, micro: bool) -> PathBuf {
        let tag = if micro { "micro" } else { "ckpt" };
        self.root.join(format!("{tag}-{step:08}"))
    }

    /// Save a full checkpoint (weights + optimizer) at a step boundary.
    /// Single pass per tensor: the content hash is computed from the
    /// bytes as they stream to disk.
    pub fn save_full(&self, state: &TrainState) -> anyhow::Result<PathBuf> {
        let dir = self.dir_for(state.logical_step, false);
        fs::create_dir_all(&dir)?;
        let params_sha = write_tensor_hashed(&dir.join("params.bin"), &state.params)?;
        let m_sha = write_tensor_hashed(&dir.join("m.bin"), &state.m)?;
        let v_sha = write_tensor_hashed(&dir.join("v.bin"), &state.v)?;
        let mut meta = Json::obj();
        meta.set("logical_step", state.logical_step)
            .set("applied_updates", state.applied_updates)
            .set("param_count", state.params.len())
            .set("params_sha256", params_sha.as_str())
            .set("m_sha256", m_sha.as_str())
            .set("v_sha256", v_sha.as_str())
            .set("kind", "full");
        fs::write(dir.join("meta.json"), meta.pretty())?;
        self.gc()?;
        Ok(dir)
    }

    /// Save a weights-only micro-checkpoint (Table 3 row 2).
    pub fn save_micro(&self, state: &TrainState) -> anyhow::Result<PathBuf> {
        let dir = self.dir_for(state.logical_step, true);
        fs::create_dir_all(&dir)?;
        let params_sha = write_tensor_hashed(&dir.join("params.bin"), &state.params)?;
        let mut meta = Json::obj();
        meta.set("logical_step", state.logical_step)
            .set("applied_updates", state.applied_updates)
            .set("param_count", state.params.len())
            .set("params_sha256", params_sha.as_str())
            .set("kind", "micro");
        fs::write(dir.join("meta.json"), meta.pretty())?;
        Ok(dir)
    }

    /// Load a full checkpoint, verifying content hashes (A4: exact
    /// restoration or hard failure).  Each tensor is read and hashed in
    /// one pass directly into its f32 buffer.
    pub fn load_full(&self, step: u32) -> anyhow::Result<TrainState> {
        let dir = self.dir_for(step, false);
        let meta = parse(&fs::read_to_string(dir.join("meta.json"))?)
            .map_err(|e| anyhow::anyhow!("bad checkpoint meta: {e}"))?;
        let (params, params_sha) = read_tensor_hashed(&dir.join("params.bin"))?;
        let (m, m_sha) = read_tensor_hashed(&dir.join("m.bin"))?;
        let (v, v_sha) = read_tensor_hashed(&dir.join("v.bin"))?;
        for (name, got) in [
            ("params", &params_sha),
            ("m", &m_sha),
            ("v", &v_sha),
        ] {
            let expect = meta
                .get(&format!("{name}_sha256"))
                .and_then(|j| j.as_str())
                .ok_or_else(|| anyhow::anyhow!("missing {name}_sha256"))?;
            anyhow::ensure!(
                got == expect,
                "checkpoint {name} hash mismatch at step {step} — \
                 refusing inexact restore (A4)"
            );
        }
        Ok(TrainState {
            params,
            m,
            v,
            applied_updates: meta
                .get("applied_updates")
                .and_then(|j| j.as_u64())
                .unwrap_or(0) as u32,
            logical_step: step,
        })
    }

    /// All full-checkpoint steps, ascending.
    pub fn list_full(&self) -> anyhow::Result<Vec<u32>> {
        let mut steps = Vec::new();
        for e in fs::read_dir(&self.root)? {
            let name = e?.file_name().to_string_lossy().into_owned();
            if let Some(s) = name.strip_prefix("ckpt-") {
                if let Ok(step) = s.parse() {
                    steps.push(step);
                }
            }
        }
        steps.sort_unstable();
        Ok(steps)
    }

    /// Latest full checkpoint at or before `step` (Alg. A.7 line 14:
    /// "load nearest checkpoint C_k").
    pub fn nearest_at_or_before(&self, step: u32) -> anyhow::Result<Option<u32>> {
        Ok(self
            .list_full()?
            .into_iter()
            .filter(|&s| s <= step)
            .max())
    }

    /// Bytes on disk for a full checkpoint (Table 3 accounting).
    pub fn full_checkpoint_bytes(&self, step: u32) -> anyhow::Result<u64> {
        let dir = self.dir_for(step, false);
        let mut total = 0;
        for e in fs::read_dir(dir)? {
            total += e?.metadata()?.len();
        }
        Ok(total)
    }

    fn gc(&self) -> anyhow::Result<()> {
        let steps = self.list_full()?;
        if steps.len() > self.keep {
            for &s in &steps[..steps.len() - self.keep] {
                fs::remove_dir_all(self.dir_for(s, false))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{f32_vec_adversarial, for_all};
    use crate::util::rng::SplitMix64;
    use crate::util::tempdir;

    fn state(seed: u64, n: usize, step: u32) -> TrainState {
        let mut r = SplitMix64::new(seed);
        TrainState {
            params: (0..n).map(|_| r.normal() as f32).collect(),
            m: (0..n).map(|_| r.normal() as f32 * 0.01).collect(),
            v: (0..n).map(|_| (r.normal() as f32).abs()).collect(),
            applied_updates: step,
            logical_step: step,
        }
    }

    #[test]
    fn save_load_bit_exact() {
        let dir = tempdir("ckpt");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(1, 1000, 5);
        store.save_full(&s).unwrap();
        let back = store.load_full(5).unwrap();
        assert!(s.bits_equal(&back));
        assert_eq!(back.logical_step, 5);
    }

    #[test]
    fn streamed_hashes_match_rehash() {
        // the hash-while-writing shortcut must equal a from-scratch hash
        let dir = tempdir("ckpt-hash");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(9, 333, 2);
        let cdir = store.save_full(&s).unwrap();
        let meta = parse(
            &std::fs::read_to_string(cdir.join("meta.json")).unwrap(),
        )
        .unwrap();
        for (name, tensor) in
            [("params", &s.params), ("m", &s.m), ("v", &s.v)]
        {
            let stored = meta
                .get(&format!("{name}_sha256"))
                .unwrap()
                .as_str()
                .unwrap();
            assert_eq!(
                stored,
                crate::util::bytes::state_hash_full(tensor),
                "{name} hash must equal the canonical tensor hash"
            );
        }
    }

    #[test]
    fn adversarial_bit_patterns_roundtrip() {
        let dir = tempdir("ckpt-adv");
        let store = CheckpointStore::open(&dir, 100_000).unwrap();
        for_all("checkpoint nan/denormal roundtrip", |rng| {
            let n = rng.below(200) as usize + 1;
            let mut s = state(rng.next_u64(), n, rng.below(1000) as u32);
            s.params = f32_vec_adversarial(rng, n);
            store.save_full(&s).unwrap();
            let back = store.load_full(s.logical_step).unwrap();
            assert!(s.bits_equal(&back));
        });
    }

    #[test]
    fn tamper_fails_closed() {
        let dir = tempdir("ckpt-tamper");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(2, 100, 7);
        let cdir = store.save_full(&s).unwrap();
        let pbin = cdir.join("params.bin");
        let mut raw = fs::read(&pbin).unwrap();
        raw[13] ^= 1;
        fs::write(&pbin, raw).unwrap();
        assert!(store.load_full(7).is_err(), "must refuse inexact restore");
    }

    #[test]
    fn truncated_tensor_fails_closed() {
        let dir = tempdir("ckpt-trunc");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        let s = state(3, 64, 1);
        let cdir = store.save_full(&s).unwrap();
        let pbin = cdir.join("m.bin");
        let raw = fs::read(&pbin).unwrap();
        fs::write(&pbin, &raw[..raw.len() - 2]).unwrap(); // unaligned too
        assert!(store.load_full(1).is_err());
    }

    #[test]
    fn rolling_gc_keeps_latest() {
        let dir = tempdir("ckpt-gc");
        let store = CheckpointStore::open(&dir, 3).unwrap();
        for step in [1, 2, 3, 4, 5] {
            store.save_full(&state(step as u64, 50, step)).unwrap();
        }
        assert_eq!(store.list_full().unwrap(), vec![3, 4, 5]);
    }

    #[test]
    fn nearest_lookup() {
        let dir = tempdir("ckpt-near");
        let store = CheckpointStore::open(&dir, 10).unwrap();
        for step in [10, 20, 30] {
            store.save_full(&state(step as u64, 10, step)).unwrap();
        }
        assert_eq!(store.nearest_at_or_before(25).unwrap(), Some(20));
        assert_eq!(store.nearest_at_or_before(30).unwrap(), Some(30));
        assert_eq!(store.nearest_at_or_before(5).unwrap(), None);
    }

    #[test]
    fn hashes_match_table5_style() {
        let s = state(3, 64, 0);
        assert_eq!(s.model_hash().len(), 16);
        assert_eq!(s.optimizer_hash().len(), 16);
        let mut s2 = s.clone();
        s2.applied_updates += 1; // step counter is part of optimizer state
        assert_ne!(s.optimizer_hash(), s2.optimizer_hash());
    }
}
