//! Rule registry and rule passes for `detlint`.
//!
//! Each rule is a pattern over the classified token stream from
//! [`crate::lint::lexer`] plus a module-path context (the path of the
//! file relative to `src/`, unix separators).  Rules are deliberately
//! conservative: they key on the *names* the repo's determinism
//! contract is written in terms of (`SystemTime::now`, `HashMap`,
//! `fs::write`, `.sum::<f32>()`, `unsafe`) and never fire inside
//! string literals, comments, or `#[cfg(test)]` regions.  Known
//! heuristic limits (untyped `.sum()`, scope-blind per-file name
//! marking) are documented in DESIGN.md §"Determinism conformance".
//!
//! Suppression: `// detlint: allow(<rule>) — <reason>` on the same
//! line as the finding or on its own line directly above (intervening
//! comment/attribute/blank lines are skipped).  The reason is
//! mandatory; an empty reason or an unknown rule name is itself a
//! finding (`allow-hygiene`) and does NOT suppress — the escape hatch
//! fails closed, like everything else in this repo.

use super::lexer::{lex, num_is_float, TokKind, Token};

pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_UNORDERED_ITER: &str = "unordered-iter";
pub const RULE_RAW_FS: &str = "raw-fs";
pub const RULE_FLOAT_REDUCE: &str = "float-reduce";
pub const RULE_ENTROPY: &str = "entropy";
pub const RULE_UNSAFE_COMMENT: &str = "unsafe-comment";
pub const RULE_ALLOW_HYGIENE: &str = "allow-hygiene";

/// One registry entry; `--list-rules` prints this table.
pub struct RuleInfo {
    pub id: &'static str,
    pub desc: &'static str,
    pub scope: &'static str,
}

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: RULE_WALL_CLOCK,
        desc: "SystemTime::now / Instant::now outside allowlisted timing modules",
        scope: "all of src/ except metrics/, deltas/",
    },
    RuleInfo {
        id: RULE_UNORDERED_ITER,
        desc: "HashMap/HashSet iteration in serialize/hash/write modules \
               without an immediate sort",
        scope: "wal/, checkpoint/, manifest/, shard/, replica/, ingest/",
    },
    RuleInfo {
        id: RULE_RAW_FS,
        desc: "fs::write / File::create in erasure-critical modules outside \
               write_atomic / faultfs wrappers",
        scope: "wal/, checkpoint/, manifest/, shard/, server/, fleet/, \
                replica/, ingest/",
    },
    RuleInfo {
        id: RULE_FLOAT_REDUCE,
        desc: ".sum::<f32>() or float fold outside runtime::reduce_pinned",
        scope: "all of src/ except runtime/ (reduce_pinned's home)",
    },
    RuleInfo {
        id: RULE_ENTROPY,
        desc: "randomness source other than util/rng (philox / SplitMix64)",
        scope: "all of src/",
    },
    RuleInfo {
        id: RULE_UNSAFE_COMMENT,
        desc: "unsafe block/fn/impl without a // SAFETY: comment",
        scope: "all of src/",
    },
    RuleInfo {
        id: RULE_ALLOW_HYGIENE,
        desc: "detlint: allow(...) with an empty reason or unknown rule \
               (such an allow suppresses nothing)",
        scope: "all of src/",
    },
];

pub fn rule_exists(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// Modules where wall-clock reads are legitimate (observability timing;
/// values never reach serialized state).  Prefix match on the rel path.
const WALL_CLOCK_ALLOWED: &[&str] = &["metrics/", "deltas/"];

/// Modules whose bytes are hashed, serialized, or replayed — unordered
/// iteration here can reach a digest or a wire format.
const SERIALIZE_MODULES: &[&str] =
    &["wal/", "checkpoint/", "manifest/", "shard/", "replica/", "ingest/"];

/// Erasure-critical modules: every durable write must go through
/// `checkpoint::write_atomic` or the `util::faultfs` wrappers so the
/// crash matrix and fault injection see it.
const DURABLE_MODULES: &[&str] = &[
    "wal/",
    "checkpoint/",
    "manifest/",
    "shard/",
    "server/",
    "fleet/",
    "replica/",
    "ingest/",
];

/// `float-reduce` is about *pinning the reduction order*; `runtime/` is
/// where `reduce_pinned` itself lives.
const FLOAT_REDUCE_EXEMPT: &[&str] = &["runtime/"];

/// Identifiers that mean "ambient entropy" — anything from the `rand`
/// crate family, the OS, or std's randomized hasher seed.
const ENTROPY_IDENTS: &[&str] = &[
    "thread_rng",
    "ThreadRng",
    "OsRng",
    "StdRng",
    "SmallRng",
    "from_entropy",
    "getrandom",
    "RandomState",
];

/// Methods that yield iteration over a hash container.
const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "into_iter", "keys", "values", "values_mut", "drain"];

fn path_in(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// One finding. `line`/`col` are 1-based; `snippet` is the trimmed
/// source line, used both for human output and baseline matching (see
/// `cigate::lint::baseline_key` — matching on content, not line
/// numbers, keeps the baseline stable under unrelated edits).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
    pub snippet: String,
}

/// Result of checking one file.
#[derive(Debug, Default)]
pub struct CheckOutcome {
    pub findings: Vec<Finding>,
    /// Findings that WOULD have fired but were suppressed by a valid
    /// `detlint: allow` — reported so `--json`/bench output can track
    /// the count of sanctioned exceptions over time.
    pub suppressed: usize,
}

struct FileCtx<'a> {
    rel: &'a str,
    src: &'a str,
    toks: Vec<Token>,
    /// Indices into `toks` of code tokens (everything but comments).
    code: Vec<usize>,
    /// Byte offset of the start of each line (index 0 = line 1).
    line_starts: Vec<usize>,
    /// `(first_line, last_line)` of `#[cfg(test)]` items, inclusive.
    test_regions: Vec<(u32, u32)>,
}

impl<'a> FileCtx<'a> {
    fn new(rel: &'a str, src: &'a str) -> Self {
        let toks = lex(src);
        let code: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment)
            })
            .map(|(i, _)| i)
            .collect();
        let mut line_starts = vec![0usize];
        for (i, b) in src.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(i + 1);
            }
        }
        let mut ctx = FileCtx {
            rel,
            src,
            toks,
            code,
            line_starts,
            test_regions: Vec::new(),
        };
        ctx.test_regions = ctx.find_test_regions();
        ctx
    }

    /// Code token at code-index `ci` (not a raw token index).
    fn ct(&self, ci: usize) -> Option<&Token> {
        self.code.get(ci).map(|&i| &self.toks[i])
    }

    fn ctext(&self, ci: usize) -> &str {
        self.ct(ci).map_or("", |t| t.text(self.src))
    }

    fn is_punct(&self, ci: usize, c: char) -> bool {
        self.ct(ci).is_some_and(|t| {
            t.kind == TokKind::Punct
                && t.end - t.start == 1
                && self.src.as_bytes()[t.start] == c as u8
        })
    }

    fn is_ident(&self, ci: usize, name: &str) -> bool {
        self.ct(ci)
            .is_some_and(|t| t.kind == TokKind::Ident && t.text(self.src) == name)
    }

    /// Full text of the (1-based) line, trimmed — the finding snippet.
    fn line_text(&self, line: u32) -> &str {
        let i = (line as usize).saturating_sub(1);
        let start = *self.line_starts.get(i).unwrap_or(&self.src.len());
        let end = self
            .line_starts
            .get(i + 1)
            .map_or(self.src.len(), |&e| e.saturating_sub(1));
        self.src[start..end.max(start)].trim()
    }

    fn in_test_region(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// Locate every `#[cfg(test)]` item and return its line extent: the
    /// attribute line through the matching close brace (or through the
    /// terminating `;` for brace-less items like a gated `use`).
    fn find_test_regions(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        let mut ci = 0usize;
        while ci + 6 < self.code.len() {
            let is_cfg_test = self.is_punct(ci, '#')
                && self.is_punct(ci + 1, '[')
                && self.is_ident(ci + 2, "cfg")
                && self.is_punct(ci + 3, '(')
                && self.is_ident(ci + 4, "test")
                && self.is_punct(ci + 5, ')')
                && self.is_punct(ci + 6, ']');
            if !is_cfg_test {
                ci += 1;
                continue;
            }
            let start_line = self.ct(ci).map_or(1, |t| t.line);
            // Scan forward for the item's opening `{`; a `;` first at
            // depth 0 means a brace-less item.
            let mut j = ci + 7;
            let mut open = None;
            let mut paren = 0i32;
            while let Some(t) = self.ct(j) {
                match t.text(self.src) {
                    "(" | "[" => paren += 1,
                    ")" | "]" => paren -= 1,
                    "{" if paren == 0 => {
                        open = Some(j);
                        break;
                    }
                    ";" if paren == 0 => break,
                    _ => {}
                }
                if j > ci + 80 {
                    break; // give up; malformed or enormous signature
                }
                j += 1;
            }
            let end_line = match open {
                Some(o) => {
                    // match braces to the close
                    let mut depth = 0i32;
                    let mut k = o;
                    let mut end = self.ct(o).map_or(start_line, |t| t.line);
                    while let Some(t) = self.ct(k) {
                        match t.text(self.src) {
                            "{" => depth += 1,
                            "}" => {
                                depth -= 1;
                                if depth == 0 {
                                    end = t.line;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                    ci = k.max(ci + 1);
                    end
                }
                None => {
                    let e = self.ct(j).map_or(start_line, |t| t.line);
                    ci = j.max(ci + 1);
                    e
                }
            };
            out.push((start_line, end_line));
        }
        out
    }
}

/// A parsed, *valid* allow annotation.
struct Allow {
    rule: String,
    /// Line the comment sits on.
    comment_line: u32,
    /// Line the allow applies to: the comment's own line if it shares
    /// it with code, else the next code-bearing line below.
    target_line: u32,
}

/// Parse `detlint: allow(<rule>) — <reason>` out of every comment.
/// The marker must be the first thing in the comment (after `//`,
/// `//!`, `///` or `/*` and whitespace) — prose *mentioning* the
/// syntax mid-sentence is not an allow.  Returns valid allows plus
/// `allow-hygiene` findings for invalid ones (empty reason / unknown
/// rule) — invalid allows suppress nothing.
fn parse_allows(ctx: &FileCtx) -> (Vec<Allow>, Vec<Finding>) {
    const MARKER: &str = "detlint: allow(";
    let mut allows = Vec::new();
    let mut findings = Vec::new();
    for t in &ctx.toks {
        if !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment) {
            continue;
        }
        let mut text = t.text(ctx.src);
        for lead in ["//!", "///", "//", "/*!", "/**", "/*"] {
            if let Some(rest) = text.strip_prefix(lead) {
                text = rest;
                break;
            }
        }
        let text = text.trim_start();
        let Some(rest) = text.strip_prefix(MARKER) else { continue };
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_string();
        let mut reason = rest[close + 1..].trim();
        // strip one leading separator: em/en dash, `--`, `-`, `:`
        for sep in ["\u{2014}", "\u{2013}", "--", "-", ":"] {
            if let Some(r) = reason.strip_prefix(sep) {
                reason = r.trim();
                break;
            }
        }
        // a block comment's close marker is not part of the reason
        let reason = reason.trim_end_matches("*/").trim();
        let bad = if !rule_exists(&rule) {
            Some(format!(
                "allow names unknown rule `{rule}` (see --list-rules); \
                 this allow suppresses nothing"
            ))
        } else if reason.is_empty() {
            Some(format!(
                "allow({rule}) has no reason; the reason is mandatory \
                 and this allow suppresses nothing"
            ))
        } else {
            None
        };
        match bad {
            Some(message) => findings.push(Finding {
                rule: RULE_ALLOW_HYGIENE,
                file: ctx.rel.to_string(),
                line: t.line,
                col: t.col,
                message,
                snippet: ctx.line_text(t.line).to_string(),
            }),
            None => {
                let target_line = allow_target_line(ctx, t);
                allows.push(Allow {
                    rule,
                    comment_line: t.line,
                    target_line,
                });
            }
        }
    }
    (allows, findings)
}

/// The line an allow comment governs: its own line if code shares it
/// (trailing comment), else the next line below that carries a code
/// token — intervening attributes/blank/comment lines are skipped.
fn allow_target_line(ctx: &FileCtx, comment: &Token) -> u32 {
    let same_line_code = ctx
        .code
        .iter()
        .any(|&i| ctx.toks[i].line == comment.line);
    if same_line_code {
        return comment.line;
    }
    ctx.code
        .iter()
        .map(|&i| ctx.toks[i].line)
        .find(|&l| l > comment.line)
        .unwrap_or(comment.line)
}

/// Check one file. `rel` must be the path relative to the scan root
/// (`src/`), with `/` separators — module allowlists prefix-match it.
pub fn check_file(rel: &str, src: &str) -> CheckOutcome {
    let ctx = FileCtx::new(rel, src);
    let (allows, mut hygiene) = parse_allows(&ctx);

    let mut raw: Vec<Finding> = Vec::new();
    wall_clock(&ctx, &mut raw);
    unordered_iter(&ctx, &mut raw);
    raw_fs(&ctx, &mut raw);
    float_reduce(&ctx, &mut raw);
    entropy(&ctx, &mut raw);
    unsafe_comment(&ctx, &mut raw);

    let mut out = CheckOutcome::default();
    for f in raw {
        if ctx.in_test_region(f.line) {
            continue; // test code may use clocks/raw fs freely
        }
        let allowed = allows.iter().any(|a| {
            a.rule == f.rule && (a.target_line == f.line || a.comment_line == f.line)
        });
        if allowed {
            out.suppressed += 1;
        } else {
            out.findings.push(f);
        }
    }
    // hygiene findings are never themselves suppressible, but test-only
    // fixtures may hold deliberately-broken allows
    hygiene.retain(|f| !ctx.in_test_region(f.line));
    out.findings.extend(hygiene);
    out.findings.sort_by(|a, b| {
        (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule))
    });
    out
}

fn push(ctx: &FileCtx, out: &mut Vec<Finding>, rule: &'static str, t: &Token, message: String) {
    out.push(Finding {
        rule,
        file: ctx.rel.to_string(),
        line: t.line,
        col: t.col,
        message,
        snippet: ctx.line_text(t.line).to_string(),
    });
}

/// Rule 1: `SystemTime::now` / `Instant::now` outside timing modules.
fn wall_clock(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if path_in(ctx.rel, WALL_CLOCK_ALLOWED) {
        return;
    }
    for ci in 0..ctx.code.len() {
        let name = ctx.ctext(ci);
        if (name == "SystemTime" || name == "Instant")
            && ctx.is_punct(ci + 1, ':')
            && ctx.is_punct(ci + 2, ':')
            && ctx.is_ident(ci + 3, "now")
        {
            let t = *ctx.ct(ci).unwrap();
            push(
                ctx,
                out,
                RULE_WALL_CLOCK,
                &t,
                format!(
                    "{name}::now() reads the wall clock; replayed state must not \
                     depend on it (allowlisted: metrics/, deltas/)"
                ),
            );
        }
    }
}

/// Rule 3: raw `fs::write` / `File::create` in erasure-critical modules.
fn raw_fs(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !path_in(ctx.rel, DURABLE_MODULES) {
        return;
    }
    for ci in 0..ctx.code.len() {
        let fire = (ctx.is_ident(ci, "fs")
            && ctx.is_punct(ci + 1, ':')
            && ctx.is_punct(ci + 2, ':')
            && ctx.is_ident(ci + 3, "write"))
            || (ctx.is_ident(ci, "File")
                && ctx.is_punct(ci + 1, ':')
                && ctx.is_punct(ci + 2, ':')
                && ctx.is_ident(ci + 3, "create"));
        if fire {
            let what = format!("{}::{}", ctx.ctext(ci), ctx.ctext(ci + 3));
            let t = *ctx.ct(ci).unwrap();
            push(
                ctx,
                out,
                RULE_RAW_FS,
                &t,
                format!(
                    "{what} bypasses write_atomic/faultfs in an erasure-critical \
                     module; crash-matrix coverage and fault injection cannot \
                     see this write"
                ),
            );
        }
    }
}

/// Rule 4: `.sum::<f32>()` or a float `fold` — the reduction order must
/// come from `runtime::reduce_pinned`, not from iterator order.
fn float_reduce(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if path_in(ctx.rel, FLOAT_REDUCE_EXEMPT) {
        return;
    }
    for ci in 0..ctx.code.len() {
        if !ctx.is_punct(ci, '.') {
            continue;
        }
        // .sum::<f32>() / .product::<f32>()
        if (ctx.is_ident(ci + 1, "sum") || ctx.is_ident(ci + 1, "product"))
            && ctx.is_punct(ci + 2, ':')
            && ctx.is_punct(ci + 3, ':')
            && ctx.is_punct(ci + 4, '<')
            && (ctx.is_ident(ci + 5, "f32") || ctx.is_ident(ci + 5, "f64"))
        {
            let t = *ctx.ct(ci + 1).unwrap();
            push(
                ctx,
                out,
                RULE_FLOAT_REDUCE,
                &t,
                format!(
                    ".{}::<{}>() pins no reduction order; route float reductions \
                     through runtime::reduce_pinned (Lemma A.3)",
                    ctx.ctext(ci + 1),
                    ctx.ctext(ci + 5),
                ),
            );
            continue;
        }
        // .fold(<float init>, ...) / .fold(f32::MIN, ...)
        if ctx.is_ident(ci + 1, "fold") && ctx.is_punct(ci + 2, '(') {
            let mut j = ci + 3;
            if ctx.is_punct(j, '-') {
                j += 1;
            }
            let float_init = ctx
                .ct(j)
                .is_some_and(|t| t.kind == TokKind::Num && num_is_float(t.text(ctx.src)))
                || ((ctx.is_ident(j, "f32") || ctx.is_ident(j, "f64"))
                    && ctx.is_punct(j + 1, ':')
                    && ctx.is_punct(j + 2, ':'));
            if float_init {
                let t = *ctx.ct(ci + 1).unwrap();
                push(
                    ctx,
                    out,
                    RULE_FLOAT_REDUCE,
                    &t,
                    ".fold with a float accumulator pins no reduction order; \
                     route float reductions through runtime::reduce_pinned \
                     (Lemma A.3)"
                        .to_string(),
                );
            }
        }
    }
}

/// Rule 5: any entropy source other than `util/rng`.
fn entropy(ctx: &FileCtx, out: &mut Vec<Finding>) {
    for ci in 0..ctx.code.len() {
        let name = ctx.ctext(ci);
        let banned_ident = ctx
            .ct(ci)
            .is_some_and(|t| t.kind == TokKind::Ident)
            && ENTROPY_IDENTS.contains(&name);
        // `rand::...` crate path (the crate is not vendored; this
        // catches a future dependency sneaking in)
        let rand_path = name == "rand"
            && ctx.ct(ci).is_some_and(|t| t.kind == TokKind::Ident)
            && ctx.is_punct(ci + 1, ':')
            && ctx.is_punct(ci + 2, ':');
        if banned_ident || rand_path {
            let t = *ctx.ct(ci).unwrap();
            push(
                ctx,
                out,
                RULE_ENTROPY,
                &t,
                format!(
                    "`{name}` is ambient entropy; all randomness must come from \
                     util/rng (philox_u64 / SplitMix64) so runs replay"
                ),
            );
        }
    }
}

/// Rule 6: every `unsafe` must carry a `// SAFETY:` comment — trailing
/// on the same line, or on a comment line directly above (attributes
/// and blank lines between the comment and the `unsafe` are fine).
fn unsafe_comment(ctx: &FileCtx, out: &mut Vec<Finding>) {
    for ci in 0..ctx.code.len() {
        if !ctx.is_ident(ci, "unsafe") {
            continue;
        }
        let t = *ctx.ct(ci).unwrap();
        if has_safety_comment(ctx, t.line) {
            continue;
        }
        push(
            ctx,
            out,
            RULE_UNSAFE_COMMENT,
            &t,
            "unsafe without a // SAFETY: comment stating the invariant the \
             caller upholds"
                .to_string(),
        );
    }
}

fn has_safety_comment(ctx: &FileCtx, unsafe_line: u32) -> bool {
    // same line (trailing comment)
    if ctx.line_text(unsafe_line).contains("SAFETY") {
        return true;
    }
    // walk upward over comment / attribute / blank lines (cap 15)
    let mut l = unsafe_line.saturating_sub(1);
    for _ in 0..15 {
        if l == 0 {
            return false;
        }
        let text = ctx.line_text(l);
        let commentish = text.starts_with("//")
            || text.starts_with("/*")
            || text.starts_with('*')
            || text.ends_with("*/");
        if commentish {
            if text.contains("SAFETY") {
                return true;
            }
            l -= 1;
        } else if text.is_empty() || text.starts_with("#[") || text.starts_with("#![")
        {
            l -= 1;
        } else {
            return false;
        }
    }
    false
}

/// Rule 2: HashMap/HashSet iteration in serialize/hash/write modules.
///
/// Three inference passes per file (scope-blind by design — a name
/// marked hash-typed anywhere in the file is hash-typed everywhere;
/// conservative over-marking can only produce a finding that an allow
/// or a `Vec`+sort refactor resolves):
///
/// 1. mark NAMES: `name: ... HashMap/HashSet` (field, param, typed
///    let) and `let [mut] name = HashMap::new()`;
/// 2. mark FNS returning hash containers (`fn f(..) -> ..HashMap..`),
///    then `let [mut] name = [self.]f(..)` marks `name` too;
/// 3. candidates: `name.iter()/keys()/values()/...` and
///    `for .. in <name> {`; a candidate is dropped when the binding it
///    feeds is sorted in the same or next statement, or when it
///    collects into a BTree container in the same statement.
fn unordered_iter(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !path_in(ctx.rel, SERIALIZE_MODULES) {
        return;
    }
    let mut hash_names: Vec<String> = Vec::new();
    let mut hash_fns: Vec<String> = Vec::new();

    let is_hash_ty = |ci: usize| ctx.is_ident(ci, "HashMap") || ctx.is_ident(ci, "HashSet");

    // Pass 1a: `name : ... HashMap/HashSet` within a short window.
    for ci in 0..ctx.code.len() {
        let t = match ctx.ct(ci) {
            Some(t) if t.kind == TokKind::Ident => t,
            _ => continue,
        };
        let name = t.text(ctx.src);
        if !ctx.is_punct(ci + 1, ':') || ctx.is_punct(ci + 2, ':') {
            continue; // not `name:` (or it's a `::` path)
        }
        for j in ci + 2..(ci + 14).min(ctx.code.len()) {
            let tx = ctx.ctext(j);
            // `,` must break the scan: in `struct S { a: u64, b: HashMap }`
            // the window from `a:` would otherwise reach `b`'s type
            if matches!(tx, ";" | "=" | "{" | "}" | ")" | ",") {
                break;
            }
            if is_hash_ty(j) {
                hash_names.push(name.to_string());
                break;
            }
        }
    }
    // Pass 1b: `let [mut] name = HashMap::new()` etc.
    for ci in 0..ctx.code.len() {
        if !ctx.is_ident(ci, "let") {
            continue;
        }
        let mut j = ci + 1;
        if ctx.is_ident(j, "mut") {
            j += 1;
        }
        let name = match ctx.ct(j) {
            Some(t) if t.kind == TokKind::Ident => t.text(ctx.src).to_string(),
            _ => continue,
        };
        if ctx.is_punct(j + 1, '=') && is_hash_ty(j + 2) {
            hash_names.push(name);
        }
    }
    // Pass 2a: fns returning hash containers.
    for ci in 0..ctx.code.len() {
        if !ctx.is_ident(ci, "fn") {
            continue;
        }
        let fname = match ctx.ct(ci + 1) {
            Some(t) if t.kind == TokKind::Ident => t.text(ctx.src).to_string(),
            _ => continue,
        };
        // scan the signature (to `{` or `;`) for an arrow then a hash ty
        let mut seen_arrow = false;
        for j in ci + 2..(ci + 60).min(ctx.code.len()) {
            let tx = ctx.ctext(j);
            if tx == "{" || tx == ";" {
                break;
            }
            if tx == "-" && ctx.is_punct(j + 1, '>') {
                seen_arrow = true;
            }
            if seen_arrow && is_hash_ty(j) {
                hash_fns.push(fname.clone());
                break;
            }
        }
    }
    // Pass 2b: `let [mut] name = [self.]f(...)` where f is a hash fn.
    for ci in 0..ctx.code.len() {
        if !ctx.is_ident(ci, "let") {
            continue;
        }
        let mut j = ci + 1;
        if ctx.is_ident(j, "mut") {
            j += 1;
        }
        let name = match ctx.ct(j) {
            Some(t) if t.kind == TokKind::Ident => t.text(ctx.src).to_string(),
            _ => continue,
        };
        if !ctx.is_punct(j + 1, '=') {
            continue;
        }
        // within the statement, look for `f(` with f in hash_fns
        for k in j + 2..(j + 20).min(ctx.code.len()) {
            let tx = ctx.ctext(k);
            if tx == ";" {
                break;
            }
            if ctx.ct(k).is_some_and(|t| t.kind == TokKind::Ident)
                && hash_fns.iter().any(|f| f == tx)
                && ctx.is_punct(k + 1, '(')
            {
                hash_names.push(name.clone());
                break;
            }
        }
    }

    hash_names.sort();
    hash_names.dedup();
    let is_hash_name =
        |ci: usize| hash_names.iter().any(|n| ctx.is_ident(ci, n));

    // Pass 3: candidates.
    let mut candidates: Vec<usize> = Vec::new(); // code indices of the name token
    for ci in 0..ctx.code.len() {
        // name.iter() / name.keys() / ...
        if is_hash_name(ci)
            && ctx.is_punct(ci + 1, '.')
            && ITER_METHODS.iter().any(|m| ctx.is_ident(ci + 2, m))
            && ctx.is_punct(ci + 3, '(')
        {
            candidates.push(ci);
        }
        // for .. in <expr ending in name> {
        if ctx.is_ident(ci, "for") {
            // find `in` within the pattern window
            let mut in_at = None;
            for j in ci + 1..(ci + 20).min(ctx.code.len()) {
                if ctx.is_ident(j, "in") {
                    in_at = Some(j);
                    break;
                }
                if matches!(ctx.ctext(j), "{" | ";") {
                    break;
                }
            }
            let Some(in_at) = in_at else { continue };
            // find the body `{` at paren/bracket depth 0
            let mut depth = 0i32;
            let mut body = None;
            for j in in_at + 1..(in_at + 40).min(ctx.code.len()) {
                match ctx.ctext(j) {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        body = Some(j);
                        break;
                    }
                    ";" if depth == 0 => break,
                    _ => {}
                }
            }
            let Some(body) = body else { continue };
            // token immediately before `{`, skipping `?`
            let mut k = body - 1;
            if ctx.is_punct(k, '?') && k > in_at {
                k -= 1;
            }
            // `&name` / `&mut name` / `self.name` all end on the name
            if k > in_at && is_hash_name(k) {
                candidates.push(k);
            }
        }
    }

    for ci in candidates {
        if sorted_after(ctx, ci) {
            continue;
        }
        let t = *ctx.ct(ci).unwrap();
        push(
            ctx,
            out,
            RULE_UNORDERED_ITER,
            &t,
            format!(
                "iteration over hash container `{}` in a serialize/hash/write \
                 module; collect + sort (or use a BTree container) before \
                 bytes depend on order",
                t.text(ctx.src),
            ),
        );
    }
}

/// Sorted-suppression for an unordered-iter candidate at code index
/// `ci`: the enclosing `let <binding> = ...;` statement is either
/// followed (within ~60 code tokens) by `<binding>.sort*`, or the
/// statement itself collects into a BTree container.
fn sorted_after(ctx: &FileCtx, ci: usize) -> bool {
    // A sort of the SAME name shortly before the iteration also pins
    // order: `retired.sort_unstable(); ... for r in retired {`
    // (common when a sorted Vec shadows a hash-typed field name).
    let name = ctx.ctext(ci).to_string();
    for j in ci.saturating_sub(60)..ci {
        if ctx.is_ident(j, &name)
            && ctx.is_punct(j + 1, '.')
            && ctx.ct(j + 2).is_some_and(|t| {
                t.kind == TokKind::Ident && t.text(ctx.src).starts_with("sort")
            })
        {
            return true;
        }
    }
    // statement end: next `;` at brace/paren depth 0 (cap 120 tokens)
    let mut depth = 0i32;
    let mut stmt_end = None;
    for j in ci..(ci + 120).min(ctx.code.len()) {
        match ctx.ctext(j) {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                depth -= 1;
                if depth < 0 {
                    break;
                }
            }
            ";" if depth == 0 => {
                stmt_end = Some(j);
                break;
            }
            _ => {}
        }
    }
    let Some(stmt_end) = stmt_end else { return false };

    // find the `let <binding>` this statement assigns, scanning back
    let mut binding = None;
    let mut stmt_start = ci;
    let mut j = ci;
    loop {
        if j == 0 {
            break;
        }
        j -= 1;
        let tx = ctx.ctext(j);
        if tx == ";" || tx == "{" || tx == "}" {
            break;
        }
        if tx == "let" {
            stmt_start = j;
            let mut k = j + 1;
            if ctx.is_ident(k, "mut") {
                k += 1;
            }
            if let Some(t) = ctx.ct(k) {
                if t.kind == TokKind::Ident {
                    binding = Some(t.text(ctx.src).to_string());
                }
            }
            break;
        }
        if ci - j > 30 {
            break;
        }
    }

    // A BTree container anywhere in the statement (type annotation or
    // collect turbofish) pins order.
    for j in stmt_start..stmt_end {
        if ctx.is_ident(j, "BTreeMap")
            || ctx.is_ident(j, "BTreeSet")
            || ctx.is_ident(j, "BinaryHeap")
        {
            return true;
        }
    }

    let Some(binding) = binding else { return false };

    // `<binding>.sort*(` within the next ~60 code tokens
    for j in stmt_end..(stmt_end + 60).min(ctx.code.len()) {
        if ctx.is_ident(j, &binding)
            && ctx.is_punct(j + 1, '.')
            && ctx
                .ct(j + 2)
                .is_some_and(|t| {
                    t.kind == TokKind::Ident && t.text(ctx.src).starts_with("sort")
                })
        {
            return true;
        }
    }
    false
}
