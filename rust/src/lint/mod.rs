//! `detlint` — in-repo determinism & durability conformance analyzer.
//!
//! Every bit-identity proof in this repo (pinned-reduce order, filtered
//! replay equality, crash-matrix recovery) rests on source-level
//! invariants: philox-only randomness, no wall clock in serialized
//! state, ordered iteration before any hash/write, durable writes
//! through `write_atomic`/faultfs.  `lint` checks those invariants
//! statically, over a classified token stream ([`lexer`]) — zero
//! dependencies, same discipline as `util/json.rs`.
//!
//! Consumers: `src/bin/detlint.rs` (the CLI, run in CI next to fmt) and
//! `cigate::lint` (the baseline gate: zero NEW findings, fixed findings
//! ratchet the baseline down).  Rules, allowlists, and the
//! `// detlint: allow(<rule>) — <reason>` policy live in [`rules`]; the
//! inventory is documented in DESIGN.md §"Determinism conformance".

pub mod lexer;
pub mod rules;

pub use rules::{check_file, CheckOutcome, Finding, RuleInfo, RULES};

use std::path::{Path, PathBuf};

/// Aggregate result of scanning a source tree.
#[derive(Debug, Default)]
pub struct ScanReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Findings suppressed by valid `detlint: allow` annotations —
    /// the count of sanctioned exceptions, tracked in bench output.
    pub suppressed: usize,
}

/// Scan every `.rs` file under `src_root` (recursively, sorted order so
/// output and baselines are deterministic).  File paths in findings are
/// relative to `src_root` with `/` separators — the rule allowlists
/// prefix-match those (`wal/`, `checkpoint/`, ...).
pub fn scan_dir(src_root: &Path) -> anyhow::Result<ScanReport> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut report = ScanReport::default();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        let rel = rel_unix(src_root, &path);
        let outcome = check_file(&rel, &src);
        report.findings.extend(outcome.findings);
        report.suppressed += outcome.suppressed;
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_unix(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}
