//! Token-level Rust lexer for `detlint` (zero-dep, same in-repo
//! discipline as `util/json.rs` — syn/proc-macro2 are not in the
//! offline vendor set).
//!
//! The lexer does NOT parse Rust; it produces a flat token stream with
//! byte spans and line/column positions that is *reliable about what is
//! code and what is not*: string literals (plain, raw, byte), char
//! literals (including `'\''` and chars containing `//`), lifetimes,
//! line comments and nested block comments are all classified, so a
//! rule matching `SystemTime :: now` can never fire on the text of a
//! string or a comment.  That classification boundary is exactly what a
//! determinism lint needs — every rule in `lint::rules` is a pattern
//! over this stream plus a module-path context, not a regex over raw
//! source.
//!
//! Positions: `line` is 1-based; `col` is the 1-based BYTE column
//! within the line (consistent for ASCII source, documented for the
//! occasional UTF-8 doc comment).  The property test in
//! `tests/detlint_rules.rs` round-trips both against a recount from
//! byte offsets on adversarial input.

/// Token classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `HashMap`, ...).
    Ident,
    /// `r#ident` raw identifier.
    RawIdent,
    /// Lifetime (`'a`, `'static`) — distinguished from char literals.
    Lifetime,
    /// Numeric literal (integer or float, suffix included).
    Num,
    /// String literal: `"..."`, `r"..."`, `r#"..."#`, `b"..."`, `br#"..."#`.
    Str,
    /// Char or byte-char literal: `'x'`, `'\''`, `b'\n'`.
    Char,
    /// `// ...` (doc comments included).
    LineComment,
    /// `/* ... */`, nesting handled.
    BlockComment,
    /// Any other single character (`:`, `{`, `.`, `#`, ...).
    Punct,
}

/// One token: kind + byte span + position.  Text is recovered from the
/// source via [`Token::text`] — tokens borrow nothing, so a file's
/// token vector outlives any slicing of the source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Token {
    pub kind: TokKind,
    /// Byte offset of the first byte of the token.
    pub start: usize,
    /// Byte offset one past the last byte.
    pub end: usize,
    /// 1-based line of `start`.
    pub line: u32,
    /// 1-based byte column of `start` within its line.
    pub col: u32,
}

impl Token {
    pub fn text<'a>(&self, src: &'a str) -> &'a str {
        &src[self.start..self.end]
    }
}

/// Lex `src` into a flat token stream.  Never fails: unterminated
/// strings/comments consume to end-of-file as a single token (the lint
/// runs on code that `rustc` may not have blessed yet, e.g. fixture
/// snippets).
pub fn lex(src: &str) -> Vec<Token> {
    Lexer {
        src: src.as_bytes(),
        pos: 0,
        line: 1,
        col: 1,
        out: Vec::new(),
    }
    .run()
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
    out: Vec<Token>,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

impl<'a> Lexer<'a> {
    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    /// Advance one byte, maintaining line/col.
    fn bump(&mut self) {
        if self.peek(0) == Some(b'\n') {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        self.pos += 1;
    }

    fn bump_n(&mut self, n: usize) {
        for _ in 0..n {
            self.bump();
        }
    }

    fn emit_from(&mut self, kind: TokKind, start: usize, line: u32, col: u32) {
        self.out.push(Token {
            kind,
            start,
            end: self.pos,
            line,
            col,
        });
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(b) = self.peek(0) {
            let (start, line, col) = (self.pos, self.line, self.col);
            match b {
                b' ' | b'\t' | b'\r' | b'\n' => self.bump(),
                b'/' if self.peek(1) == Some(b'/') => {
                    while self.peek(0).is_some_and(|c| c != b'\n') {
                        self.bump();
                    }
                    self.emit_from(TokKind::LineComment, start, line, col);
                }
                b'/' if self.peek(1) == Some(b'*') => {
                    self.block_comment();
                    self.emit_from(TokKind::BlockComment, start, line, col);
                }
                b'"' => {
                    self.string_body();
                    self.emit_from(TokKind::Str, start, line, col);
                }
                b'r' | b'b' if self.raw_or_byte_string() => {
                    self.emit_from(TokKind::Str, start, line, col);
                }
                b'b' if self.peek(1) == Some(b'\'') => {
                    self.bump(); // b
                    self.bump(); // '
                    self.char_body();
                    self.emit_from(TokKind::Char, start, line, col);
                }
                b'r' if self.peek(1) == Some(b'#')
                    && self.peek(2).is_some_and(is_ident_start) =>
                {
                    self.bump_n(2);
                    while self.peek(0).is_some_and(is_ident_continue) {
                        self.bump();
                    }
                    self.emit_from(TokKind::RawIdent, start, line, col);
                }
                b'\'' => {
                    // lifetime vs char literal
                    if self.char_not_lifetime() {
                        self.bump(); // '
                        self.char_body();
                        self.emit_from(TokKind::Char, start, line, col);
                    } else {
                        self.bump(); // '
                        while self.peek(0).is_some_and(is_ident_continue) {
                            self.bump();
                        }
                        self.emit_from(TokKind::Lifetime, start, line, col);
                    }
                }
                c if is_ident_start(c) => {
                    while self.peek(0).is_some_and(is_ident_continue) {
                        self.bump();
                    }
                    self.emit_from(TokKind::Ident, start, line, col);
                }
                c if c.is_ascii_digit() => {
                    self.number_body();
                    self.emit_from(TokKind::Num, start, line, col);
                }
                c if c < 0x80 => {
                    self.bump();
                    self.emit_from(TokKind::Punct, start, line, col);
                }
                _ => {
                    // non-ASCII outside a string/comment: consume the
                    // whole UTF-8 scalar as one Punct so spans stay on
                    // character boundaries
                    let mut n = 1;
                    while self
                        .peek(n)
                        .is_some_and(|c| (c & 0xC0) == 0x80)
                    {
                        n += 1;
                    }
                    self.bump_n(n);
                    self.emit_from(TokKind::Punct, start, line, col);
                }
            }
        }
        self.out
    }

    /// At `/*`: consume the whole comment, nesting-aware.
    fn block_comment(&mut self) {
        self.bump_n(2);
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(0), self.peek(1)) {
                (Some(b'/'), Some(b'*')) => {
                    depth += 1;
                    self.bump_n(2);
                }
                (Some(b'*'), Some(b'/')) => {
                    depth -= 1;
                    self.bump_n(2);
                }
                (Some(_), _) => self.bump(),
                (None, _) => break, // unterminated: consume to EOF
            }
        }
    }

    /// At the opening `"`: consume through the closing quote.
    fn string_body(&mut self) {
        self.bump(); // "
        loop {
            match self.peek(0) {
                None => break,
                Some(b'\\') => self.bump_n(2.min(self.src.len() - self.pos)),
                Some(b'"') => {
                    self.bump();
                    break;
                }
                Some(_) => self.bump(),
            }
        }
    }

    /// If positioned at a raw/byte string (`r"`, `r#"`, `b"`, `br#"`,
    /// `rb"` is not Rust — `br` only), consume it and return true.
    fn raw_or_byte_string(&mut self) -> bool {
        let mut ahead = 1; // past the r or b
        let first = self.peek(0);
        if first == Some(b'b') && self.peek(1) == Some(b'r') {
            ahead = 2;
        }
        let raw = first == Some(b'r') || ahead == 2;
        let mut hashes = 0usize;
        if raw {
            while self.peek(ahead) == Some(b'#') {
                hashes += 1;
                ahead += 1;
            }
        }
        if self.peek(ahead) != Some(b'"') {
            return false;
        }
        if !raw && hashes == 0 && first == Some(b'b') && ahead != 1 {
            return false;
        }
        self.bump_n(ahead + 1); // prefix + opening quote
        if raw {
            // scan for `"` followed by `hashes` hash marks, no escapes
            loop {
                match self.peek(0) {
                    None => break,
                    Some(b'"') => {
                        let mut ok = true;
                        for i in 0..hashes {
                            if self.peek(1 + i) != Some(b'#') {
                                ok = false;
                                break;
                            }
                        }
                        self.bump();
                        if ok {
                            self.bump_n(hashes);
                            break;
                        }
                    }
                    Some(_) => self.bump(),
                }
            }
        } else {
            // b"..." — escapes apply
            loop {
                match self.peek(0) {
                    None => break,
                    Some(b'\\') => {
                        self.bump_n(2.min(self.src.len() - self.pos))
                    }
                    Some(b'"') => {
                        self.bump();
                        break;
                    }
                    Some(_) => self.bump(),
                }
            }
        }
        true
    }

    /// Past the opening `'` of a char literal: consume the scalar (or
    /// escape) and the closing quote.
    fn char_body(&mut self) {
        match self.peek(0) {
            Some(b'\\') => {
                self.bump(); // backslash
                self.bump(); // escaped char ('\'' and '\\' land here)
                // \u{...} and \x.. tails
                while self
                    .peek(0)
                    .is_some_and(|c| c != b'\'' && c != b'\n')
                {
                    self.bump();
                }
            }
            Some(_) => {
                // one UTF-8 scalar
                self.bump();
                while self.peek(0).is_some_and(|c| (c & 0xC0) == 0x80) {
                    self.bump();
                }
            }
            None => return,
        }
        if self.peek(0) == Some(b'\'') {
            self.bump();
        }
    }

    /// At a `'`: decide char-literal vs lifetime without consuming.
    fn char_not_lifetime(&self) -> bool {
        match self.peek(1) {
            Some(b'\\') => true, // '\n' '\'' '\u{..}'
            Some(c) if is_ident_start(c) => {
                // 'a' is a char only if a quote closes it right after
                // one ident char; 'static / 'a (no close) are lifetimes
                self.peek(2) == Some(b'\'')
            }
            Some(_) => true, // '0', '(', multi-byte scalar, ...
            None => false,
        }
    }

    /// At a digit: integer/float literal with suffix.
    fn number_body(&mut self) {
        // integer part (covers 0x/0b/0o prefixes via the alnum sweep)
        while self
            .peek(0)
            .is_some_and(|c| c.is_ascii_alphanumeric() || c == b'_')
        {
            // exponent sign: 1.5e-3 / 2E+8
            if (self.peek(0) == Some(b'e') || self.peek(0) == Some(b'E'))
                && matches!(self.peek(1), Some(b'+') | Some(b'-'))
                && self.peek(2).is_some_and(|c| c.is_ascii_digit())
            {
                self.bump_n(2);
                continue;
            }
            self.bump();
        }
        // fraction: only when a digit follows the dot (so `0..n` and
        // `x.0.to_string()` tokenize as ranges/field accesses)
        if self.peek(0) == Some(b'.')
            && self.peek(1).is_some_and(|c| c.is_ascii_digit())
        {
            self.bump(); // .
            while self
                .peek(0)
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == b'_')
            {
                if (self.peek(0) == Some(b'e') || self.peek(0) == Some(b'E'))
                    && matches!(self.peek(1), Some(b'+') | Some(b'-'))
                    && self.peek(2).is_some_and(|c| c.is_ascii_digit())
                {
                    self.bump_n(2);
                    continue;
                }
                self.bump();
            }
        }
    }
}

/// Is this numeric literal a float? (`1.5`, `1.5e3`, `0.0f32`, `1f64`,
/// `1e9`).  Hex literals are never floats (`0xE3` contains `e`).
pub fn num_is_float(text: &str) -> bool {
    if text.starts_with("0x") || text.starts_with("0X") {
        return false;
    }
    text.contains('.')
        || text.ends_with("f32")
        || text.ends_with("f64")
        || text.contains('e')
        || text.contains('E')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .iter()
            .map(|t| (t.kind, t.text(src).to_string()))
            .collect()
    }

    #[test]
    fn classifies_strings_comments_chars_lifetimes() {
        let src = r##"let s = "a // not a comment"; // real
let r = r#"raw " with // stuff"#;
let c = '\''; let d = '/'; let lt: &'static str = "x";
/* outer /* nested */ still comment */ let z = 1.5e-3f32;"##;
        let ks = kinds(src);
        let strs: Vec<_> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(strs.len(), 3);
        assert!(strs[1].contains("raw"));
        let chars: Vec<_> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Char)
            .collect();
        assert_eq!(chars.len(), 2);
        assert!(ks
            .iter()
            .any(|(k, t)| *k == TokKind::Lifetime && t == "'static"));
        assert!(ks
            .iter()
            .any(|(k, t)| *k == TokKind::BlockComment && t.contains("nested")));
        assert!(ks
            .iter()
            .any(|(k, t)| *k == TokKind::Num && t == "1.5e-3f32"));
    }

    #[test]
    fn ranges_do_not_eat_dots() {
        let ks = kinds("for i in 0..n { a[i.0] }");
        assert!(ks.iter().any(|(k, t)| *k == TokKind::Num && t == "0"));
        assert!(!ks.iter().any(|(_, t)| t.contains("..")));
    }

    #[test]
    fn float_detection() {
        assert!(num_is_float("1.5"));
        assert!(num_is_float("0.0f32"));
        assert!(num_is_float("1e9"));
        assert!(num_is_float("2f64"));
        assert!(!num_is_float("42"));
        assert!(!num_is_float("0xE3"));
        assert!(!num_is_float("1_000"));
    }
}
