//! Deterministic pure-Rust reference executor (the default backend).
//!
//! The coordinator's exactness guarantees (G1/G3, Theorem A.1) never
//! depend on *which* model the compute graphs implement — only on the
//! graphs being pure functions of their input buffers (Assumption
//! A.13).  This module provides that contract without PJRT: a tiny
//! byte-level **bigram language model** with a fused AdamW update,
//! implemented in sequential f32 arithmetic so every graph is
//! bit-deterministic (same bits in, same bits out) across runs and
//! processes.
//!
//! Unlike a hash-based stub, the bigram model genuinely *learns* (its
//! loss decreases, it memorizes canary digit pairs), so the audit
//! harness (MIA / canary exposure / extraction / utility) measures real
//! signals and the replay-equality suite exercises real optimizer
//! trajectories.
//!
//! Graph semantics (mirrors the AOT HLO surface in `pjrt.rs`):
//! - `train_step(θ, tokens[B,S], mask[B], seed)`: summed next-token
//!   cross-entropy over the *unmasked* examples; returns (∇θ, Σloss,
//!   Σtokens).  Masked slots are **skipped entirely** — bitwise
//!   content-independence (Lemma A.2(ii)) holds by construction, which
//!   is what makes content-scrubbed replay exact.
//! - `adamw_update`: global-norm clip + AdamW with bias correction,
//!   sequential element order.
//! - `eval_loss` / `next_logits` and the `lora_*` family: the adapter
//!   is an additive per-vocab logit bias patch trained against a
//!   strictly frozen base (the G2 precondition).
//!
//! Parameter layout (flat vector, `REF_PARAM_COUNT` = V·V + V):
//! `θ[prev·V + v]` bigram logits, then `θ[V·V + v]` unigram bias.

use crate::runtime::{
    ArtifactManifest, Executor, GraphId, MicrobatchInput, StepOut,
};

/// Vocabulary (byte-level tokenizer).
pub const REF_VOCAB: usize = 256;
/// Train microbatch size.
pub const REF_BATCH: usize = 8;
/// Eval batch size.
pub const REF_EVAL_BATCH: usize = 8;
/// Sequence length.
pub const REF_SEQ_LEN: usize = 64;
/// Flat parameter count: V·V bigram table + V bias.
pub const REF_PARAM_COUNT: usize = REF_VOCAB * REF_VOCAB + REF_VOCAB;
/// LoRA patch length: additive per-vocab logit bias.
pub const REF_LORA_PARAM_COUNT: usize = REF_VOCAB;
/// Rank of the (degenerate rank-1) adapter patch.
pub const REF_LORA_RANK: usize = 1;
/// Version string pinned (hashed) into the artifact/pin set: bump on
/// ANY semantic change to the executor — it is the kernel-algorithm pin.
pub const REF_VERSION: &str =
    "reference-executor-v1:bigram256+bias;adamw(b1=0.9,b2=0.999,eps=1e-8,clip=1.0,wd=0);ce-sum";

const CLIP_NORM: f32 = 1.0;
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// The reference backend.  Stateless (all state flows through the
/// buffers), so `execute`-style purity is trivial.
#[derive(Debug, Clone)]
pub struct ReferenceExec {
    batch: usize,
    eval_batch: usize,
    seq_len: usize,
    vocab: usize,
}

impl ReferenceExec {
    /// Build for a manifest's geometry; refuses geometries the
    /// reference model cannot realize (those need the `pjrt` feature).
    pub fn new(man: &super::ArtifactManifest) -> anyhow::Result<ReferenceExec> {
        anyhow::ensure!(
            man.param_count == REF_PARAM_COUNT
                && man.lora_param_count == REF_LORA_PARAM_COUNT
                && man.vocab == REF_VOCAB,
            "manifest geometry (P={}, PL={}, V={}) is not the reference \
             executor's (P={REF_PARAM_COUNT}, PL={REF_LORA_PARAM_COUNT}, \
             V={REF_VOCAB}) — these artifacts need the `pjrt` feature",
            man.param_count,
            man.lora_param_count,
            man.vocab
        );
        Ok(ReferenceExec {
            batch: man.batch,
            eval_batch: man.eval_batch,
            seq_len: man.seq_len,
            vocab: man.vocab,
        })
    }

    /// Deterministic θ0: small random logits (ties would make rank
    /// statistics degenerate, so exact zeros are avoided).
    pub fn init_params() -> Vec<f32> {
        let mut r = crate::util::rng::SplitMix64::new(0x5EED_1217);
        (0..REF_PARAM_COUNT)
            .map(|_| r.normal() as f32 * 0.02)
            .collect()
    }

    /// Deterministic LoRA init (small, like A ~ N(0, 0.01)).
    pub fn init_lora() -> Vec<f32> {
        let mut r = crate::util::rng::SplitMix64::new(0x10_5EED);
        (0..REF_LORA_PARAM_COUNT)
            .map(|_| r.normal() as f32 * 0.01)
            .collect()
    }

    #[inline]
    fn token_at(
        &self,
        tokens: &[i32],
        slot: usize,
        pos: usize,
    ) -> anyhow::Result<usize> {
        let t = tokens[slot * self.seq_len + pos];
        anyhow::ensure!(
            (0..self.vocab as i32).contains(&t),
            "token {t} out of vocab range at slot {slot} pos {pos}"
        );
        Ok(t as usize)
    }

    /// Logits for position `pos` of `slot` into `logits` (len V):
    /// bigram row of the previous token + bias (+ optional lora patch).
    #[inline]
    fn fill_logits(
        &self,
        params: &[f32],
        lora: Option<&[f32]>,
        prev: usize,
        logits: &mut [f32],
    ) {
        let v = self.vocab;
        let row = &params[prev * v..(prev + 1) * v];
        let bias = &params[v * v..v * v + v];
        match lora {
            None => {
                for i in 0..v {
                    logits[i] = row[i] + bias[i];
                }
            }
            Some(l) => {
                for i in 0..v {
                    logits[i] = row[i] + bias[i] + l[i];
                }
            }
        }
    }

    /// Numerically stable softmax-CE at one position.  Returns
    /// (loss, max, expsum); `probs` receives exp(l - max).
    #[inline]
    fn softmax_ce(
        logits: &[f32],
        target: usize,
        probs: &mut [f32],
    ) -> (f32, f32, f32) {
        let mut mx = f32::NEG_INFINITY;
        for &l in logits {
            mx = mx.max(l);
        }
        let mut sum = 0.0f32;
        for (p, &l) in probs.iter_mut().zip(logits) {
            let e = (l - mx).exp();
            *p = e;
            sum += e;
        }
        let loss = sum.ln() + mx - logits[target];
        (loss, mx, sum)
    }

    /// Core fwd/bwd.  `grad_base` collects ∇θ (full layout) when given;
    /// `grad_lora` collects the adapter gradient when given.
    #[allow(clippy::too_many_arguments)]
    fn step_inner(
        &self,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
        mask: &[f32],
        mut grad_base: Option<&mut [f32]>,
        mut grad_lora: Option<&mut [f32]>,
    ) -> anyhow::Result<(f32, f32)> {
        let (b, s, v) = (self.batch, self.seq_len, self.vocab);
        anyhow::ensure!(tokens.len() == b * s, "tokens shape");
        anyhow::ensure!(mask.len() == b, "mask shape");
        anyhow::ensure!(params.len() == REF_PARAM_COUNT, "params shape");
        if let Some(l) = lora {
            anyhow::ensure!(l.len() == REF_LORA_PARAM_COUNT, "lora shape");
        }
        let mut logits = vec![0.0f32; v];
        let mut probs = vec![0.0f32; v];
        let mut loss_sum = 0.0f32;
        let mut tok_count = 0.0f32;
        for slot in 0..b {
            // Filtered/padded slots are skipped, not multiplied by zero:
            // their *content* provably never enters the computation.
            if mask[slot] == 0.0 {
                continue;
            }
            for pos in 1..s {
                let target = self.token_at(tokens, slot, pos)?;
                if target == 0 {
                    continue; // PAD targets carry no loss
                }
                let prev = self.token_at(tokens, slot, pos - 1)?;
                self.fill_logits(params, lora, prev, &mut logits);
                let (loss, _mx, sum) =
                    Self::softmax_ce(&logits, target, &mut probs);
                loss_sum += loss;
                tok_count += 1.0;
                if grad_base.is_none() && grad_lora.is_none() {
                    continue;
                }
                let inv = 1.0 / sum;
                if let Some(g) = grad_base.as_deref_mut() {
                    let (rows, bias) = g.split_at_mut(v * v);
                    let row = &mut rows[prev * v..(prev + 1) * v];
                    for i in 0..v {
                        let mut d = probs[i] * inv;
                        if i == target {
                            d -= 1.0;
                        }
                        row[i] += d;
                        bias[i] += d;
                    }
                }
                if let Some(g) = grad_lora.as_deref_mut() {
                    for i in 0..v {
                        let mut d = probs[i] * inv;
                        if i == target {
                            d -= 1.0;
                        }
                        g[i] += d;
                    }
                }
            }
        }
        Ok((loss_sum, tok_count))
    }

    /// g(θ; B, S) — one microbatch forward/backward (reduction=sum).
    /// `_seed` is accepted for wire compatibility; the reference model
    /// has no dropout, so the graph is trivially index-stable.
    pub fn train_step(
        &self,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        _seed: i32,
    ) -> anyhow::Result<StepOut> {
        let mut grad = vec![0.0f32; REF_PARAM_COUNT];
        let (loss_sum, tok_count) = self.step_inner(
            params,
            None,
            tokens,
            mask,
            Some(&mut grad),
            None,
        )?;
        Ok(StepOut {
            grad,
            loss_sum,
            tok_count,
        })
    }

    /// Adapter-only gradient against a strictly frozen base (G2).
    pub fn lora_step(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        _seed: i32,
    ) -> anyhow::Result<StepOut> {
        let mut grad = vec![0.0f32; REF_LORA_PARAM_COUNT];
        let (loss_sum, tok_count) = self.step_inner(
            base,
            Some(lora),
            tokens,
            mask,
            None,
            Some(&mut grad),
        )?;
        Ok(StepOut {
            grad,
            loss_sum,
            tok_count,
        })
    }

    /// Global-norm clip + AdamW with bias correction (the fused UPDATE
    /// kernel).  Sequential f32 element order — bit-deterministic.
    pub fn adamw_update(
        &self,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            params.len() == grad.len()
                && params.len() == m.len()
                && params.len() == v.len(),
            "update tensor shapes disagree"
        );
        anyhow::ensure!(step >= 1, "applied-update counter is 1-based");
        let mut sq = 0.0f32;
        for g in grad {
            sq += g * g;
        }
        let norm = sq.sqrt();
        let scale = if norm > CLIP_NORM { CLIP_NORM / norm } else { 1.0 };
        let bc1 = 1.0 - BETA1.powi(step);
        let bc2 = 1.0 - BETA2.powi(step);
        let mut p2 = Vec::with_capacity(params.len());
        let mut m2 = Vec::with_capacity(params.len());
        let mut v2 = Vec::with_capacity(params.len());
        for i in 0..params.len() {
            let g = grad[i] * scale;
            let mi = BETA1 * m[i] + (1.0 - BETA1) * g;
            let vi = BETA2 * v[i] + (1.0 - BETA2) * g * g;
            let mhat = mi / bc1;
            let vhat = vi / bc2;
            p2.push(params[i] - lr * (mhat / (vhat.sqrt() + EPS)));
            m2.push(mi);
            v2.push(vi);
        }
        Ok((p2, m2, v2))
    }

    /// Per-example (sum CE loss, predicted-token count) over the eval
    /// batch.  Empty (all-PAD) slots yield (0, 0).
    pub fn eval_loss(
        &self,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (be, s, v) = (self.eval_batch, self.seq_len, self.vocab);
        anyhow::ensure!(tokens.len() == be * s, "eval tokens shape");
        anyhow::ensure!(params.len() == REF_PARAM_COUNT, "params shape");
        if let Some(l) = lora {
            anyhow::ensure!(
                l.len() == REF_LORA_PARAM_COUNT,
                "lora patch length {} != {REF_LORA_PARAM_COUNT} — refusing \
                 (fail-closed on corrupt adapter files)",
                l.len()
            );
        }
        let mut logits = vec![0.0f32; v];
        let mut probs = vec![0.0f32; v];
        let mut losses = vec![0.0f32; be];
        let mut counts = vec![0.0f32; be];
        for slot in 0..be {
            for pos in 1..s {
                let t = tokens[slot * s + pos];
                anyhow::ensure!(
                    (0..v as i32).contains(&t),
                    "token {t} out of vocab"
                );
                if t == 0 {
                    continue;
                }
                let prev = tokens[slot * s + pos - 1];
                anyhow::ensure!(
                    (0..v as i32).contains(&prev),
                    "token {prev} out of vocab"
                );
                self.fill_logits(params, lora, prev as usize, &mut logits);
                let (loss, _, _) =
                    Self::softmax_ce(&logits, t as usize, &mut probs);
                losses[slot] += loss;
                counts[slot] += 1.0;
            }
        }
        Ok((losses, counts))
    }

    /// Next-token logits at position `lens[b]-1` for greedy decoding.
    pub fn next_logits(
        &self,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let (be, s, v) = (self.eval_batch, self.seq_len, self.vocab);
        anyhow::ensure!(
            tokens.len() == be * s && lens.len() == be,
            "next_logits shapes"
        );
        if let Some(l) = lora {
            anyhow::ensure!(
                l.len() == REF_LORA_PARAM_COUNT,
                "lora patch length {} != {REF_LORA_PARAM_COUNT} — refusing \
                 (fail-closed on corrupt adapter files)",
                l.len()
            );
        }
        let mut out = vec![0.0f32; be * v];
        for slot in 0..be {
            anyhow::ensure!(
                lens[slot] >= 1 && lens[slot] as usize <= s,
                "length {} out of range",
                lens[slot]
            );
            let last = tokens[slot * s + lens[slot] as usize - 1];
            anyhow::ensure!(
                (0..v as i32).contains(&last),
                "token {last} out of vocab"
            );
            self.fill_logits(
                params,
                lora,
                last as usize,
                &mut out[slot * v..(slot + 1) * v],
            );
        }
        Ok(out)
    }
}

/// Worker count for the segment/eval parallel overrides: the host's
/// parallelism, capped by the number of independent work items.
fn workers_for(items: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(items)
        .max(1)
}

/// Run `f(i)` for every `i < items` across a scoped thread pool
/// (work-stealing via an atomic cursor), collecting results in index
/// order.  Item order in the OUTPUT is fixed regardless of scheduling —
/// the caller's combine step sees the pinned order.
fn parallel_map<T: Send>(
    items: usize,
    f: impl Fn(usize) -> anyhow::Result<T> + Sync,
) -> anyhow::Result<Vec<T>> {
    let workers = workers_for(items);
    if workers <= 1 {
        return (0..items).map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    // one worker's error aborts the whole map: the remaining items'
    // results could never be used, so computing them is pure waste
    let abort = std::sync::atomic::AtomicBool::new(false);
    let mut slots: Vec<Option<anyhow::Result<T>>> =
        (0..items).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let abort = &abort;
                let f = &f;
                s.spawn(move || {
                    let mut out: Vec<(usize, anyhow::Result<T>)> = Vec::new();
                    loop {
                        if abort.load(std::sync::atomic::Ordering::Relaxed) {
                            break;
                        }
                        let i = next
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= items {
                            break;
                        }
                        let r = f(i);
                        if r.is_err() {
                            abort
                                .store(true, std::sync::atomic::Ordering::Relaxed);
                        }
                        let failed = r.is_err();
                        out.push((i, r));
                        if failed {
                            break; // surface the first error promptly
                        }
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h
                .join()
                .map_err(|_| anyhow::anyhow!("executor worker panicked"))?
            {
                slots[i] = Some(r);
            }
        }
        anyhow::Ok(())
    })?;
    // First error in INDEX order wins (deterministic reporting).
    // `None` slots are the unclaimed suffix left behind by the abort
    // flag; claims are monotonic, so an error is always found at an
    // earlier index than any `None`.
    let mut out = Vec::with_capacity(items);
    let mut err: Option<anyhow::Error> = None;
    for s in slots {
        match s {
            Some(Ok(t)) => {
                if err.is_none() {
                    out.push(t);
                }
            }
            Some(Err(e)) => {
                if err.is_none() {
                    err = Some(e);
                }
            }
            None => {
                if err.is_none() {
                    err = Some(anyhow::anyhow!(
                        "executor worker aborted before claiming its item"
                    ));
                }
            }
        }
    }
    match err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

impl Executor for ReferenceExec {
    fn kind(&self) -> &'static str {
        "reference"
    }

    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }

    fn train_step(
        &self,
        _man: &ArtifactManifest,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        ReferenceExec::train_step(self, params, tokens, mask, seed)
    }

    fn update(
        &self,
        _graph: GraphId,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        // base and LoRA updates share one fused AdamW kernel here; the
        // graph id only selects the artifact pin under PJRT
        ReferenceExec::adamw_update(self, params, grad, m, v, step, lr)
    }

    fn eval_loss(
        &self,
        _man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        ReferenceExec::eval_loss(self, params, lora, tokens)
    }

    fn next_logits(
        &self,
        _man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        ReferenceExec::next_logits(self, params, lora, tokens, lens)
    }

    fn lora_step(
        &self,
        _man: &ArtifactManifest,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        ReferenceExec::lora_step(self, base, lora, tokens, mask, seed)
    }

    /// Parallel override: evaluate the N chunks across a scoped thread
    /// pool.  Bit-identical to sequential chunking because each slot's
    /// loss is a pure function of that slot's tokens alone — chunk
    /// results are concatenated in index order, no cross-chunk
    /// arithmetic exists to reorder.
    fn eval_batch(
        &self,
        _man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let chunk = self.eval_batch * self.seq_len;
        anyhow::ensure!(
            chunk > 0 && tokens.len() % chunk == 0,
            "eval_batch tokens length {} is not a multiple of the \
             {chunk}-token eval chunk",
            tokens.len()
        );
        let n = tokens.len() / chunk;
        let per_chunk = parallel_map(n, |i| {
            ReferenceExec::eval_loss(
                self,
                params,
                lora,
                &tokens[i * chunk..(i + 1) * chunk],
            )
        })?;
        let mut losses = Vec::with_capacity(n * self.eval_batch);
        let mut counts = Vec::with_capacity(n * self.eval_batch);
        for (l, c) in per_chunk {
            losses.extend_from_slice(&l);
            counts.extend_from_slice(&c);
        }
        Ok((losses, counts))
    }

    /// Parallel override: compute the per-microbatch gradients across a
    /// scoped thread pool, then combine through the pinned reduce
    /// ([`crate::runtime::reduce_pinned`]) in microbatch index order —
    /// bit-identical to the logged sequential traversal no matter how
    /// the threads were scheduled.
    fn grad_accumulate(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        mbs: &[MicrobatchInput<'_>],
    ) -> anyhow::Result<StepOut> {
        let outs = parallel_map(mbs.len(), |i| {
            ReferenceExec::train_step(
                self,
                params,
                mbs[i].tokens,
                mbs[i].mask,
                mbs[i].seed,
            )
        })?;
        Ok(crate::runtime::reduce_pinned(man.param_count, &outs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactManifest;
    use crate::util::bytes::bits_equal;

    fn exec() -> ReferenceExec {
        let man = ArtifactManifest::reference(std::path::Path::new(
            "unused-artifacts-dir",
        ));
        ReferenceExec::new(&man).unwrap()
    }

    fn toy_tokens(exec: &ReferenceExec) -> (Vec<i32>, Vec<f32>) {
        let tokens: Vec<i32> = (0..REF_BATCH * REF_SEQ_LEN)
            .map(|i| (i % 97 + 1) as i32)
            .collect();
        let mask = vec![1.0f32; REF_BATCH];
        let _ = exec;
        (tokens, mask)
    }

    #[test]
    fn train_step_is_bit_deterministic() {
        let e = exec();
        let p = ReferenceExec::init_params();
        let (tokens, mask) = toy_tokens(&e);
        let a = e.train_step(&p, &tokens, &mask, 7).unwrap();
        let b = e.train_step(&p, &tokens, &mask, 7).unwrap();
        assert!(bits_equal(&a.grad, &b.grad));
        assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits());
        assert!(a.tok_count > 0.0);
    }

    #[test]
    fn masked_slot_content_never_enters_the_graph() {
        let e = exec();
        let p = ReferenceExec::init_params();
        let (mut tokens, mut mask) = toy_tokens(&e);
        mask[3] = 0.0;
        let a = e.train_step(&p, &tokens, &mask, 1).unwrap();
        // scribble arbitrary content into the masked slot
        for t in &mut tokens[3 * REF_SEQ_LEN..4 * REF_SEQ_LEN] {
            *t = 255;
        }
        let b = e.train_step(&p, &tokens, &mask, 1).unwrap();
        assert!(bits_equal(&a.grad, &b.grad), "Lemma A.2(ii)");
        assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits());
    }

    #[test]
    fn gradient_descends_the_loss() {
        let e = exec();
        let mut p = ReferenceExec::init_params();
        let (tokens, mask) = toy_tokens(&e);
        let mut m = vec![0.0f32; p.len()];
        let mut v = vec![0.0f32; p.len()];
        let l0 = e.train_step(&p, &tokens, &mask, 0).unwrap().loss_sum;
        for step in 1..=20 {
            let out = e.train_step(&p, &tokens, &mask, 0).unwrap();
            let (p2, m2, v2) = e
                .adamw_update(&p, &out.grad, &m, &v, step, 5e-2)
                .unwrap();
            p = p2;
            m = m2;
            v = v2;
        }
        let l1 = e.train_step(&p, &tokens, &mask, 0).unwrap().loss_sum;
        assert!(
            l1 < l0 * 0.9,
            "bigram model must actually learn: {l0} -> {l1}"
        );
    }

    #[test]
    fn eval_matches_train_loss_semantics() {
        let e = exec();
        let p = ReferenceExec::init_params();
        let (tokens, mask) = toy_tokens(&e);
        let t = e.train_step(&p, &tokens, &mask, 0).unwrap();
        let (losses, counts) = e.eval_loss(&p, None, &tokens).unwrap();
        let sum: f32 = losses.iter().sum();
        let cnt: f32 = counts.iter().sum();
        assert!((sum - t.loss_sum).abs() < 1e-3 * sum.abs().max(1.0));
        assert_eq!(cnt, t.tok_count);
    }

    #[test]
    fn lora_patch_shifts_logits_additively() {
        let e = exec();
        let p = ReferenceExec::init_params();
        let tokens: Vec<i32> = (0..REF_EVAL_BATCH * REF_SEQ_LEN)
            .map(|i| (i % 31 + 1) as i32)
            .collect();
        let lens = vec![REF_SEQ_LEN as i32; REF_EVAL_BATCH];
        let base = e.next_logits(&p, None, &tokens, &lens).unwrap();
        let mut lora = vec![0.0f32; REF_LORA_PARAM_COUNT];
        lora[5] = 3.0;
        let patched = e
            .next_logits(&p, Some(&lora), &tokens, &lens)
            .unwrap();
        for slot in 0..REF_EVAL_BATCH {
            for i in 0..REF_VOCAB {
                let d = patched[slot * REF_VOCAB + i] - base[slot * REF_VOCAB + i];
                if i == 5 {
                    assert!((d - 3.0).abs() < 1e-6);
                } else {
                    assert_eq!(d, 0.0);
                }
            }
        }
    }

    #[test]
    fn rejects_wrong_length_lora_instead_of_panicking() {
        // a truncated-but-4-aligned cohort-*.lora file must surface as
        // Err at the executor boundary, never an index panic
        let e = exec();
        let p = ReferenceExec::init_params();
        let tokens: Vec<i32> = (0..REF_EVAL_BATCH * REF_SEQ_LEN)
            .map(|i| (i % 31 + 1) as i32)
            .collect();
        let lens = vec![REF_SEQ_LEN as i32; REF_EVAL_BATCH];
        let short = vec![0.0f32; REF_LORA_PARAM_COUNT / 8];
        assert!(e.eval_loss(&p, Some(&short), &tokens).is_err());
        assert!(e.next_logits(&p, Some(&short), &tokens, &lens).is_err());
        let (mask, train_tokens) = (
            vec![1.0f32; REF_BATCH],
            (0..REF_BATCH * REF_SEQ_LEN)
                .map(|i| (i % 31 + 1) as i32)
                .collect::<Vec<i32>>(),
        );
        assert!(e.lora_step(&p, &short, &train_tokens, &mask, 0).is_err());
    }

    #[test]
    fn rejects_out_of_vocab_tokens() {
        let e = exec();
        let p = ReferenceExec::init_params();
        let (mut tokens, mask) = toy_tokens(&e);
        tokens[10] = 999;
        assert!(e.train_step(&p, &tokens, &mask, 0).is_err());
    }
}
