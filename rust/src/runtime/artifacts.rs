//! Parsed view of `artifacts/manifest.json` (written by `aot.py`).
//!
//! Carries the model geometry the coordinator needs (param counts, batch
//! shapes) plus the artifact SHA-256 pins and the deterministic initial
//! parameter vectors.

use std::path::{Path, PathBuf};

use crate::util::bytes::bytes_to_f32s;
use crate::util::hashing::{sha256_hex, sha256_file};
use crate::util::json::{parse, Json};

/// Model geometry + artifact pins from the AOT manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub param_count: usize,
    pub lora_param_count: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub dropout: f64,
    pub lora_rank: usize,
    /// (artifact name, sha256), sorted by name — Table 2 pins.
    pub artifact_hashes: Vec<(String, String)>,
    /// SHA-256 over the canonical encoding of the model config object.
    pub config_hash: String,
    pub tokenizer_checksum: String,
    /// Named-tensor layout of the flat parameter vector.
    pub layout: Vec<(String, Vec<usize>, usize)>,
    /// True when this manifest describes the built-in reference
    /// executor (no files on disk; init vectors are derived, and the
    /// "artifact hash" pin is the executor version hash).
    pub synthetic: bool,
}

impl ArtifactManifest {
    /// The synthetic manifest of the pure-Rust reference executor: a
    /// constant, so pins captured at train time match pins captured at
    /// replay time on any host (fail-closed contract preserved).
    pub fn reference(dir: &Path) -> ArtifactManifest {
        use crate::runtime::reference as rf;
        let v = rf::REF_VOCAB;
        let descriptor = format!(
            "{};P={};PL={};B={};EB={};S={};V={}",
            rf::REF_VERSION,
            rf::REF_PARAM_COUNT,
            rf::REF_LORA_PARAM_COUNT,
            rf::REF_BATCH,
            rf::REF_EVAL_BATCH,
            rf::REF_SEQ_LEN,
            v,
        );
        ArtifactManifest {
            dir: dir.to_path_buf(),
            param_count: rf::REF_PARAM_COUNT,
            lora_param_count: rf::REF_LORA_PARAM_COUNT,
            batch: rf::REF_BATCH,
            eval_batch: rf::REF_EVAL_BATCH,
            seq_len: rf::REF_SEQ_LEN,
            vocab: v,
            dropout: 0.0,
            lora_rank: rf::REF_LORA_RANK,
            artifact_hashes: vec![(
                "reference_executor".to_string(),
                sha256_hex(rf::REF_VERSION.as_bytes()),
            )],
            config_hash: sha256_hex(descriptor.as_bytes()),
            tokenizer_checksum:
                crate::data::tokenizer::ByteTokenizer::checksum(),
            layout: vec![
                ("bigram".to_string(), vec![v, v], 0),
                ("bias".to_string(), vec![v], v * v),
            ],
            synthetic: true,
        }
    }
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}) — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let cfg = j
            .get("config")
            .ok_or_else(|| anyhow::anyhow!("manifest missing config"))?;
        let u = |k: &str| -> anyhow::Result<usize> {
            cfg.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("config missing {k}"))
        };
        let mut artifact_hashes = Vec::new();
        if let Some(arts) = j.get("artifacts").and_then(|v| v.as_obj()) {
            for (name, meta) in arts {
                if let Some(h) = meta.get("sha256").and_then(|v| v.as_str()) {
                    artifact_hashes.push((name.clone(), h.to_string()));
                }
            }
        }
        artifact_hashes.sort();
        let mut layout = Vec::new();
        if let Some(items) = cfg.get("layout").and_then(|v| v.as_arr()) {
            for item in items {
                let name = item
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string();
                let shape: Vec<usize> = item
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .map(|a| {
                        a.iter().filter_map(|x| x.as_usize()).collect()
                    })
                    .unwrap_or_default();
                let offset = item
                    .get("offset")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0);
                layout.push((name, shape, offset));
            }
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            param_count: u("param_count")?,
            lora_param_count: u("lora_param_count")?,
            batch: u("batch")?,
            eval_batch: u("eval_batch")?,
            seq_len: u("seq_len")?,
            vocab: u("vocab")?,
            dropout: cfg.get("dropout").and_then(|v| v.as_f64()).unwrap_or(0.0),
            lora_rank: u("lora_rank")?,
            artifact_hashes,
            config_hash: sha256_hex(cfg.encode().as_bytes()),
            tokenizer_checksum: j
                .get("tokenizer_checksum")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            layout,
            synthetic: false,
        })
    }

    /// Verify every artifact file still matches its manifest SHA-256
    /// (part of the fail-closed pin check).  The synthetic reference
    /// manifest has no files — its pin is the executor version hash.
    pub fn verify_files(&self) -> anyhow::Result<()> {
        if self.synthetic {
            return Ok(());
        }
        for (name, expect) in &self.artifact_hashes {
            let file = if name.ends_with(".bin") {
                self.dir.join(name)
            } else {
                self.dir.join(format!("{name}.hlo.txt"))
            };
            let got = sha256_file(&file)?;
            anyhow::ensure!(
                &got == expect,
                "artifact {name} drifted: manifest {expect}, file {got}"
            );
        }
        Ok(())
    }

    /// θ0: the deterministic initialization — exported by aot.py for
    /// real artifacts, derived from a pinned seed for the reference
    /// executor (identical across processes and hosts either way).
    pub fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        if self.synthetic {
            return Ok(crate::runtime::reference::ReferenceExec::init_params());
        }
        let v = bytes_to_f32s(&std::fs::read(self.dir.join("init_params.bin"))?)?;
        anyhow::ensure!(v.len() == self.param_count, "init_params length");
        Ok(v)
    }

    /// LoRA initialization (A ~ N(0, 0.01), B = 0).
    pub fn init_lora(&self) -> anyhow::Result<Vec<f32>> {
        if self.synthetic {
            return Ok(crate::runtime::reference::ReferenceExec::init_lora());
        }
        let v = bytes_to_f32s(&std::fs::read(self.dir.join("init_lora.bin"))?)?;
        anyhow::ensure!(v.len() == self.lora_param_count, "init_lora length");
        Ok(v)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("param_count", self.param_count)
            .set("lora_param_count", self.lora_param_count)
            .set("batch", self.batch)
            .set("eval_batch", self.eval_batch)
            .set("seq_len", self.seq_len)
            .set("vocab", self.vocab)
            .set("config_hash", self.config_hash.as_str())
            .set("tokenizer_checksum", self.tokenizer_checksum.as_str());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Only runs when artifacts have been built (`make artifacts`).
    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let Some(dir) = artifacts_dir() else { return };
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.param_count > 0);
        assert!(m.batch > 0 && m.seq_len > 0);
        assert!(!m.artifact_hashes.is_empty());
        assert_eq!(m.tokenizer_checksum,
                   crate::data::tokenizer::ByteTokenizer::checksum());
        let p0 = m.init_params().unwrap();
        assert_eq!(p0.len(), m.param_count);
        m.verify_files().unwrap();
        // layout covers the whole flat vector contiguously
        let mut end = 0usize;
        for (_, shape, off) in &m.layout {
            assert_eq!(*off, end);
            end += shape.iter().product::<usize>();
        }
        assert_eq!(end, m.param_count);
    }
}
