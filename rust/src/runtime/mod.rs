//! Runtime: the open multi-backend executor API every compute graph the
//! coordinator calls goes through.
//!
//! The compute layer is a public [`Executor`] **trait** (object-safe,
//! `Send + Sync`) rather than a closed enum: any backend that provides
//! the typed graphs ([`GraphId`]) as pure functions of their input
//! buffers (Assumption A.13) can serve train/replay/oracle.  Shipped
//! backends:
//!
//! - **reference** (default): a deterministic pure-Rust executor
//!   ([`reference::ReferenceExec`]) — a tiny bigram LM with a fused
//!   AdamW update, bit-deterministic by construction.  Keeps tier-1
//!   (`cargo build --release && cargo test -q`) hermetic: no PJRT, no
//!   AOT artifacts required.  Overrides the batch entry points with
//!   scoped-thread parallel implementations.
//! - **pjrt** (feature `pjrt`): [`pjrt::PjrtExec`], the AOT HLO
//!   artifacts produced by `make artifacts` executed on a PJRT CPU
//!   client.  The trait impl always compiles (CI checks the feature
//!   matrix); the actual xla-rs client is additionally gated behind the
//!   `pjrt-xla` feature because the crate is not vendored — without it
//!   `PjrtExec::load` fails closed with instructions.
//!
//! Every loaded runtime carries an [`ExecutorFingerprint`] — backend
//! kind + platform + the per-graph artifact hashes — which flows into
//! [`crate::config::Pins`] via [`Runtime::capture_pins`].  A replay
//! against pins captured under a different backend (reference vs PJRT)
//! fails closed in `Pins::ensure_match`: mixed-backend replays are
//! refused, which is what makes "train/replay/oracle share one pinned
//! executor" (§5, Table 2) mechanically checkable.
//!
//! ## Batch-first entry points
//!
//! Two contracts exist specifically so upper layers can batch:
//!
//! - [`Executor::eval_batch`]: one call evaluates N concatenated eval
//!   chunks.  Per-slot losses are independent of chunk composition
//!   (each slot's loss is a pure function of that slot's tokens), so
//!   batched evaluation is bit-transparent w.r.t. per-chunk
//!   [`Executor::eval_loss`] calls — the audit layer and the coalesced
//!   forget probes batch through this.
//! - [`Executor::grad_accumulate`]: one call runs a whole gradient
//!   accumulation segment and combines the microbatch gradients with
//!   the **pinned reduce** ([`reduce_pinned`]) — the left-comb tree
//!   (((0+g₀)+g₁)+…)+gₙ₋₁ in microbatch index order, the exact
//!   summation order the trainer logs (Lemma A.3).  The reduce shape is
//!   a function of the segment length alone, never of thread
//!   scheduling, which is the order contract that legalizes
//!   segment-parallel replay: backends may compute the per-microbatch
//!   gradients concurrently, but the combine replays the logged
//!   sequential order bit-for-bit.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;

pub use artifacts::ArtifactManifest;

use std::path::Path;

use crate::config::Pins;

/// Typed handle for every compute graph a backend must provide — the
/// closed set of AOT artifacts (`GraphId::ALL`), replacing the stringly
/// graph names the PJRT loader and the metrics keys used to share.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GraphId {
    TrainStep,
    AdamwUpdate,
    EvalLoss,
    NextLogits,
    LoraStep,
    LoraAdamw,
    LoraEval,
    LoraNextLogits,
}

impl GraphId {
    /// Every AOT graph, in artifact order.
    pub const ALL: [GraphId; 8] = [
        GraphId::TrainStep,
        GraphId::AdamwUpdate,
        GraphId::EvalLoss,
        GraphId::NextLogits,
        GraphId::LoraStep,
        GraphId::LoraAdamw,
        GraphId::LoraEval,
        GraphId::LoraNextLogits,
    ];

    /// Artifact/manifest name of the graph.
    pub fn as_str(&self) -> &'static str {
        match self {
            GraphId::TrainStep => "train_step",
            GraphId::AdamwUpdate => "adamw_update",
            GraphId::EvalLoss => "eval_loss",
            GraphId::NextLogits => "next_logits",
            GraphId::LoraStep => "lora_step",
            GraphId::LoraAdamw => "lora_adamw",
            GraphId::LoraEval => "lora_eval",
            GraphId::LoraNextLogits => "lora_next_logits",
        }
    }

    /// Metrics timer key of the graph.
    pub fn metric(&self) -> &'static str {
        match self {
            GraphId::TrainStep => "exec.train_step",
            GraphId::AdamwUpdate => "exec.adamw_update",
            GraphId::EvalLoss => "exec.eval_loss",
            GraphId::NextLogits => "exec.next_logits",
            GraphId::LoraStep => "exec.lora_step",
            GraphId::LoraAdamw => "exec.lora_adamw",
            GraphId::LoraEval => "exec.lora_eval",
            GraphId::LoraNextLogits => "exec.lora_next_logits",
        }
    }
}

/// The identity of a loaded executor: what [`Pins`] pins about the
/// compute layer.  Two runtimes interoperate on one WAL only when their
/// fingerprints match exactly — `Pins::ensure_match` refuses anything
/// else (mixed-backend replays fail closed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecutorFingerprint {
    /// Backend discriminator ("reference" / "pjrt").
    pub kind: String,
    /// Hardware platform pin (e.g. "reference-cpu", "cpu").
    pub platform: String,
    /// (artifact name, sha256), sorted by name: the HLO hashes for
    /// pjrt, the executor version hash for the reference backend.
    pub artifact_hashes: Vec<(String, String)>,
}

impl ExecutorFingerprint {
    /// One hex digest over the whole fingerprint (manifest/log lines).
    pub fn digest(&self) -> String {
        let mut enc = format!("{};{}", self.kind, self.platform);
        for (name, hash) in &self.artifact_hashes {
            enc.push_str(&format!(";{name}={hash}"));
        }
        crate::util::hashing::sha256_hex(enc.as_bytes())
    }
}

/// Output of one train-step microbatch call (and of a combined
/// [`Executor::grad_accumulate`] segment).
#[derive(Debug, Clone)]
pub struct StepOut {
    pub grad: Vec<f32>,
    pub loss_sum: f32,
    pub tok_count: f32,
}

/// One microbatch's input tensors for the batched segment entry points.
#[derive(Debug, Clone, Copy)]
pub struct MicrobatchInput<'a> {
    /// Row-major `[batch, seq_len]` token tensor.
    pub tokens: &'a [i32],
    /// Per-example mask (0.0 = filtered slot).
    pub mask: &'a [f32],
    /// WAL seed64 truncated to the graph's i32 input.
    pub seed: i32,
}

/// The pinned reduce: fold the per-microbatch outputs into an
/// accumulator initialized to zero, in microbatch **index order** — the
/// left-comb tree (((0+g₀)+g₁)+…)+gₙ₋₁, elementwise sequential f32
/// adds.  This is byte-for-byte the summation order the trainer logs
/// per accumulation segment (Lemma A.3), so any schedule that computes
/// the `outs` concurrently and then combines through this function is
/// bit-identical to the logged sequential traversal.  The shape depends
/// only on `outs.len()`; it is pinned by the `reduction = "sum"` pin.
pub fn reduce_pinned(param_count: usize, outs: &[StepOut]) -> StepOut {
    let mut grad = vec![0.0f32; param_count];
    let mut loss_sum = 0.0f32;
    let mut tok_count = 0.0f32;
    for o in outs {
        crate::trainer::accumulate(&mut grad, &o.grad);
        loss_sum += o.loss_sum;
        tok_count += o.tok_count;
    }
    StepOut {
        grad,
        loss_sum,
        tok_count,
    }
}

/// A compute backend: every graph as a pure function of its input
/// buffers (same bits in, same bits out — Assumption A.13).  Object
/// safe, so the runtime is open: `Runtime::with_backend` accepts any
/// implementation, and the shipped reference/PJRT backends are just two
/// instances.  `Send + Sync` because the admin server and the
/// segment-parallel replay share one executor across threads; backends
/// whose native handles are not thread-safe must serialize internally.
pub trait Executor: Send + Sync {
    /// Backend discriminator — becomes the `executor_kind` pin.
    fn kind(&self) -> &'static str;

    /// Platform name (the Table 2 hardware pin).
    fn platform(&self) -> String;

    /// g(θ; B, S): one microbatch forward/backward (reduction=sum).
    fn train_step(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut>;

    /// UPDATE: global-norm clip + fused AdamW (`graph` selects the base
    /// or LoRA variant — same math, different artifact pin).
    fn update(
        &self,
        graph: GraphId,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;

    /// Per-example eval loss over ONE eval chunk; `lora` applies the
    /// adapter patch against a strictly frozen base.
    fn eval_loss(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;

    /// Next-token logits at position `lens[b]-1` (greedy decoding).
    fn next_logits(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>>;

    /// LoRA microbatch step: gradient w.r.t. the adapter only (base
    /// strictly frozen — the G2 precondition).
    fn lora_step(
        &self,
        man: &ArtifactManifest,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut>;

    /// Batched eval: `tokens` is N concatenated `[eval_batch, seq_len]`
    /// chunks; returns the concatenated per-example (loss, count)
    /// vectors.  Contract: bit-identical to N separate
    /// [`Executor::eval_loss`] calls — each slot's loss is a pure
    /// function of that slot's tokens alone, so backends may evaluate
    /// the chunks in any order or concurrently.  Default: sequential
    /// chunking (always correct).
    fn eval_batch(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let chunk = man.eval_batch * man.seq_len;
        anyhow::ensure!(
            chunk > 0 && tokens.len() % chunk == 0,
            "eval_batch tokens length {} is not a multiple of the \
             {}-token eval chunk",
            tokens.len(),
            chunk
        );
        let mut losses = Vec::with_capacity(tokens.len() / chunk * man.eval_batch);
        let mut counts = Vec::with_capacity(losses.capacity());
        for c in tokens.chunks(chunk) {
            let (l, n) = self.eval_loss(man, params, lora, c)?;
            losses.extend_from_slice(&l);
            counts.extend_from_slice(&n);
        }
        Ok((losses, counts))
    }

    /// One gradient-accumulation segment: run every microbatch against
    /// the SAME `params` and combine through [`reduce_pinned`].
    /// Contract: bit-identical to calling [`Executor::train_step`] per
    /// microbatch in index order and accumulating sequentially — the
    /// pinned reduce IS that order, so backends are free to compute the
    /// per-microbatch gradients concurrently.  Default: sequential
    /// (always correct; the reference backend overrides with a scoped
    /// thread pool).
    fn grad_accumulate(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        mbs: &[MicrobatchInput<'_>],
    ) -> anyhow::Result<StepOut> {
        let mut outs = Vec::with_capacity(mbs.len());
        for mb in mbs {
            outs.push(self.train_step(man, params, mb.tokens, mb.mask, mb.seed)?);
        }
        Ok(reduce_pinned(man.param_count, &outs))
    }
}

/// Loaded executor + manifest metadata + metrics, behind the stable
/// facade the rest of the crate calls.
pub struct Runtime {
    backend: Box<dyn Executor>,
    pub manifest: ArtifactManifest,
    /// Metrics hook (execution counts/timings).
    pub metrics: crate::metrics::Metrics,
}

impl Runtime {
    /// Load a runtime for `dir`.
    ///
    /// With the `pjrt` feature: parses `manifest.json` and loads the
    /// PJRT backend.  Without it: uses the reference executor — if a
    /// `manifest.json` is present its geometry must match the reference
    /// model's, otherwise the synthetic reference manifest is used (no
    /// files needed).
    pub fn load(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = if dir.join("manifest.json").exists() {
            ArtifactManifest::load(dir)?
        } else {
            ArtifactManifest::reference(dir)
        };
        #[cfg(feature = "pjrt")]
        let backend: Box<dyn Executor> =
            Box::new(pjrt::PjrtExec::load(dir, &manifest)?);
        #[cfg(not(feature = "pjrt"))]
        let backend: Box<dyn Executor> =
            Box::new(reference::ReferenceExec::new(&manifest)?);
        Ok(Runtime::with_backend(backend, manifest))
    }

    /// Assemble a runtime over ANY [`Executor`] implementation — the
    /// open end of the API (tests inject fault/fake backends; embedders
    /// bring their own compute layer).  The backend's fingerprint flows
    /// into every pin captured from this runtime.
    pub fn with_backend(
        backend: Box<dyn Executor>,
        manifest: ArtifactManifest,
    ) -> Runtime {
        Runtime {
            backend,
            manifest,
            metrics: crate::metrics::Metrics::new(),
        }
    }

    /// Platform name (the Table 2 hardware pin).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// The loaded executor's identity: backend kind + platform + the
    /// per-graph artifact hashes.
    pub fn fingerprint(&self) -> ExecutorFingerprint {
        ExecutorFingerprint {
            kind: self.backend.kind().to_string(),
            platform: self.backend.platform(),
            artifact_hashes: self.manifest.artifact_hashes.clone(),
        }
    }

    /// Capture the current environment pins (compare against the stored
    /// training-time pins before any replay — fail-closed on drift).
    pub fn capture_pins(&self, accum: usize) -> Pins {
        let fp = self.fingerprint();
        Pins {
            executor_kind: fp.kind,
            // the runtime is topology-blind ("" = unsharded capture);
            // sharded callers overwrite this with the fleet topology pin
            // (the trainer from RunConfig::shard_pin, replay from
            // ReplayOptions::shard_pin) before any comparison
            shard: String::new(),
            artifact_hashes: fp.artifact_hashes,
            model_config_hash: self.manifest.config_hash.clone(),
            tokenizer_checksum: self.manifest.tokenizer_checksum.clone(),
            param_count: self.manifest.param_count,
            accum,
            batch: self.manifest.batch,
            layout: "single-host;dp=1;tp=1;pp=1".to_string(),
            reduction: "sum".to_string(),
            platform: fp.platform,
        }
    }

    /// g(θ; B, S): one microbatch forward/backward (reduction=sum).
    ///
    /// `tokens` is row-major `[batch, seq_len]`, `mask` is per-example
    /// (0.0 = filtered slot — Lemma A.2(ii) masking), `seed` is the WAL
    /// seed64 truncated to the graph's i32 input.
    pub fn train_step(
        &self,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let man = &self.manifest;
        let (b, s) = (man.batch, man.seq_len);
        anyhow::ensure!(tokens.len() == b * s, "tokens shape");
        anyhow::ensure!(mask.len() == b, "mask shape");
        anyhow::ensure!(params.len() == man.param_count, "params");
        self.metrics.time(GraphId::TrainStep.metric(), || {
            self.backend.train_step(man, params, tokens, mask, seed)
        })
    }

    /// One gradient-accumulation segment through the backend's batched
    /// entry point (see [`Executor::grad_accumulate`] for the pinned
    /// reduce-order contract).
    pub fn grad_accumulate(
        &self,
        params: &[f32],
        mbs: &[MicrobatchInput<'_>],
    ) -> anyhow::Result<StepOut> {
        let man = &self.manifest;
        let (b, s) = (man.batch, man.seq_len);
        anyhow::ensure!(!mbs.is_empty(), "empty accumulation segment");
        anyhow::ensure!(params.len() == man.param_count, "params");
        for (i, mb) in mbs.iter().enumerate() {
            anyhow::ensure!(
                mb.tokens.len() == b * s && mb.mask.len() == b,
                "microbatch {i} tensor shapes"
            );
        }
        // per-microbatch counter alongside the per-segment timer so the
        // planner can derive an amortized per-record replay cost
        self.metrics
            .inc("exec.grad_accumulate.microbatches", mbs.len() as u64);
        self.metrics.time("exec.grad_accumulate", || {
            self.backend.grad_accumulate(man, params, mbs)
        })
    }

    /// UPDATE: global-norm clip + fused-AdamW (the Pallas L1 kernel).
    /// `step` is the 1-based applied-update counter.
    pub fn adamw_update(
        &self,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.metrics.time(GraphId::AdamwUpdate.metric(), || {
            self.backend
                .update(GraphId::AdamwUpdate, params, grad, m, v, step, lr)
        })
    }

    /// AdamW over the LoRA parameter vector (adapter training).
    pub fn lora_adamw(
        &self,
        lora: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.metrics.time(GraphId::LoraAdamw.metric(), || {
            self.backend
                .update(GraphId::LoraAdamw, lora, grad, m, v, step, lr)
        })
    }

    /// Per-example eval loss: (loss_sum[eval_batch], count[eval_batch]).
    pub fn eval_loss(
        &self,
        params: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let man = &self.manifest;
        anyhow::ensure!(
            tokens.len() == man.eval_batch * man.seq_len,
            "eval tokens shape"
        );
        self.metrics.time(GraphId::EvalLoss.metric(), || {
            self.backend.eval_loss(man, params, None, tokens)
        })
    }

    /// Batched eval over N concatenated eval chunks — ONE executor call
    /// for what used to be N `eval_loss`/`lora_eval` round trips, bit-
    /// identical to them (see [`Executor::eval_batch`]).
    pub fn eval_batch(
        &self,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let man = &self.manifest;
        let chunk = man.eval_batch * man.seq_len;
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() % chunk == 0,
            "eval_batch tokens length {} not a positive multiple of {chunk}",
            tokens.len()
        );
        self.metrics
            .inc("exec.eval_batch.chunks", (tokens.len() / chunk) as u64);
        self.metrics.time("exec.eval_batch", || {
            self.backend.eval_batch(man, params, lora, tokens)
        })
    }

    /// Next-token logits at position `lens[b]-1` (greedy decoding).
    pub fn next_logits(
        &self,
        params: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let man = &self.manifest;
        anyhow::ensure!(
            tokens.len() == man.eval_batch * man.seq_len
                && lens.len() == man.eval_batch
        );
        self.metrics.time(GraphId::NextLogits.metric(), || {
            self.backend.next_logits(man, params, None, tokens, lens)
        })
    }

    /// LoRA microbatch step: gradient w.r.t. the adapter only (base
    /// strictly frozen — the G2 precondition is enforced in the graph).
    pub fn lora_step(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        self.metrics.time(GraphId::LoraStep.metric(), || {
            self.backend
                .lora_step(&self.manifest, base, lora, tokens, mask, seed)
        })
    }

    /// Eval loss with an adapter patch applied (serving-path audits).
    pub fn lora_eval(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.metrics.time(GraphId::LoraEval.metric(), || {
            self.backend
                .eval_loss(&self.manifest, base, Some(lora), tokens)
        })
    }

    /// Next-token logits with an adapter patch applied.
    pub fn lora_next_logits(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        self.metrics.time(GraphId::LoraNextLogits.metric(), || {
            self.backend
                .next_logits(&self.manifest, base, Some(lora), tokens, lens)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::bits_equal;

    #[test]
    fn loads_reference_runtime_without_artifacts() {
        let dir = crate::util::tempdir("rt-ref");
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.platform(), "reference-cpu");
        assert_eq!(rt.manifest.param_count, reference::REF_PARAM_COUNT);
        let fp = rt.fingerprint();
        assert_eq!(fp.kind, "reference");
        assert!(!fp.digest().is_empty());
        let pins = rt.capture_pins(2);
        assert_eq!(pins.reduction, "sum");
        assert_eq!(pins.executor_kind, "reference");
        // the executor version is pinned like an artifact hash
        assert!(pins
            .artifact_hashes
            .iter()
            .any(|(n, _)| n == "reference_executor"));
        // pins are stable across loads (replay fail-closed contract)
        let rt2 = Runtime::load(&dir).unwrap();
        assert!(pins.ensure_match(&rt2.capture_pins(2)).is_ok());
    }

    #[test]
    fn runtime_train_step_records_metrics() {
        let dir = crate::util::tempdir("rt-metrics");
        let rt = Runtime::load(&dir).unwrap();
        let man = &rt.manifest;
        let params = man.init_params().unwrap();
        let tokens: Vec<i32> = (0..man.batch * man.seq_len)
            .map(|i| (i % 251 + 1) as i32)
            .collect();
        let mask = vec![1.0f32; man.batch];
        let out = rt.train_step(&params, &tokens, &mask, 7).unwrap();
        assert_eq!(out.grad.len(), man.param_count);
        let (n, _, _) = rt.metrics.timer("exec.train_step").unwrap();
        assert_eq!(n, 1);
    }

    #[test]
    fn graph_ids_cover_the_artifact_set() {
        let names: Vec<&str> =
            GraphId::ALL.iter().map(|g| g.as_str()).collect();
        assert_eq!(names.len(), 8);
        for g in GraphId::ALL {
            assert!(g.metric().starts_with("exec."));
            assert!(g.metric().ends_with(g.as_str()));
        }
    }

    /// A fake backend proving the trait is object-safe and the runtime
    /// open: foreign `Executor` impls load through `with_backend` and
    /// their fingerprint flows into the pins.
    struct FakePjrt;

    impl Executor for FakePjrt {
        fn kind(&self) -> &'static str {
            "pjrt"
        }
        fn platform(&self) -> String {
            "cpu".into()
        }
        fn train_step(
            &self,
            _man: &ArtifactManifest,
            params: &[f32],
            _tokens: &[i32],
            _mask: &[f32],
            _seed: i32,
        ) -> anyhow::Result<StepOut> {
            Ok(StepOut {
                grad: vec![0.0; params.len()],
                loss_sum: 0.0,
                tok_count: 0.0,
            })
        }
        fn update(
            &self,
            _graph: GraphId,
            params: &[f32],
            _grad: &[f32],
            m: &[f32],
            v: &[f32],
            _step: i32,
            _lr: f32,
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            Ok((params.to_vec(), m.to_vec(), v.to_vec()))
        }
        fn eval_loss(
            &self,
            man: &ArtifactManifest,
            _params: &[f32],
            _lora: Option<&[f32]>,
            _tokens: &[i32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            Ok((vec![0.0; man.eval_batch], vec![0.0; man.eval_batch]))
        }
        fn next_logits(
            &self,
            man: &ArtifactManifest,
            _params: &[f32],
            _lora: Option<&[f32]>,
            _tokens: &[i32],
            _lens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            Ok(vec![0.0; man.eval_batch * man.vocab])
        }
        fn lora_step(
            &self,
            man: &ArtifactManifest,
            _base: &[f32],
            _lora: &[f32],
            _tokens: &[i32],
            _mask: &[f32],
            _seed: i32,
        ) -> anyhow::Result<StepOut> {
            Ok(StepOut {
                grad: vec![0.0; man.lora_param_count],
                loss_sum: 0.0,
                tok_count: 0.0,
            })
        }
    }

    #[test]
    fn mixed_backend_pins_refuse_to_interoperate() {
        // reference pins vs synthetic PJRT pins: the fingerprint flows
        // into Pins and ensure_match fails closed on the mix — a replay
        // can never silently run on a different backend than trained.
        let dir = crate::util::tempdir("rt-mixed");
        let ref_rt = Runtime::load(&dir).unwrap();
        let mut pjrt_man = ArtifactManifest::reference(&dir);
        pjrt_man.artifact_hashes = GraphId::ALL
            .iter()
            .map(|g| (g.as_str().to_string(), format!("hlo-{}", g.as_str())))
            .collect();
        let pjrt_rt = Runtime::with_backend(Box::new(FakePjrt), pjrt_man);
        let ref_pins = ref_rt.capture_pins(2);
        let pjrt_pins = pjrt_rt.capture_pins(2);
        assert_eq!(pjrt_pins.executor_kind, "pjrt");
        let err = ref_pins.ensure_match(&pjrt_pins).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pin drift"), "{msg}");
        // and in the other direction too
        assert!(pjrt_pins.ensure_match(&ref_pins).is_err());
        // fingerprints differ structurally as well
        assert_ne!(
            ref_rt.fingerprint().digest(),
            pjrt_rt.fingerprint().digest()
        );
    }

    fn toy_segment(
        man: &ArtifactManifest,
        rng: &mut crate::util::rng::SplitMix64,
        n: usize,
    ) -> Vec<(Vec<i32>, Vec<f32>, i32)> {
        (0..n)
            .map(|_| {
                let tokens: Vec<i32> = (0..man.batch * man.seq_len)
                    .map(|_| (rng.below(man.vocab as u64)) as i32)
                    .collect();
                let mask: Vec<f32> = (0..man.batch)
                    .map(|_| if rng.below(4) == 0 { 0.0 } else { 1.0 })
                    .collect();
                (tokens, mask, rng.below(1 << 31) as i32)
            })
            .collect()
    }

    #[test]
    fn grad_accumulate_is_bit_identical_to_sequential_accumulation() {
        // The reduce-order pin (satellite): across segment sizes
        // 1..=16, the batched (possibly parallel) segment entry point
        // must be bit-identical to the logged sequential traversal —
        // one train_step per microbatch, accumulated in index order.
        let dir = crate::util::tempdir("rt-reduce-pin");
        let rt = Runtime::load(&dir).unwrap();
        let man = rt.manifest.clone();
        crate::util::prop::for_all("reduce-order pin", |rng| {
            let n = (rng.below(16) + 1) as usize;
            let params = crate::util::prop::f32_vec(
                rng,
                man.param_count,
                0.05,
            );
            let seg = toy_segment(&man, rng, n);
            let inputs: Vec<MicrobatchInput<'_>> = seg
                .iter()
                .map(|(t, m, s)| MicrobatchInput {
                    tokens: t,
                    mask: m,
                    seed: *s,
                })
                .collect();
            // sequential reference order: fold from zeros, index order
            let mut grad = vec![0.0f32; man.param_count];
            let mut loss_sum = 0.0f32;
            let mut tok_count = 0.0f32;
            for mb in &inputs {
                let out = rt
                    .train_step(&params, mb.tokens, mb.mask, mb.seed)
                    .unwrap();
                crate::trainer::accumulate(&mut grad, &out.grad);
                loss_sum += out.loss_sum;
                tok_count += out.tok_count;
            }
            let batched = rt.grad_accumulate(&params, &inputs).unwrap();
            assert!(
                bits_equal(&batched.grad, &grad),
                "segment of {n}: tree-reduce drifted from the logged \
                 sequential order"
            );
            assert_eq!(batched.loss_sum.to_bits(), loss_sum.to_bits());
            assert_eq!(batched.tok_count.to_bits(), tok_count.to_bits());
        });
    }

    #[test]
    fn reduce_pinned_matches_explicit_left_fold_on_adversarial_bits() {
        // the combine itself, on raw bit patterns (NaN, -0.0, inf):
        // reduce_pinned must BE the left fold, not merely close to it
        crate::util::prop::for_all("reduce_pinned left fold", |rng| {
            let n = (rng.below(16) + 1) as usize;
            let p = 64usize;
            let outs: Vec<StepOut> = (0..n)
                .map(|_| StepOut {
                    grad: crate::util::prop::f32_vec_adversarial(rng, p),
                    loss_sum: rng.normal() as f32,
                    tok_count: rng.below(512) as f32,
                })
                .collect();
            let mut grad = vec![0.0f32; p];
            let mut loss = 0.0f32;
            for o in &outs {
                for (a, g) in grad.iter_mut().zip(&o.grad) {
                    *a += g;
                }
                loss += o.loss_sum;
            }
            let red = reduce_pinned(p, &outs);
            assert!(bits_equal(&red.grad, &grad));
            assert_eq!(red.loss_sum.to_bits(), loss.to_bits());
        });
    }

    #[test]
    fn eval_batch_is_bit_identical_to_per_chunk_eval_loss() {
        let dir = crate::util::tempdir("rt-eval-batch");
        let rt = Runtime::load(&dir).unwrap();
        let man = &rt.manifest;
        let params = man.init_params().unwrap();
        let chunk = man.eval_batch * man.seq_len;
        let n_chunks = 5usize;
        let tokens: Vec<i32> = (0..n_chunks * chunk)
            .map(|i| (i % 231 + 1) as i32)
            .collect();
        let (bl, bc) = rt.eval_batch(&params, None, &tokens).unwrap();
        assert_eq!(bl.len(), n_chunks * man.eval_batch);
        let mut sl = Vec::new();
        let mut sc = Vec::new();
        for c in tokens.chunks(chunk) {
            let (l, n) = rt.eval_loss(&params, c).unwrap();
            sl.extend_from_slice(&l);
            sc.extend_from_slice(&n);
        }
        assert!(bits_equal(&bl, &sl), "batched eval drifted per-chunk eval");
        assert!(bits_equal(&bc, &sc));
        // shape errors fail closed
        assert!(rt.eval_batch(&params, None, &tokens[..chunk - 1]).is_err());
        assert!(rt.eval_batch(&params, None, &[]).is_err());
    }
}
