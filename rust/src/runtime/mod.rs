//! PJRT runtime: loads the AOT HLO-text artifacts and exposes typed
//! wrappers for every compute graph the coordinator calls.
//!
//! Python never runs here — `make artifacts` already lowered the JAX/
//! Pallas programs to `artifacts/*.hlo.txt`; this module parses the HLO
//! text (`HloModuleProto::from_text_file`), compiles once per graph on
//! the PJRT CPU client, and executes from the hot path.
//!
//! Determinism note (Assumption A.13): a compiled PJRT executable is a
//! pure function of its input buffers — same bits in, same bits out.
//! All exactness guarantees downstream lean on this plus the fact that
//! train/replay/oracle all use the *same* executables (pinned by
//! SHA-256 in [`crate::config::Pins`]).

pub mod artifacts;

pub use artifacts::ArtifactManifest;

use std::collections::HashMap;
use std::path::Path;

use crate::config::Pins;

/// Compiled executables + manifest metadata.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    execs: HashMap<&'static str, xla::PjRtLoadedExecutable>,
    /// Metrics hook (execution counts/timings).
    pub metrics: crate::metrics::Metrics,
}

/// Output of one train-step microbatch call.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub grad: Vec<f32>,
    pub loss_sum: f32,
    pub tok_count: f32,
}

const GRAPHS: &[&str] = &[
    "train_step",
    "adamw_update",
    "eval_loss",
    "next_logits",
    "lora_step",
    "lora_adamw",
    "lora_eval",
    "lora_next_logits",
];

impl Runtime {
    /// Load the artifact directory and compile every graph.
    pub fn load(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;
        let mut execs = HashMap::new();
        for &name in GRAPHS {
            let path = dir.join(format!("{name}.hlo.txt"));
            anyhow::ensure!(
                path.exists(),
                "missing artifact {} — run `make artifacts`",
                path.display()
            );
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().unwrap(),
            )
            .map_err(|e| anyhow::anyhow!("parse {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            execs.insert(name, exe);
        }
        Ok(Runtime {
            client,
            manifest,
            execs,
            metrics: crate::metrics::Metrics::new(),
        })
    }

    /// PJRT platform name (the Table 2 hardware pin).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Capture the current environment pins (compare against the stored
    /// training-time pins before any replay — fail-closed on drift).
    pub fn capture_pins(&self, accum: usize) -> Pins {
        Pins {
            artifact_hashes: self.manifest.artifact_hashes.clone(),
            model_config_hash: self.manifest.config_hash.clone(),
            tokenizer_checksum: self.manifest.tokenizer_checksum.clone(),
            param_count: self.manifest.param_count,
            accum,
            batch: self.manifest.batch,
            layout: "single-host;dp=1;tp=1;pp=1".to_string(),
            reduction: "sum".to_string(),
            platform: self.platform(),
        }
    }

    fn run(
        &self,
        name: &'static str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown graph {name}"))?;
        let out = self.metrics.time(&format!("exec.{name}"), || {
            exe.execute::<xla::Literal>(inputs)
        });
        let result = out.map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    fn f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("literal to f32: {e:?}"))
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let l = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(l);
        }
        l.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    fn lit_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let l = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(l);
        }
        l.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    /// g(θ; B, S): one microbatch forward/backward (reduction=sum).
    ///
    /// `tokens` is row-major `[batch, seq_len]`, `mask` is per-example
    /// (0.0 = filtered slot — Lemma A.2(ii) masking), `seed` is the WAL
    /// seed64 truncated to the graph's i32 input.
    pub fn train_step(
        &self,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let (b, s) = (self.manifest.batch, self.manifest.seq_len);
        anyhow::ensure!(tokens.len() == b * s, "tokens shape");
        anyhow::ensure!(mask.len() == b, "mask shape");
        anyhow::ensure!(params.len() == self.manifest.param_count, "params");
        let out = self.run(
            "train_step",
            &[
                Self::lit_f32(params, &[params.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_f32(mask, &[b as i64])?,
                xla::Literal::scalar(seed),
            ],
        )?;
        anyhow::ensure!(out.len() == 3, "train_step arity");
        Ok(StepOut {
            grad: Self::f32_vec(&out[0])?,
            loss_sum: Self::f32_vec(&out[1])?[0],
            tok_count: Self::f32_vec(&out[2])?[0],
        })
    }

    /// UPDATE: global-norm clip + fused-AdamW (the Pallas L1 kernel).
    /// `step` is the 1-based applied-update counter.
    pub fn adamw_update(
        &self,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.update_inner("adamw_update", params, grad, m, v, step, lr)
    }

    /// AdamW over the LoRA parameter vector (adapter training).
    pub fn lora_adamw(
        &self,
        lora: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.update_inner("lora_adamw", lora, grad, m, v, step, lr)
    }

    fn update_inner(
        &self,
        graph: &'static str,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let n = params.len() as i64;
        let out = self.run(
            graph,
            &[
                Self::lit_f32(params, &[n])?,
                Self::lit_f32(grad, &[n])?,
                Self::lit_f32(m, &[n])?,
                Self::lit_f32(v, &[n])?,
                xla::Literal::scalar(step),
                xla::Literal::scalar(lr),
            ],
        )?;
        anyhow::ensure!(out.len() == 3, "{graph} arity");
        Ok((
            Self::f32_vec(&out[0])?,
            Self::f32_vec(&out[1])?,
            Self::f32_vec(&out[2])?,
        ))
    }

    /// Per-example eval loss: (loss_sum[eval_batch], count[eval_batch]).
    pub fn eval_loss(
        &self,
        params: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (b, s) = (self.manifest.eval_batch, self.manifest.seq_len);
        anyhow::ensure!(tokens.len() == b * s, "eval tokens shape");
        let out = self.run(
            "eval_loss",
            &[
                Self::lit_f32(params, &[params.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
            ],
        )?;
        Ok((Self::f32_vec(&out[0])?, Self::f32_vec(&out[1])?))
    }

    /// Next-token logits at position `lens[b]-1` (greedy decoding).
    pub fn next_logits(
        &self,
        params: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let (b, s) = (self.manifest.eval_batch, self.manifest.seq_len);
        anyhow::ensure!(tokens.len() == b * s && lens.len() == b);
        let out = self.run(
            "next_logits",
            &[
                Self::lit_f32(params, &[params.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_i32(lens, &[b as i64])?,
            ],
        )?;
        Self::f32_vec(&out[0])
    }

    /// LoRA microbatch step: gradient w.r.t. the adapter only (base
    /// strictly frozen — the G2 precondition is enforced in the graph).
    pub fn lora_step(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let (b, s) = (self.manifest.batch, self.manifest.seq_len);
        let out = self.run(
            "lora_step",
            &[
                Self::lit_f32(base, &[base.len() as i64])?,
                Self::lit_f32(lora, &[lora.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_f32(mask, &[b as i64])?,
                xla::Literal::scalar(seed),
            ],
        )?;
        Ok(StepOut {
            grad: Self::f32_vec(&out[0])?,
            loss_sum: Self::f32_vec(&out[1])?[0],
            tok_count: Self::f32_vec(&out[2])?[0],
        })
    }

    /// Eval loss with an adapter patch applied (serving-path audits).
    pub fn lora_eval(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (b, s) = (self.manifest.eval_batch, self.manifest.seq_len);
        let out = self.run(
            "lora_eval",
            &[
                Self::lit_f32(base, &[base.len() as i64])?,
                Self::lit_f32(lora, &[lora.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
            ],
        )?;
        Ok((Self::f32_vec(&out[0])?, Self::f32_vec(&out[1])?))
    }

    /// Next-token logits with an adapter patch applied.
    pub fn lora_next_logits(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let (b, s) = (self.manifest.eval_batch, self.manifest.seq_len);
        let out = self.run(
            "lora_next_logits",
            &[
                Self::lit_f32(base, &[base.len() as i64])?,
                Self::lit_f32(lora, &[lora.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_i32(lens, &[b as i64])?,
            ],
        )?;
        Self::f32_vec(&out[0])
    }
}
