//! Runtime: typed wrappers for every compute graph the coordinator
//! calls, over one of two interchangeable backends.
//!
//! - **reference** (default): a deterministic pure-Rust executor
//!   ([`reference::ReferenceExec`]) — a tiny bigram LM with a fused
//!   AdamW update, bit-deterministic by construction.  Keeps tier-1
//!   (`cargo build --release && cargo test -q`) hermetic: no PJRT, no
//!   AOT artifacts required.
//! - **pjrt** (feature `pjrt`): the AOT HLO artifacts produced by
//!   `make artifacts`, compiled once per graph on the `xla` crate's
//!   PJRT CPU client — Python never runs on the request path.
//!
//! Determinism note (Assumption A.13): both backends are pure functions
//! of their input buffers — same bits in, same bits out.  All exactness
//! guarantees downstream lean on this plus the fact that train/replay/
//! oracle all use the *same* executor (pinned by hash in
//! [`crate::config::Pins`]: the HLO SHA-256s for pjrt, the
//! [`reference::REF_VERSION`] hash for the reference executor).

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;

pub use artifacts::ArtifactManifest;

use std::path::Path;

use crate::config::Pins;

enum Backend {
    Reference(reference::ReferenceExec),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// Compiled/loaded executor + manifest metadata.
pub struct Runtime {
    backend: Backend,
    pub manifest: ArtifactManifest,
    /// Metrics hook (execution counts/timings).
    pub metrics: crate::metrics::Metrics,
}

/// Output of one train-step microbatch call.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub grad: Vec<f32>,
    pub loss_sum: f32,
    pub tok_count: f32,
}

impl Runtime {
    /// Load a runtime for `dir`.
    ///
    /// With the `pjrt` feature: parses `manifest.json` and compiles the
    /// HLO artifacts.  Without it: uses the reference executor — if a
    /// `manifest.json` is present its geometry must match the reference
    /// model's, otherwise the synthetic reference manifest is used (no
    /// files needed).
    pub fn load(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = if dir.join("manifest.json").exists() {
            ArtifactManifest::load(dir)?
        } else {
            ArtifactManifest::reference(dir)
        };
        #[cfg(feature = "pjrt")]
        {
            let backend = pjrt::PjrtBackend::load(dir, &manifest)?;
            Ok(Runtime {
                backend: Backend::Pjrt(backend),
                manifest,
                metrics: crate::metrics::Metrics::new(),
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let exec = reference::ReferenceExec::new(&manifest)?;
            Ok(Runtime {
                backend: Backend::Reference(exec),
                manifest,
                metrics: crate::metrics::Metrics::new(),
            })
        }
    }

    /// Platform name (the Table 2 hardware pin).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Reference(_) => "reference-cpu".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.platform(),
        }
    }

    /// Capture the current environment pins (compare against the stored
    /// training-time pins before any replay — fail-closed on drift).
    pub fn capture_pins(&self, accum: usize) -> Pins {
        Pins {
            artifact_hashes: self.manifest.artifact_hashes.clone(),
            model_config_hash: self.manifest.config_hash.clone(),
            tokenizer_checksum: self.manifest.tokenizer_checksum.clone(),
            param_count: self.manifest.param_count,
            accum,
            batch: self.manifest.batch,
            layout: "single-host;dp=1;tp=1;pp=1".to_string(),
            reduction: "sum".to_string(),
            platform: self.platform(),
        }
    }

    /// g(θ; B, S): one microbatch forward/backward (reduction=sum).
    ///
    /// `tokens` is row-major `[batch, seq_len]`, `mask` is per-example
    /// (0.0 = filtered slot — Lemma A.2(ii) masking), `seed` is the WAL
    /// seed64 truncated to the graph's i32 input.
    pub fn train_step(
        &self,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let man = &self.manifest;
        let (b, s) = (man.batch, man.seq_len);
        anyhow::ensure!(tokens.len() == b * s, "tokens shape");
        anyhow::ensure!(mask.len() == b, "mask shape");
        anyhow::ensure!(params.len() == man.param_count, "params");
        self.metrics.time("exec.train_step", || match &self.backend {
            Backend::Reference(e) => e.train_step(params, tokens, mask, seed),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.train_step(man, params, tokens, mask, seed),
        })
    }

    /// UPDATE: global-norm clip + fused-AdamW (the Pallas L1 kernel).
    /// `step` is the 1-based applied-update counter.
    pub fn adamw_update(
        &self,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.metrics.time("exec.adamw_update", || match &self.backend {
            Backend::Reference(e) => e.adamw_update(params, grad, m, v, step, lr),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                p.update("adamw_update", params, grad, m, v, step, lr)
            }
        })
    }

    /// AdamW over the LoRA parameter vector (adapter training).
    pub fn lora_adamw(
        &self,
        lora: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.metrics.time("exec.lora_adamw", || match &self.backend {
            Backend::Reference(e) => e.adamw_update(lora, grad, m, v, step, lr),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.update("lora_adamw", lora, grad, m, v, step, lr),
        })
    }

    /// Per-example eval loss: (loss_sum[eval_batch], count[eval_batch]).
    pub fn eval_loss(
        &self,
        params: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let man = &self.manifest;
        anyhow::ensure!(
            tokens.len() == man.eval_batch * man.seq_len,
            "eval tokens shape"
        );
        self.metrics.time("exec.eval_loss", || match &self.backend {
            Backend::Reference(e) => e.eval_loss(params, None, tokens),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.eval_loss(man, params, tokens),
        })
    }

    /// Next-token logits at position `lens[b]-1` (greedy decoding).
    pub fn next_logits(
        &self,
        params: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let man = &self.manifest;
        anyhow::ensure!(
            tokens.len() == man.eval_batch * man.seq_len
                && lens.len() == man.eval_batch
        );
        self.metrics.time("exec.next_logits", || match &self.backend {
            Backend::Reference(e) => e.next_logits(params, None, tokens, lens),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.next_logits(man, params, tokens, lens),
        })
    }

    /// LoRA microbatch step: gradient w.r.t. the adapter only (base
    /// strictly frozen — the G2 precondition is enforced in the graph).
    pub fn lora_step(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        self.metrics.time("exec.lora_step", || match &self.backend {
            Backend::Reference(e) => e.lora_step(base, lora, tokens, mask, seed),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                p.lora_step(&self.manifest, base, lora, tokens, mask, seed)
            }
        })
    }

    /// Eval loss with an adapter patch applied (serving-path audits).
    pub fn lora_eval(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.metrics.time("exec.lora_eval", || match &self.backend {
            Backend::Reference(e) => e.eval_loss(base, Some(lora), tokens),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.lora_eval(&self.manifest, base, lora, tokens),
        })
    }

    /// Next-token logits with an adapter patch applied.
    pub fn lora_next_logits(
        &self,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        self.metrics
            .time("exec.lora_next_logits", || match &self.backend {
                Backend::Reference(e) => {
                    e.next_logits(base, Some(lora), tokens, lens)
                }
                #[cfg(feature = "pjrt")]
                Backend::Pjrt(p) => {
                    p.lora_next_logits(&self.manifest, base, lora, tokens, lens)
                }
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_reference_runtime_without_artifacts() {
        let dir = crate::util::tempdir("rt-ref");
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.platform(), "reference-cpu");
        assert_eq!(rt.manifest.param_count, reference::REF_PARAM_COUNT);
        let pins = rt.capture_pins(2);
        assert_eq!(pins.reduction, "sum");
        // the executor version is pinned like an artifact hash
        assert!(pins
            .artifact_hashes
            .iter()
            .any(|(n, _)| n == "reference_executor"));
        // pins are stable across loads (replay fail-closed contract)
        let rt2 = Runtime::load(&dir).unwrap();
        assert!(pins.ensure_match(&rt2.capture_pins(2)).is_ok());
    }

    #[test]
    fn runtime_train_step_records_metrics() {
        let dir = crate::util::tempdir("rt-metrics");
        let rt = Runtime::load(&dir).unwrap();
        let man = &rt.manifest;
        let params = man.init_params().unwrap();
        let tokens: Vec<i32> = (0..man.batch * man.seq_len)
            .map(|i| (i % 251 + 1) as i32)
            .collect();
        let mask = vec![1.0f32; man.batch];
        let out = rt.train_step(&params, &tokens, &mask, 7).unwrap();
        assert_eq!(out.grad.len(), man.param_count);
        let (n, _, _) = rt.metrics.timer("exec.train_step").unwrap();
        assert_eq!(n, 1);
    }
}
