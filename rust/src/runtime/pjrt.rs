//! PJRT backend (feature `pjrt`): the AOT HLO-text artifacts produced
//! by `make artifacts`, executed through a PJRT CPU client.
//!
//! Python never runs here — `make artifacts` already lowered the JAX/
//! Pallas programs to `artifacts/*.hlo.txt`; the client parses the HLO
//! text, compiles once per [`GraphId`], and executes from the hot path.
//!
//! Two layers of gating keep the feature matrix honest:
//!
//! - `pjrt` alone compiles [`PjrtExec`] and its [`Executor`] impl —
//!   this is what CI's feature-matrix `cargo check` verifies (the trait
//!   must stay object-safe under both backends) — but `load` fails
//!   closed at runtime: the `xla` crate (xla-rs) is not on crates.io
//!   and is not part of the pinned dependency set.
//! - `pjrt-xla` (requires vendoring xla-rs as a path/git dependency in
//!   `Cargo.toml` first) additionally compiles the real client.  The
//!   xla-rs handles are not thread-safe, so every call is serialized
//!   through one mutex — the `Executor: Send + Sync` contract is met by
//!   construction, at the cost of no intra-backend parallelism (the
//!   batch entry points fall back to the sequential defaults, which the
//!   pinned reduce makes bit-identical anyway).

use super::{ArtifactManifest, Executor, GraphId, StepOut};
use std::path::Path;

/// The PJRT-backed executor.  Without the `pjrt-xla` feature this is a
/// typed placeholder whose `load` refuses with instructions — the trait
/// surface (and therefore the whole coordinator) still compiles, which
/// is the point: enabling the real client is a dependency change, not
/// an API change.
pub struct PjrtExec {
    #[cfg(feature = "pjrt-xla")]
    client: std::sync::Mutex<client::PjrtClient>,
    platform: String,
}

// SAFETY CAVEAT (pjrt-xla): the mutex serializes every client CALL,
// which covers data races — but `Send` additionally permits the client
// to be dropped (and `serve` to run it) on a different thread than the
// one that created it.  Whoever vendors xla-rs MUST verify the PJRT
// CPU client is not thread-affine before shipping this; if it is,
// replace the mutex with a dedicated executor thread owning the client
// (calls over a channel) and delete these impls.  Nothing in CI
// compiles this path today — the assertion is documented, not tested.
#[cfg(feature = "pjrt-xla")]
// SAFETY: all client access is serialized through the mutex; cross-thread
// drop/use is the vendor-time obligation in the caveat above.
unsafe impl Send for PjrtExec {}
#[cfg(feature = "pjrt-xla")]
// SAFETY: &PjrtExec only exposes the client via Mutex::lock, so shared
// references never race; same vendor-time obligation as Send.
unsafe impl Sync for PjrtExec {}

impl PjrtExec {
    /// Load the artifact directory and compile every graph.
    #[cfg_attr(not(feature = "pjrt-xla"), allow(unused_variables))]
    pub fn load(
        dir: &Path,
        manifest: &ArtifactManifest,
    ) -> anyhow::Result<PjrtExec> {
        anyhow::ensure!(
            !manifest.synthetic,
            "the pjrt backend needs real AOT artifacts — run `make artifacts`"
        );
        #[cfg(not(feature = "pjrt-xla"))]
        {
            anyhow::bail!(
                "the pjrt backend compiled without its client: the `xla` \
                 crate (xla-rs) is not vendored in this image.  Add it as \
                 a path/git dependency and build with `--features \
                 pjrt-xla` (see DESIGN.md \"Execution backends\"), or use \
                 the default reference backend"
            );
        }
        #[cfg(feature = "pjrt-xla")]
        {
            let c = client::PjrtClient::load(dir)?;
            let platform = c.platform_name();
            Ok(PjrtExec {
                client: std::sync::Mutex::new(c),
                platform,
            })
        }
    }

    #[cfg(not(feature = "pjrt-xla"))]
    fn unavailable(&self) -> anyhow::Error {
        anyhow::anyhow!(
            "pjrt executor unavailable (built without `pjrt-xla`) — \
             PjrtExec::load cannot have succeeded; this is a bug"
        )
    }

    #[cfg(feature = "pjrt-xla")]
    fn with_client<T>(
        &self,
        f: impl FnOnce(&client::PjrtClient) -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        let g = self
            .client
            .lock()
            .map_err(|_| anyhow::anyhow!("pjrt client mutex poisoned"))?;
        f(&g)
    }
}

impl Executor for PjrtExec {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.platform.clone()
    }

    #[cfg_attr(not(feature = "pjrt-xla"), allow(unused_variables))]
    fn train_step(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        #[cfg(not(feature = "pjrt-xla"))]
        return Err(self.unavailable());
        #[cfg(feature = "pjrt-xla")]
        self.with_client(|c| c.train_step(man, params, tokens, mask, seed))
    }

    #[cfg_attr(not(feature = "pjrt-xla"), allow(unused_variables))]
    fn update(
        &self,
        graph: GraphId,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        #[cfg(not(feature = "pjrt-xla"))]
        return Err(self.unavailable());
        #[cfg(feature = "pjrt-xla")]
        self.with_client(|c| c.update(graph, params, grad, m, v, step, lr))
    }

    #[cfg_attr(not(feature = "pjrt-xla"), allow(unused_variables))]
    fn eval_loss(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        #[cfg(not(feature = "pjrt-xla"))]
        return Err(self.unavailable());
        #[cfg(feature = "pjrt-xla")]
        self.with_client(|c| c.eval_loss(man, params, lora, tokens))
    }

    #[cfg_attr(not(feature = "pjrt-xla"), allow(unused_variables))]
    fn next_logits(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        lora: Option<&[f32]>,
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        #[cfg(not(feature = "pjrt-xla"))]
        return Err(self.unavailable());
        #[cfg(feature = "pjrt-xla")]
        self.with_client(|c| c.next_logits(man, params, lora, tokens, lens))
    }

    #[cfg_attr(not(feature = "pjrt-xla"), allow(unused_variables))]
    fn lora_step(
        &self,
        man: &ArtifactManifest,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        #[cfg(not(feature = "pjrt-xla"))]
        return Err(self.unavailable());
        #[cfg(feature = "pjrt-xla")]
        self.with_client(|c| {
            c.lora_step(man, base, lora, tokens, mask, seed)
        })
    }
    // eval_batch / grad_accumulate: the sequential trait defaults.  The
    // mutex-serialized client cannot overlap graph executions, and the
    // pinned reduce makes the sequential order the canonical one.
}

/// The actual xla-rs client.  Compiled only with `pjrt-xla` (the crate
/// is not vendored); kept verbatim so wiring the dependency back in is
/// a Cargo.toml change.
#[cfg(feature = "pjrt-xla")]
mod client {
    use super::super::{ArtifactManifest, GraphId, StepOut};
    use std::collections::HashMap;
    use std::path::Path;

    pub struct PjrtClient {
        client: xla::PjRtClient,
        execs: HashMap<&'static str, xla::PjRtLoadedExecutable>,
    }

    impl PjrtClient {
        pub fn load(dir: &Path) -> anyhow::Result<PjrtClient> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;
            let mut execs = HashMap::new();
            for g in GraphId::ALL {
                let name = g.as_str();
                let path = dir.join(format!("{name}.hlo.txt"));
                anyhow::ensure!(
                    path.exists(),
                    "missing artifact {} — run `make artifacts`",
                    path.display()
                );
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().unwrap(),
                )
                .map_err(|e| anyhow::anyhow!("parse {name}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
                execs.insert(name, exe);
            }
            Ok(PjrtClient { client, execs })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        fn run(
            &self,
            name: &'static str,
            inputs: &[xla::Literal],
        ) -> anyhow::Result<Vec<xla::Literal>> {
            let exe = self
                .execs
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown graph {name}"))?;
            let out = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
            lit.to_tuple()
                .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
        }

        fn f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
            lit.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal to f32: {e:?}"))
        }

        fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
            let l = xla::Literal::vec1(data);
            if dims.len() == 1 {
                return Ok(l);
            }
            l.reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
        }

        fn lit_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
            let l = xla::Literal::vec1(data);
            if dims.len() == 1 {
                return Ok(l);
            }
            l.reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
        }

        fn step_out(
            out: Vec<xla::Literal>,
            graph: &str,
        ) -> anyhow::Result<StepOut> {
            anyhow::ensure!(out.len() == 3, "{graph} arity");
            Ok(StepOut {
                grad: Self::f32_vec(&out[0])?,
                loss_sum: Self::f32_vec(&out[1])?[0],
                tok_count: Self::f32_vec(&out[2])?[0],
            })
        }

        pub fn train_step(
            &self,
            man: &ArtifactManifest,
            params: &[f32],
            tokens: &[i32],
            mask: &[f32],
            seed: i32,
        ) -> anyhow::Result<StepOut> {
            let (b, s) = (man.batch, man.seq_len);
            let out = self.run(
                GraphId::TrainStep.as_str(),
                &[
                    Self::lit_f32(params, &[params.len() as i64])?,
                    Self::lit_i32(tokens, &[b as i64, s as i64])?,
                    Self::lit_f32(mask, &[b as i64])?,
                    xla::Literal::scalar(seed),
                ],
            )?;
            Self::step_out(out, "train_step")
        }

        pub fn update(
            &self,
            graph: GraphId,
            params: &[f32],
            grad: &[f32],
            m: &[f32],
            v: &[f32],
            step: i32,
            lr: f32,
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            let n = params.len() as i64;
            let out = self.run(
                graph.as_str(),
                &[
                    Self::lit_f32(params, &[n])?,
                    Self::lit_f32(grad, &[n])?,
                    Self::lit_f32(m, &[n])?,
                    Self::lit_f32(v, &[n])?,
                    xla::Literal::scalar(step),
                    xla::Literal::scalar(lr),
                ],
            )?;
            anyhow::ensure!(out.len() == 3, "{} arity", graph.as_str());
            Ok((
                Self::f32_vec(&out[0])?,
                Self::f32_vec(&out[1])?,
                Self::f32_vec(&out[2])?,
            ))
        }

        pub fn eval_loss(
            &self,
            man: &ArtifactManifest,
            params: &[f32],
            lora: Option<&[f32]>,
            tokens: &[i32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            let (b, s) = (man.eval_batch, man.seq_len);
            let out = match lora {
                None => self.run(
                    GraphId::EvalLoss.as_str(),
                    &[
                        Self::lit_f32(params, &[params.len() as i64])?,
                        Self::lit_i32(tokens, &[b as i64, s as i64])?,
                    ],
                )?,
                Some(l) => self.run(
                    GraphId::LoraEval.as_str(),
                    &[
                        Self::lit_f32(params, &[params.len() as i64])?,
                        Self::lit_f32(l, &[l.len() as i64])?,
                        Self::lit_i32(tokens, &[b as i64, s as i64])?,
                    ],
                )?,
            };
            Ok((Self::f32_vec(&out[0])?, Self::f32_vec(&out[1])?))
        }

        pub fn next_logits(
            &self,
            man: &ArtifactManifest,
            params: &[f32],
            lora: Option<&[f32]>,
            tokens: &[i32],
            lens: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            let (b, s) = (man.eval_batch, man.seq_len);
            let out = match lora {
                None => self.run(
                    GraphId::NextLogits.as_str(),
                    &[
                        Self::lit_f32(params, &[params.len() as i64])?,
                        Self::lit_i32(tokens, &[b as i64, s as i64])?,
                        Self::lit_i32(lens, &[b as i64])?,
                    ],
                )?,
                Some(l) => self.run(
                    GraphId::LoraNextLogits.as_str(),
                    &[
                        Self::lit_f32(params, &[params.len() as i64])?,
                        Self::lit_f32(l, &[l.len() as i64])?,
                        Self::lit_i32(tokens, &[b as i64, s as i64])?,
                        Self::lit_i32(lens, &[b as i64])?,
                    ],
                )?,
            };
            Self::f32_vec(&out[0])
        }

        pub fn lora_step(
            &self,
            man: &ArtifactManifest,
            base: &[f32],
            lora: &[f32],
            tokens: &[i32],
            mask: &[f32],
            seed: i32,
        ) -> anyhow::Result<StepOut> {
            let (b, s) = (man.batch, man.seq_len);
            let out = self.run(
                GraphId::LoraStep.as_str(),
                &[
                    Self::lit_f32(base, &[base.len() as i64])?,
                    Self::lit_f32(lora, &[lora.len() as i64])?,
                    Self::lit_i32(tokens, &[b as i64, s as i64])?,
                    Self::lit_f32(mask, &[b as i64])?,
                    xla::Literal::scalar(seed),
                ],
            )?;
            Self::step_out(out, "lora_step")
        }
    }
}
