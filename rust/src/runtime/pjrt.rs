//! PJRT backend (feature `pjrt`): loads the AOT HLO-text artifacts and
//! executes them through the `xla` crate's PJRT CPU client.
//!
//! Python never runs here — `make artifacts` already lowered the JAX/
//! Pallas programs to `artifacts/*.hlo.txt`; this module parses the HLO
//! text (`HloModuleProto::from_text_file`), compiles once per graph on
//! the PJRT CPU client, and executes from the hot path.
//!
//! NOTE: the `xla` crate (xla-rs) is not on crates.io and is not part
//! of the pinned dependency set; enabling the `pjrt` feature requires
//! adding it as a path/git dependency in `Cargo.toml`.  The default
//! build uses [`super::reference`] instead, which satisfies the same
//! purity contract (Assumption A.13) without the native toolchain.

use std::collections::HashMap;
use std::path::Path;

use super::{ArtifactManifest, StepOut};

/// Compiled executables + manifest metadata.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    execs: HashMap<&'static str, xla::PjRtLoadedExecutable>,
}

const GRAPHS: &[&str] = &[
    "train_step",
    "adamw_update",
    "eval_loss",
    "next_logits",
    "lora_step",
    "lora_adamw",
    "lora_eval",
    "lora_next_logits",
];

impl PjrtBackend {
    /// Load the artifact directory and compile every graph.
    pub fn load(dir: &Path, manifest: &ArtifactManifest) -> anyhow::Result<PjrtBackend> {
        anyhow::ensure!(
            !manifest.synthetic,
            "the pjrt backend needs real AOT artifacts — run `make artifacts`"
        );
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;
        let mut execs = HashMap::new();
        for &name in GRAPHS {
            let path = dir.join(format!("{name}.hlo.txt"));
            anyhow::ensure!(
                path.exists(),
                "missing artifact {} — run `make artifacts`",
                path.display()
            );
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().unwrap(),
            )
            .map_err(|e| anyhow::anyhow!("parse {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            execs.insert(name, exe);
        }
        Ok(PjrtBackend { client, execs })
    }

    /// PJRT platform name (the Table 2 hardware pin).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(
        &self,
        name: &'static str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown graph {name}"))?;
        let out = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    fn f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("literal to f32: {e:?}"))
    }

    fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let l = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(l);
        }
        l.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    fn lit_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        let l = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(l);
        }
        l.reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    fn step_out(out: Vec<xla::Literal>, graph: &str) -> anyhow::Result<StepOut> {
        anyhow::ensure!(out.len() == 3, "{graph} arity");
        Ok(StepOut {
            grad: Self::f32_vec(&out[0])?,
            loss_sum: Self::f32_vec(&out[1])?[0],
            tok_count: Self::f32_vec(&out[2])?[0],
        })
    }

    pub fn train_step(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let (b, s) = (man.batch, man.seq_len);
        let out = self.run(
            "train_step",
            &[
                Self::lit_f32(params, &[params.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_f32(mask, &[b as i64])?,
                xla::Literal::scalar(seed),
            ],
        )?;
        Self::step_out(out, "train_step")
    }

    pub fn update(
        &self,
        graph: &'static str,
        params: &[f32],
        grad: &[f32],
        m: &[f32],
        v: &[f32],
        step: i32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let n = params.len() as i64;
        let out = self.run(
            graph,
            &[
                Self::lit_f32(params, &[n])?,
                Self::lit_f32(grad, &[n])?,
                Self::lit_f32(m, &[n])?,
                Self::lit_f32(v, &[n])?,
                xla::Literal::scalar(step),
                xla::Literal::scalar(lr),
            ],
        )?;
        anyhow::ensure!(out.len() == 3, "{graph} arity");
        Ok((
            Self::f32_vec(&out[0])?,
            Self::f32_vec(&out[1])?,
            Self::f32_vec(&out[2])?,
        ))
    }

    pub fn eval_loss(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (b, s) = (man.eval_batch, man.seq_len);
        let out = self.run(
            "eval_loss",
            &[
                Self::lit_f32(params, &[params.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
            ],
        )?;
        Ok((Self::f32_vec(&out[0])?, Self::f32_vec(&out[1])?))
    }

    pub fn next_logits(
        &self,
        man: &ArtifactManifest,
        params: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let (b, s) = (man.eval_batch, man.seq_len);
        let out = self.run(
            "next_logits",
            &[
                Self::lit_f32(params, &[params.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_i32(lens, &[b as i64])?,
            ],
        )?;
        Self::f32_vec(&out[0])
    }

    pub fn lora_step(
        &self,
        man: &ArtifactManifest,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        mask: &[f32],
        seed: i32,
    ) -> anyhow::Result<StepOut> {
        let (b, s) = (man.batch, man.seq_len);
        let out = self.run(
            "lora_step",
            &[
                Self::lit_f32(base, &[base.len() as i64])?,
                Self::lit_f32(lora, &[lora.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_f32(mask, &[b as i64])?,
                xla::Literal::scalar(seed),
            ],
        )?;
        Self::step_out(out, "lora_step")
    }

    pub fn lora_eval(
        &self,
        man: &ArtifactManifest,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let (b, s) = (man.eval_batch, man.seq_len);
        let out = self.run(
            "lora_eval",
            &[
                Self::lit_f32(base, &[base.len() as i64])?,
                Self::lit_f32(lora, &[lora.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
            ],
        )?;
        Ok((Self::f32_vec(&out[0])?, Self::f32_vec(&out[1])?))
    }

    pub fn lora_next_logits(
        &self,
        man: &ArtifactManifest,
        base: &[f32],
        lora: &[f32],
        tokens: &[i32],
        lens: &[i32],
    ) -> anyhow::Result<Vec<f32>> {
        let (b, s) = (man.eval_batch, man.seq_len);
        let out = self.run(
            "lora_next_logits",
            &[
                Self::lit_f32(base, &[base.len() as i64])?,
                Self::lit_f32(lora, &[lora.len() as i64])?,
                Self::lit_i32(tokens, &[b as i64, s as i64])?,
                Self::lit_i32(lens, &[b as i64])?,
            ],
        )?;
        Self::f32_vec(&out[0])
    }
}
