//! Equality-proof artifact (paper §5 "Equality proof artifact", Table 5).
//!
//! When the replay precondition holds, we emit a compact JSON proof
//! recording model/optimizer state hashes for oracle and replay (which
//! must match), per-component optimizer equality flags, both runs'
//! traversal invariants, and the WAL segment integrity hashes.

use std::path::Path;

use crate::checkpoint::TrainState;
use crate::replay::ReplayInvariants;
use crate::util::bytes::{bits_equal, max_abs_diff};
use crate::util::json::Json;

/// The Table 5 artifact.
#[derive(Debug, Clone)]
pub struct EqualityProof {
    pub status_pass: bool,
    pub model_hash_oracle: String,
    pub model_hash_replay: String,
    pub optimizer_hash_oracle: String,
    pub optimizer_hash_replay: String,
    pub exp_avg_equal: bool,
    pub exp_avg_sq_equal: bool,
    pub step_equal: bool,
    pub max_abs_diff: f32,
    pub replay_invariants: ReplayInvariants,
    pub oracle_invariants: ReplayInvariants,
    pub wal_segment_shas: Vec<String>,
}

impl EqualityProof {
    /// Compare an oracle retrain against a replay (bit-level, G1).
    pub fn build(
        oracle: &TrainState,
        replay: &TrainState,
        oracle_inv: ReplayInvariants,
        replay_inv: ReplayInvariants,
        wal_segment_shas: Vec<String>,
    ) -> EqualityProof {
        let model_equal = bits_equal(&oracle.params, &replay.params);
        let exp_avg_equal = bits_equal(&oracle.m, &replay.m);
        let exp_avg_sq_equal = bits_equal(&oracle.v, &replay.v);
        let step_equal = oracle.applied_updates == replay.applied_updates;
        EqualityProof {
            status_pass: model_equal
                && exp_avg_equal
                && exp_avg_sq_equal
                && step_equal,
            model_hash_oracle: oracle.model_hash(),
            model_hash_replay: replay.model_hash(),
            optimizer_hash_oracle: oracle.optimizer_hash(),
            optimizer_hash_replay: replay.optimizer_hash(),
            exp_avg_equal,
            exp_avg_sq_equal,
            step_equal,
            max_abs_diff: max_abs_diff(&oracle.params, &replay.params),
            replay_invariants: replay_inv,
            oracle_invariants: oracle_inv,
            wal_segment_shas,
        }
    }

    fn inv_json(inv: &ReplayInvariants) -> Json {
        let mut j = Json::obj();
        j.set("applied_steps", inv.applied_steps)
            .set("empty_logical_steps", inv.empty_logical_steps)
            .set("records", inv.records)
            .set("skipped_microbatches", inv.skipped_microbatches);
        if let Some((a, b)) = inv.logical_range {
            j.set("logical_range", Json::Arr(vec![a.into(), b.into()]));
        }
        j
    }

    /// The `equality_proof_v2.json` document of §6.2.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("status", if self.status_pass { "PASS" } else { "FAIL" })
            .set("model_hash_oracle", self.model_hash_oracle.as_str())
            .set("model_hash_replay", self.model_hash_replay.as_str())
            .set(
                "optimizer_hash_oracle",
                self.optimizer_hash_oracle.as_str(),
            )
            .set(
                "optimizer_hash_replay",
                self.optimizer_hash_replay.as_str(),
            )
            .set("exp_avg_equal", self.exp_avg_equal)
            .set("exp_avg_sq_equal", self.exp_avg_sq_equal)
            .set("step_equal", self.step_equal)
            .set("max_abs_diff", self.max_abs_diff as f64)
            .set("replay_invariants", Self::inv_json(&self.replay_invariants))
            .set("oracle_invariants", Self::inv_json(&self.oracle_invariants))
            .set(
                "wal_segment_sha256",
                Json::Arr(
                    self.wal_segment_shas
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            );
        j
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    /// Human-readable Table 5 rendering.
    pub fn render_table5(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Status                         | {}\n",
            if self.status_pass { "PASS" } else { "FAIL" }
        ));
        out.push_str(&format!(
            "Model hash (oracle = replay)   | {} / {}\n",
            self.model_hash_oracle, self.model_hash_replay
        ));
        out.push_str(&format!(
            "Optimizer hash (oracle=replay) | {} / {}\n",
            self.optimizer_hash_oracle, self.optimizer_hash_replay
        ));
        out.push_str(&format!(
            "Optimizer components equal     | exp_avg={}, exp_avg_sq={}, step={}\n",
            self.exp_avg_equal, self.exp_avg_sq_equal, self.step_equal
        ));
        out.push_str(&format!(
            "Replay invariants              | applied steps = {} (range {:?})\n",
            self.replay_invariants.applied_steps,
            self.replay_invariants.logical_range
        ));
        out.push_str(&format!(
            "Oracle invariants              | applied steps = {}, empty logical steps = {}, range {:?}\n",
            self.oracle_invariants.applied_steps,
            self.oracle_invariants.empty_logical_steps,
            self.oracle_invariants.logical_range
        ));
        out.push_str(&format!(
            "WAL segment SHA-256            | {}\n",
            self.wal_segment_shas.first().map(|s| &s[..16.min(s.len())])
                .unwrap_or("-")
        ));
        out
    }
}

/// Collect the per-segment SHA-256 values of a run's WAL.
pub fn wal_segment_shas(wal_dir: &Path) -> anyhow::Result<Vec<String>> {
    let mut out = Vec::new();
    let mut paths: Vec<_> = std::fs::read_dir(wal_dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.to_string_lossy().ends_with(".seg.sum"))
        .collect();
    paths.sort();
    for p in paths {
        let j = crate::util::json::parse(&std::fs::read_to_string(&p)?)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(s) = j.get("sha256").and_then(|v| v.as_str()) {
            out.push(s.to_string());
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(bump: bool) -> TrainState {
        let mut s = TrainState::zeros_like(vec![1.0, 2.0, 3.0]);
        s.m = vec![0.1, 0.2, 0.3];
        s.v = vec![0.01, 0.02, 0.03];
        s.applied_updates = 5;
        if bump {
            s.params[1] = f32::from_bits(s.params[1].to_bits() ^ 1);
        }
        s
    }

    #[test]
    fn identical_states_pass() {
        let proof = EqualityProof::build(
            &state(false),
            &state(false),
            ReplayInvariants::default(),
            ReplayInvariants::default(),
            vec!["abc".into()],
        );
        assert!(proof.status_pass);
        assert_eq!(proof.model_hash_oracle, proof.model_hash_replay);
        assert_eq!(proof.max_abs_diff, 0.0);
        let j = proof.to_json();
        assert_eq!(j.get("status").unwrap().as_str(), Some("PASS"));
        assert_eq!(j.get("exp_avg_equal").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn single_ulp_flip_fails() {
        let proof = EqualityProof::build(
            &state(false),
            &state(true),
            ReplayInvariants::default(),
            ReplayInvariants::default(),
            vec![],
        );
        assert!(!proof.status_pass);
        assert_ne!(proof.model_hash_oracle, proof.model_hash_replay);
        assert!(proof.max_abs_diff > 0.0);
        // optimizer still matches component-wise
        assert!(proof.exp_avg_equal && proof.exp_avg_sq_equal && proof.step_equal);
    }

    #[test]
    fn step_counter_mismatch_fails() {
        let mut r = state(false);
        r.applied_updates = 6;
        let proof = EqualityProof::build(
            &state(false),
            &r,
            ReplayInvariants::default(),
            ReplayInvariants::default(),
            vec![],
        );
        assert!(!proof.status_pass);
        assert!(!proof.step_equal);
    }

    #[test]
    fn render_includes_table5_rows() {
        let proof = EqualityProof::build(
            &state(false),
            &state(false),
            ReplayInvariants::default(),
            ReplayInvariants::default(),
            vec!["deadbeefdeadbeefdeadbeef".into()],
        );
        let t = proof.render_table5();
        assert!(t.contains("Status"));
        assert!(t.contains("PASS"));
        assert!(t.contains("exp_avg=true"));
    }
}
