//! `unlearn` — leader entrypoint + CLI.
//!
//! Subcommands (grows as the system does; see README):
//!   smoke       load artifacts, run one train_step + update, print hashes
//!   train       deterministic training run with WAL/checkpoints/ring
//!   ci-gate     Algorithm 5.1 determinism/replay gate
//!   pins        print the current environment pins (Table 2)
//!   wal-scan    WAL integrity scan
//!   serve       admin server for forget requests
//!   plan        dry-run the planner: typed plan + cost estimates
//!   forget      run the controller on a forget request
//!   ingest      append docs + one bounded train-increment (online
//!               ingest through the deterministic interleave log)
//!   launder     compact the forgotten set into a rewritten lineage
//!   audit       run the audit harness against a checkpoint
//!   fleet-train   train/resume an N-shard fleet (deterministic
//!                 user→shard partitioning, pinned topology)
//!   fleet-forget  route a forget request to its owning shards only
//!   fleet-status  per-shard status rollup (+ ensemble utility)
//!   fleet-serve   fleet admin server (fleet_status / shard-addressed
//!                 submits / per-shard laundering)
//!   replica-serve   read replica of one shard: lineage-generation CAS
//!                   sync + watermarked eval/loss query plane
//!   replica-status  one replica's sync state (generation, lag,
//!                   last-sync transfer accounting)

use std::collections::HashSet;
use std::path::PathBuf;

use unlearn::config::RunConfig;
use unlearn::data::corpus::{Corpus, CorpusConfig};
use unlearn::runtime::Runtime;
use unlearn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn run_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_json_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    cfg.artifacts_dir = artifacts_dir(args);
    if let Some(d) = args.get("run-dir") {
        cfg.run_dir = PathBuf::from(d);
    }
    cfg.steps = args.get_u64("steps", cfg.steps as u64)? as u32;
    cfg.accum = args.get_usize("accum", cfg.accum)?;
    cfg.lr = args.get_f32("lr", cfg.lr)?;
    cfg.warmup = args.get_u64("warmup", cfg.warmup as u64)? as u32;
    cfg.checkpoint_every =
        args.get_u64("checkpoint-every", cfg.checkpoint_every as u64)? as u32;
    cfg.ring_window = args.get_usize("ring-window", cfg.ring_window)?;
    cfg.run_seed = args.get_u64("seed", cfg.run_seed)?;
    if let Some(k) = args.get("hmac-key") {
        cfg.hmac_key = Some(k.as_bytes().to_vec());
    }
    Ok(cfg)
}

fn cli_request(
    args: &Args,
    default_id: &str,
) -> anyhow::Result<unlearn::controller::ForgetRequest> {
    Ok(unlearn::controller::ForgetRequest {
        id: args.get_or("id", default_id).to_string(),
        user: args.get("user").map(|u| u.parse()).transpose()?,
        sample_ids: args
            .get_or("sample-ids", "")
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse())
            .collect::<Result<_, _>>()?,
        urgency: if args.flag("urgent") {
            unlearn::controller::Urgency::High
        } else {
            unlearn::controller::Urgency::Normal
        },
    })
}

fn corpus(args: &Args) -> anyhow::Result<Corpus> {
    let mut cc = CorpusConfig::default();
    cc.seq_len = args.get_usize("seq-len", cc.seq_len)?;
    cc.seed = args.get_u64("corpus-seed", cc.seed)?;
    Ok(Corpus::generate(cc))
}

fn fleet_config(args: &Args) -> anyhow::Result<unlearn::fleet::FleetConfig> {
    Ok(unlearn::fleet::FleetConfig {
        root: PathBuf::from(args.get_or("fleet-dir", "runs/fleet")),
        spec: unlearn::shard::ShardSpec {
            n_shards: args.get_u64("shards", 4)? as u32,
            salt: args.get_u64("salt", 0x51AB_D00F)?,
        },
        base: run_config(args)?,
        scale_steps: !args.flag("no-scale-steps"),
        launder_policy: unlearn::controller::LaunderPolicy {
            min_extra_replay_records: args.get_u64("launder-min-extra", 64)?,
        },
        auto_launder: args.flag("auto-launder"),
    })
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_deref() {
        Some("smoke") => smoke(args),
        Some("pins") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            println!("{}", rt.capture_pins(cfg.accum).to_json().pretty());
            Ok(())
        }
        Some("train") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            println!(
                "training: {} samples, {} steps x {} microbatches",
                c.len(),
                cfg.steps,
                cfg.accum
            );
            let out = unlearn::trainer::Trainer::new(&rt, cfg, c).train(|_| false)?;
            println!(
                "done: model {}, optimizer {}, applied {}",
                out.state.model_hash(),
                out.state.optimizer_hash(),
                out.state.applied_updates
            );
            if let Some((s, l)) = out.losses.last() {
                println!("final loss/token at step {s}: {l:.4}");
            }
            Ok(())
        }
        Some("ci-gate") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let steps = args.get_u64("gate-steps", 20)? as u32;
            let report = unlearn::cigate::run_gate(&rt, &cfg, &c, steps)?;
            println!("{}", report.to_json().pretty());
            anyhow::ensure!(report.pass(), "CI gate FAILED — forgetting blocked");
            println!("CI gate PASS");
            Ok(())
        }
        Some("wal-scan") => {
            let cfg = run_config(args)?;
            let rep = unlearn::wal::integrity::scan(
                &cfg.run_dir.join("wal"),
                cfg.hmac_key.as_deref(),
            )?;
            println!("{}", rep.to_json().pretty());
            anyhow::ensure!(rep.ok(), "WAL integrity scan failed");
            Ok(())
        }
        Some("replay") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let store = unlearn::checkpoint::CheckpointStore::open(
                &cfg.run_dir.join("ckpt"),
                cfg.checkpoint_keep,
            )?;
            let from_step = args.get_u64("from-step", 0)? as u32;
            let ck = store.load_full(from_step)?;
            let (records, idmap, pins) =
                unlearn::replay::load_run(&cfg.run_dir, cfg.hmac_key.clone())?;
            let closure: HashSet<u64> = args
                .get_or("forget-ids", "")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            let out = unlearn::replay::replay_filter(
                &rt,
                &c,
                &ck,
                &records,
                &idmap,
                &closure,
                Some(&pins),
                // present the configured topology claim: replaying a
                // fleet shard's run dir needs its shard pin to match
                &unlearn::replay::ReplayOptions {
                    shard_pin: cfg.shard_pin.clone(),
                    ..unlearn::replay::ReplayOptions::default()
                },
            )?;
            println!(
                "replayed: model {}, optimizer {}, applied {}, empty {}",
                out.state.model_hash(),
                out.state.optimizer_hash(),
                out.invariants.applied_steps,
                out.invariants.empty_logical_steps
            );
            Ok(())
        }
        Some("serve") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
            // restart path: an existing run dir is REOPENED (WAL,
            // checkpoint lineages, manifest, jobs WAL, forgotten set
            // all survive), not wiped and retrained.  This server
            // exposes the `ingest` op, so the resume must be
            // ingest-aware: recover torn ingest rounds and re-enter
            // committed doc segments into the corpus before the WAL
            // tail is replayed or appended to (same predicate as
            // `harness::open_or_build_system` for the resumed report).
            let resumed = cfg.run_dir.join("wal").exists()
                && cfg.run_dir.join("pins.json").exists()
                && cfg.run_dir.join("ids.map").exists();
            let (trained, _log, report) =
                unlearn::ingest::reopen(&rt, cfg, c, args.flag("fisher"))?;
            if report.wal_segments_removed + report.doc_segments_removed > 0
            {
                println!(
                    "recovered torn ingest round: removed {} wal \
                     segment(s), {} doc segment(s)",
                    report.wal_segments_removed,
                    report.doc_segments_removed
                );
            }
            if resumed {
                println!("resumed existing run (state rebuilt from the \
                          checkpoint lineage)");
            } else {
                println!("trained a fresh run before serving");
            }
            let system =
                std::sync::Arc::new(std::sync::Mutex::new(trained.system));
            unlearn::server::serve(system, &addr)
        }
        Some("ingest") => {
            // online ingest into a (possibly reopened) run: durably
            // append the docs through the interleave log, then advance
            // the tail with one bounded train-increment.  Repeated
            // invocations keep growing the same run dir, and forget
            // requests interleave freely between them.
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let user: u32 = args
                .get("user")
                .ok_or_else(|| anyhow::anyhow!("ingest needs --user"))?
                .parse()?;
            let texts: Vec<String> = match args.get("text") {
                Some(t) => vec![t.to_string()],
                None => args
                    .get_or("docs", "")
                    .split(';')
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect(),
            };
            anyhow::ensure!(
                !texts.is_empty(),
                "ingest needs --text STR or --docs 'a;b;c'"
            );
            let train_steps = args.get_u64("train-steps", 2)? as u32;
            let req_id = args.get_or("id", "cli-ingest").to_string();
            let (mut trained, mut log, report) =
                unlearn::ingest::reopen(&rt, cfg, c, args.flag("fisher"))?;
            if report.wal_segments_removed + report.doc_segments_removed > 0
            {
                println!(
                    "recovered torn round: removed {} wal segment(s), \
                     {} doc segment(s)",
                    report.wal_segments_removed,
                    report.doc_segments_removed
                );
            }
            let sys = &mut trained.system;
            let docs: Vec<unlearn::ingest::IngestDoc> = texts
                .iter()
                .map(|t| unlearn::ingest::IngestDoc {
                    user,
                    text: t.clone(),
                })
                .collect();
            // an explicit --train-steps 0 runs a docs-only round
            let sched =
                unlearn::ingest::IngestScheduler::new(train_steps);
            let out = sched.run_round(
                sys,
                &mut log,
                unlearn::ingest::round_of(&req_id),
                &docs,
            )?;
            println!(
                "ingested {} doc(s) for user {user}; increment \
                 [{}..{}) applied {} update(s){}",
                docs.len(),
                out.step.from_step,
                out.step.from_step + out.step.n_steps,
                out.updates_applied,
                if out.executed {
                    ""
                } else {
                    " (round already committed — idempotent retry)"
                }
            );
            println!(
                "trained_step {}, ingested_docs {}, tail_lag_steps {}",
                sys.state.logical_step,
                sys.ingest.ingested_docs,
                sys.tail_lag_steps()
            );
            Ok(())
        }
        Some("forget") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let trained =
                unlearn::harness::build_system(&rt, cfg, c, args.flag("fisher"))?;
            let mut system = trained.system;
            let req = cli_request(args, "cli-forget")?;
            let outcome = system.handle(&req)?;
            println!(
                "action: {} (closure {}, expanded {})",
                outcome.action.as_str(),
                outcome.closure_size,
                outcome.closure_expanded
            );
            for e in &outcome.escalations {
                println!("escalation [{}]: {e}", e.kind());
            }
            if let Some(a) = outcome.audit {
                println!("audits: {}", a.to_json().pretty());
            }
            Ok(())
        }
        Some("launder") => {
            // demo of the full compaction loop: forget the listed users
            // (cumulative `forgotten` grows), show how the forgotten set
            // inflates a probe plan, launder, show the deflated plan +
            // CAS accounting.
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let trained =
                unlearn::harness::build_system(&rt, cfg, c, args.flag("fisher"))?;
            let mut system = trained.system;
            for (i, u) in args
                .get_or("forget-users", "")
                .split(',')
                .filter(|s| !s.is_empty())
                .enumerate()
            {
                let user: u32 = u.parse()?;
                let o = system.handle(&unlearn::controller::ForgetRequest {
                    id: format!("launder-pre-{i}"),
                    user: Some(user),
                    sample_ids: vec![],
                    urgency: unlearn::controller::Urgency::Normal,
                })?;
                println!("forgot user {user}: {}", o.action.as_str());
            }
            let probe = args
                .get("probe-user")
                .map(|u| u.parse::<u32>())
                .transpose()?;
            let probe_req = |tag: &str, user: u32| {
                unlearn::controller::ForgetRequest {
                    id: format!("launder-probe-{tag}"),
                    user: Some(user),
                    sample_ids: vec![],
                    urgency: unlearn::controller::Urgency::Normal,
                }
            };
            if let Some(u) = probe {
                if let Ok(p) = system.plan(&probe_req("pre", u)) {
                    if let Some(s) = p.steps.last() {
                        println!(
                            "pre-launder probe plan: {} replay steps",
                            s.cost.replay_steps
                        );
                    }
                }
            }
            let policy = unlearn::controller::LaunderPolicy {
                min_extra_replay_records: args
                    .get_u64("launder-min-extra", 0)?,
            };
            let out = system.launder(
                args.get_or("id", "cli-launder"),
                &policy,
                args.flag("force"),
            )?;
            println!("{}", out.to_json().pretty());
            if let Some(u) = probe {
                if let Ok(p) = system.plan(&probe_req("post", u)) {
                    if let Some(s) = p.steps.last() {
                        println!(
                            "post-launder probe plan: {} replay steps",
                            s.cost.replay_steps
                        );
                    }
                }
            }
            let stats = system.cas_stats()?;
            println!(
                "cas: {} objects, {} bytes stored / {} referenced \
                 (dedup ratio {:.3}), lineage gen {}, {} laundered ids",
                stats.objects,
                stats.object_bytes,
                stats.referenced_bytes,
                stats.dedup_ratio,
                stats.generation,
                stats.laundered_ids
            );
            Ok(())
        }
        Some("plan") => {
            // dry-run: print the typed plan + cost estimates, mutate
            // nothing (the planner is pure over the system view)
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let trained =
                unlearn::harness::build_system(&rt, cfg, c, args.flag("fisher"))?;
            let system = trained.system;
            let req = cli_request(args, "cli-plan")?;
            match system.plan(&req) {
                Ok(plan) => println!("{}", plan.to_json().pretty()),
                Err(e) => {
                    println!("{}", e.to_json().pretty());
                    anyhow::bail!("planning failed: {e}");
                }
            }
            Ok(())
        }
        Some("audit") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let cfg = run_config(args)?;
            let c = corpus(args)?;
            let trained = unlearn::harness::build_system(&rt, cfg, c, false)?;
            let sys = trained.system;
            let forget: Vec<u64> = sys.retain_ids.iter().take(8).copied().collect();
            let ctx = unlearn::audit::AuditContext {
                rt: &rt,
                corpus: &sys.corpus,
                forget_ids: &forget,
                retain_ids: &sys.retain_ids,
                eval_ids: &sys.eval_ids,
                baseline_ppl: None,
                thresholds: Default::default(),
                seed: 1,
            };
            let rep = unlearn::audit::run_audits(
                &ctx,
                unlearn::audit::ModelView::Base(&sys.state.params),
            )?;
            println!("{}", rep.to_json().pretty());
            Ok(())
        }
        Some("fleet-train") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let fcfg = fleet_config(args)?;
            let c = corpus(args)?;
            let (fleet, resumed) =
                unlearn::fleet::Fleet::open_or_train(&rt, fcfg, c)?;
            println!(
                "{} fleet: {} shards, salt {:#x}",
                if resumed { "resumed" } else { "trained" },
                fleet.n_shards(),
                fleet.spec.salt
            );
            println!("{}", fleet.status_json().pretty());
            Ok(())
        }
        Some("fleet-forget") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let fcfg = fleet_config(args)?;
            let c = corpus(args)?;
            let (mut fleet, _) =
                unlearn::fleet::Fleet::open_or_train(&rt, fcfg, c)?;
            let req = cli_request(args, "cli-fleet-forget")?;
            let plan = fleet.plan(&req)?;
            println!(
                "routing: {} shard(s), total replay steps {}, \
                 max est wall {:.3}s",
                plan.shard_plans.len(),
                plan.total_replay_steps,
                plan.max_est_wall_secs
            );
            let out = fleet.forget(&req)?;
            for fo in &out.outcomes {
                println!("{}", fo.to_json().pretty());
            }
            println!(
                "shards touched: {}, shared rebuilds: {}, applied \
                 steps total: {}",
                out.shards_touched, out.replays_run, out.applied_steps_total
            );
            Ok(())
        }
        Some("fleet-status") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let fcfg = fleet_config(args)?;
            let c = corpus(args)?;
            let (fleet, _) =
                unlearn::fleet::Fleet::open_or_train(&rt, fcfg, c)?;
            println!("{}", fleet.status_json().pretty());
            if args.flag("utility") {
                let u = fleet.utility_ensemble()?;
                println!("fleet ensemble ppl: {:.4}", u.fleet_ppl);
                for (s, p) in u.per_shard {
                    println!("  shard {s}: ppl {p:.4}");
                }
            }
            Ok(())
        }
        Some("fleet-serve") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let fcfg = fleet_config(args)?;
            let c = corpus(args)?;
            let addr = args.get_or("addr", "127.0.0.1:7879").to_string();
            let (fleet, resumed) =
                unlearn::fleet::Fleet::open_or_train(&rt, fcfg, c)?;
            println!(
                "{} fleet of {} shard(s); serving on {addr}",
                if resumed { "resumed" } else { "trained" },
                fleet.n_shards()
            );
            let fleet = std::sync::Arc::new(std::sync::Mutex::new(fleet));
            unlearn::fleet::server::serve_fleet(fleet, &addr)
        }
        Some("replica-serve") => {
            let rt = Runtime::load(&artifacts_dir(args))?;
            let fcfg = fleet_config(args)?;
            let c = corpus(args)?;
            let shard = args.get_u64("shard", 0)? as u32;
            let fleet_root = fcfg.root.clone();
            let local = args
                .get("replica-dir")
                .map(PathBuf::from)
                .unwrap_or_else(|| {
                    fleet_root.join(format!("replica-{shard:04}"))
                });
            let addr = args.get_or("addr", "127.0.0.1:7880").to_string();
            // the replica serves the shard's own corpus view (eval ids
            // are local to the mirrored shard)
            let (fleet, _) =
                unlearn::fleet::Fleet::open_or_train(&rt, fcfg, c)?;
            let shard_corpus = fleet
                .shard(shard)
                .ok_or_else(|| {
                    anyhow::anyhow!("shard {shard} is empty or out of range")
                })?
                .corpus
                .clone();
            drop(fleet);
            let source = fleet_root.join(format!("shard-{shard:04}")).join("ckpt");
            let mut replica = unlearn::replica::Replica::open(&source, &local)?;
            let stats = replica.sync()?;
            println!(
                "replica of shard {shard} at generation {} ({} objects / \
                 {} bytes pulled, {} reused); serving on {addr}",
                stats.to_generation,
                stats.objects_pulled,
                stats.bytes_pulled,
                stats.objects_reused
            );
            let ctx =
                unlearn::replica::ReplicaCtx::new(&rt, shard_corpus, replica);
            unlearn::replica::serve_replica(&ctx, &addr)
        }
        Some("replica-status") => {
            let shard = args.get_u64("shard", 0)? as u32;
            let fleet_root = PathBuf::from(args.get_or("fleet-dir", "runs/fleet"));
            let local = args
                .get("replica-dir")
                .map(PathBuf::from)
                .unwrap_or_else(|| {
                    fleet_root.join(format!("replica-{shard:04}"))
                });
            let source = fleet_root.join(format!("shard-{shard:04}")).join("ckpt");
            let replica = unlearn::replica::Replica::open(&source, &local)?;
            println!("{}", replica.status_json().pretty());
            Ok(())
        }
        other => {
            eprintln!(
                "usage: unlearn <smoke|pins|train|ci-gate|wal-scan|replay|plan|forget|ingest|launder|audit|serve|\
                 fleet-train|fleet-forget|fleet-status|fleet-serve|\
                 replica-serve|replica-status> \
                 [--artifacts DIR] [--run-dir DIR] [--steps N] \
                 [--user U --text STR --train-steps N] \
                 [--shards N --salt S --fleet-dir DIR] \
                 [--shard N --replica-dir DIR] ...\n\
                 (got {other:?})"
            );
            anyhow::bail!("unknown subcommand");
        }
    }
}

fn smoke(args: &Args) -> anyhow::Result<()> {
    let rt = Runtime::load(&artifacts_dir(args))?;
    let man = &rt.manifest;
    let fp = rt.fingerprint();
    println!(
        "executor={} platform={} fingerprint={} P={} PL={} B={} S={}",
        fp.kind,
        rt.platform(),
        fp.digest(),
        man.param_count,
        man.lora_param_count,
        man.batch,
        man.seq_len
    );
    man.verify_files()?;
    let params = man.init_params()?;
    let tokens: Vec<i32> = (0..man.batch * man.seq_len)
        .map(|i| (i % 251 + 1) as i32)
        .collect();
    let mask = vec![1.0f32; man.batch];
    let out = rt.train_step(&params, &tokens, &mask, 7)?;
    println!(
        "train_step: loss={} count={} |g|inf={}",
        out.loss_sum,
        out.tok_count,
        // detlint: allow(float-reduce) — ∞-norm for a smoke printout; max
        // is order-insensitive and nothing replayed reads it
        out.grad.iter().fold(0.0f32, |a, x| a.max(x.abs()))
    );
    // purity check (Assumption A.13): run twice, compare bits
    let out2 = rt.train_step(&params, &tokens, &mask, 7)?;
    anyhow::ensure!(
        unlearn::util::bytes::bits_equal(&out.grad, &out2.grad),
        "train_step not bit-deterministic!"
    );
    let m = vec![0.0f32; man.param_count];
    let v = vec![0.0f32; man.param_count];
    let (p2, m2, _v2) = rt.adamw_update(&params, &out.grad, &m, &v, 1, 1e-3)?;
    println!(
        "adamw_update: params {} -> {}",
        unlearn::util::bytes::state_hash64(&params),
        unlearn::util::bytes::state_hash64(&p2)
    );
    anyhow::ensure!(!unlearn::util::bytes::bits_equal(&params, &p2));
    anyhow::ensure!(m2.iter().any(|&x| x != 0.0));
    // eval + logits
    let etokens: Vec<i32> = (0..man.eval_batch * man.seq_len)
        .map(|i| (i % 97 + 1) as i32)
        .collect();
    let (losses, counts) = rt.eval_loss(&params, &etokens)?;
    println!("eval_loss[0]={} count[0]={}", losses[0], counts[0]);
    let lens = vec![man.seq_len as i32; man.eval_batch];
    let logits = rt.next_logits(&params, &etokens, &lens)?;
    anyhow::ensure!(logits.len() == man.eval_batch * man.vocab);
    // lora path
    let lora = man.init_lora()?;
    let lout = rt.lora_step(&params, &lora, &tokens, &mask, 3)?;
    println!("lora_step: loss={} |g|inf={}", lout.loss_sum,
             // detlint: allow(float-reduce) — ∞-norm for a smoke printout;
             // max is order-insensitive and nothing replayed reads it
             lout.grad.iter().fold(0.0f32, |a, x| a.max(x.abs())));
    // batched segment entry point: reduce-order pin (possibly parallel
    // execution, bit-identical to the sequential fold)
    let seg: Vec<unlearn::runtime::MicrobatchInput<'_>> = (0..4)
        .map(|i| unlearn::runtime::MicrobatchInput {
            tokens: &tokens,
            mask: &mask,
            seed: i,
        })
        .collect();
    let acc = rt.grad_accumulate(&params, &seg)?;
    let mut fold = vec![0.0f32; man.param_count];
    for mb in &seg {
        let o = rt.train_step(&params, mb.tokens, mb.mask, mb.seed)?;
        unlearn::trainer::accumulate(&mut fold, &o.grad);
    }
    anyhow::ensure!(
        unlearn::util::bytes::bits_equal(&acc.grad, &fold),
        "grad_accumulate drifted from the logged sequential order!"
    );
    println!("grad_accumulate: 4-microbatch segment == sequential fold");
    println!("smoke OK");
    Ok(())
}
